"""Benchmark: paper Tables I & II — analytic complexity vs measured HLO
FLOPs of the actual JAX implementation (reduced ViT, scaled check)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import DEIT_SMALL, PruningConfig
from repro.core import complexity as C
from repro.models import model as M


def run() -> list:
    rows = []
    cfg = DEIT_SMALL
    # Table I closed forms at the paper's operating point
    d = C.EncoderDims(B=1, N=197, H=6, Dp=64, D=384, Dmlp=1536)
    dense = C.dense_encoder_macs(d)
    rows.append(("table_i.dense_encoder_msa_macs", dense["msa"], ""))
    rows.append(("table_i.dense_encoder_mlp_macs", dense["mlp"], ""))
    rows.append(("table_i.dense_encoder_total_macs", dense["total"], ""))

    pr = C.pruned_encoder_macs(d, alpha=0.5, alpha_proj=0.5, h_kept=6,
                               n_kept=100, alpha_mlp=0.5, has_tdm=True)
    rows.append(("table_ii.pruned_encoder_total_macs", pr["total"],
                 "alpha=0.5 n_kept=100"))

    # cross-check the analytic model against XLA-counted flops of the real
    # ViT forward (reduced config; flops = 2*MACs + elementwise overhead)
    rcfg = DEIT_SMALL.reduced().replace(
        pruning=PruningConfig(block_size=16, r_b=1.0, r_t=1.0))
    params = jax.eval_shape(
        lambda: M.init_params(rcfg, jax.random.PRNGKey(0)))
    n = (rcfg.image_size // rcfg.patch_size) ** 2
    patches = jax.ShapeDtypeStruct((1, n, rcfg.patch_size ** 2 * 3),
                                   jnp.float32)
    compiled = jax.jit(
        lambda p, x: M.forward_vit(rcfg, p, x).logits).lower(
            params, patches).compile()
    flops = float(dict(compiled.cost_analysis()).get("flops", 0))
    analytic = C.model_macs(rcfg, 1)["total"] * 2  # MACs -> flops
    rows.append(("table_i.xla_flops_reduced_vit", flops, ""))
    rows.append(("table_i.analytic_flops_reduced_vit", analytic,
                 f"ratio={flops/analytic:.2f}"))
    return rows
