"""Benchmark: Fig. 9/10 analog measured on THIS host — wall-clock latency of
the actual JAX ViT forward, dense vs simultaneous-pruned (reduced config so
it runs on CPU), plus the SBMM kernel vs dense matmul at the packed sizes.

The FPGA numbers are reproduced analytically in perf_model_bench; this file
shows the pruning speedup materializes in the real implementation too."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DEIT_SMALL, PruningConfig
from repro.core import block_pruning as bp
from repro.core import packing
from repro.kernels.sbmm import sbmm
from repro.models import model as M


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)

    # real ViT forward: dense vs token-pruned (same weights)
    cfg_d = DEIT_SMALL.replace(
        num_layers=4, pruning=PruningConfig())
    cfg_p = cfg_d.replace(pruning=PruningConfig(
        block_size=16, r_b=0.5, r_t=0.5, tdm_layers=(1, 2)))
    params = M.init_params(cfg_d, key)
    n = (cfg_d.image_size // cfg_d.patch_size) ** 2
    patches = jax.random.normal(key, (1, n, cfg_d.patch_size ** 2 * 3))

    f_dense = jax.jit(lambda p, x: M.forward_vit(cfg_d, p, x).logits)
    f_tdm = jax.jit(lambda p, x: M.forward_vit(cfg_p, p, x).logits)
    t_dense = _time(f_dense, params, patches)
    t_tdm = _time(f_tdm, params, patches)
    rows.append(("fig9.jax_vit4L_dense_us", round(t_dense, 1), "CPU wall"))
    rows.append(("fig9.jax_vit4L_tdm_rt0.5_us", round(t_tdm, 1),
                 f"speedup={t_dense/t_tdm:.2f}x"))

    # SBMM kernel vs dense matmul at a pruned-weight operating point
    K, N, b, rb = 384, 1536, 16, 0.5
    w = np.asarray(jax.random.normal(key, (K, N)), np.float32)
    sc = np.asarray(jax.random.normal(key, bp.score_shape((K, N), b)))
    keep = max(1, int(np.ceil(sc.size * rb)))
    mask = np.asarray(bp._hard_topk(jnp.asarray(sc), keep))
    pk = packing.pack_weight(w, mask, b)
    x = jax.random.normal(key, (128, K))
    dense_w = pk.to_dense()
    t_dense_mm = _time(jax.jit(lambda a, b: a @ b), x, dense_w)
    rows.append(("sbmm.dense_matmul_us", round(t_dense_mm, 1),
                 f"{128}x{K}x{N}"))
    rows.append(("sbmm.packed_blocks", int(np.asarray(pk.counts).sum()),
                 f"of {sc.size} ({rb:.0%} kept)"))
    # NOTE: the Pallas kernel runs in interpret mode on CPU (orders of
    # magnitude slower than compiled TPU execution); we report its VALIDATED
    # numerical match instead of a misleading CPU wall time.
    y1 = np.asarray(sbmm(x, pk, tm=64))
    y2 = np.asarray(x @ dense_w)
    rows.append(("sbmm.kernel_max_abs_err", float(np.abs(y1 - y2).max()),
                 "interpret-mode validation"))
    return rows
