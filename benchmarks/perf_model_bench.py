"""Benchmark: paper Table III / §V-E — the accelerator cycle model, and the
Table V / VII cross-platform latency story (normalized-latency analysis)."""
from __future__ import annotations

from repro.configs import DEIT_SMALL, PruningConfig
from repro.core import perf_model as PM


def run() -> list:
    rows = []
    acc = PM.PAPER_U250
    rows.append(("table_iii.macs_per_cycle", acc.macs_per_cycle,
                 "p_h*p_t*p_c*p_pe^2 = 4*12*2*64"))
    rows.append(("table_iii.peak_tmacs", acc.macs_per_cycle * acc.freq_hz / 1e12,
                 "paper lists 1.8 TFLOPS peak"))

    # SBMM/DBMM/DHBMM cycle counts at the paper's operating point
    c_sbmm = PM.sbmm_cycles(197, 384, 1152, 6, 16, acc, phi=0.5)
    c_dbmm = PM.sbmm_cycles(197, 384, 1152, 6, 16, acc, phi=1.0)
    c_dhb = PM.dhbmm_cycles(197, 64, 197, 6, 16, acc)
    rows.append(("table_iii.sbmm_cycles_qkv_phi0.5", c_sbmm, ""))
    rows.append(("table_iii.dbmm_cycles_qkv", c_dbmm, ""))
    rows.append(("table_iii.dhbmm_cycles_qkT", c_dhb, ""))
    rows.append(("table_iii.sparse_speedup", round(c_dbmm / c_sbmm, 2),
                 "phi=0.5 -> ~2x"))

    # end-to-end latency trajectory (Fig. 9 analog) across pruning settings
    for (b, rb, rt) in [(16, 1.0, 1.0), (16, 0.7, 0.9), (16, 0.7, 0.5),
                        (16, 0.5, 0.7), (16, 0.5, 0.5)]:
        pc = PruningConfig(block_size=b, r_b=rb, r_t=rt,
                           tdm_layers=(2, 6, 9) if rt < 1 else ())
        lat = PM.model_latency_ms(DEIT_SMALL, pc)
        rows.append((f"fig9.latency_ms.b{b}_rb{rb}_rt{rt}",
                     round(lat["latency_ms"], 3),
                     f"throughput={lat['throughput_ips']:.0f} img/s"))

    # Table VII normalized-latency comparison: latency x peak-performance
    # (paper's fairness metric). Peak TFLOPS from Table V.
    peers = {"ViTAcc_zcu102": (26.0, 0.37), "HeatViT_zcu102": (9.1, 0.37),
             "SPViT_zcu102": (13.23, 0.54)}
    ours_lat = PM.model_latency_ms(
        DEIT_SMALL, PruningConfig(block_size=16, r_b=0.5, r_t=0.5,
                                  tdm_layers=(2, 6, 9)))["latency_ms"]
    ours_norm = ours_lat * 1.8
    rows.append(("table_vii.ours.norm_latency", round(ours_norm, 2),
                 f"lat={ours_lat:.3f}ms x 1.8TF"))
    for name, (lat, peak) in peers.items():
        norm = lat * peak
        rows.append((f"table_vii.{name}.norm_speedup_vs_ours",
                     round(norm / ours_norm, 2),
                     f"paper reports 0.72-4.5x band"))
    return rows
