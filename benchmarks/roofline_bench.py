"""Benchmark: roofline table from the dry-run artifacts (§Roofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and emits
one row per (arch × shape × mesh) with the three roofline terms. If the
dry-run has not been executed yet, emits a pointer row instead of failing —
the dry-run takes hours at 512 devices and runs as its own step."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run() -> list:
    rows = []
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        return [("roofline.status", 0,
                 f"no dry-run artifacts in {DRYRUN_DIR}; run "
                 "PYTHONPATH=src python -m repro.launch.dryrun first")]
    ok = failed = 0
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        tag = f"{d['arch']}.{d['shape']}.{d['mesh']}"
        if d.get("status") != "ok":
            failed += 1
            rows.append((f"roofline.{tag}.status", 0,
                         d.get("error", "?")[:80]))
            continue
        ok += 1
        r = d["roofline"]
        rows.append((f"roofline.{tag}.compute_ms",
                     round(r["compute_s"] * 1e3, 4), ""))
        rows.append((f"roofline.{tag}.memory_ms",
                     round(r["memory_s"] * 1e3, 4), ""))
        rows.append((f"roofline.{tag}.collective_ms",
                     round(r["collective_s"] * 1e3, 4),
                     f"dominant={r['dominant']}"))
        rows.append((f"roofline.{tag}.useful_ratio",
                     round(r["useful_ratio"], 3),
                     f"model_flops/hlo_flops"))
    rows.append(("roofline.cells_ok", ok, f"failed={failed}"))
    return rows
