# One function per paper table. Prints ``name,value,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  complexity_tables — Tables I & II (analytic vs XLA-counted flops)
  table_vi          — Table VI pruning sweep (MACs / size / latency model)
  perf_model_bench  — Table III cycle model + Table V/VII normalized latency
  latency           — Fig. 9/10 analog measured on this host (real JAX fwd)
  roofline_bench    — §Roofline table from the dry-run artifacts

Run: ``PYTHONPATH=src python -m benchmarks.run [module ...]``
"""
from __future__ import annotations

import sys
import time
import traceback


MODULES = ["complexity_tables", "table_vi", "perf_model_bench", "latency",
           "roofline_bench"]


def main() -> None:
    want = sys.argv[1:] or MODULES
    print("name,value,derived")
    failures = 0
    for name in want:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}.ERROR,1,\"{type(e).__name__}: {e}\"")
            traceback.print_exc(file=sys.stderr)
            continue
        for row in rows:
            n, v, derived = row
            d = str(derived).replace(",", ";")
            print(f"{n},{v},\"{d}\"")
        print(f"{name}.wall_s,{time.time()-t0:.1f},\"\"")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
