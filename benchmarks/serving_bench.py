"""Static-wave vs continuous-batching serving throughput + admission cost.

A static wave holds every slot until the *longest* request in the wave
finishes, so skewed request lengths strand capacity; the continuous path
re-admits waiting requests into slots the moment one retires. This bench
serves an identical skewed request mix through three paths and reports
tokens/s plus the two costs the PR-3 redesign targets:

* ``prefill tok/admit`` — padded tokens run per admission. The legacy
  continuous path (``cont-reprefill``, PR-2 behavior) re-prefills *every*
  active prefix on each admission, so this grows with slot occupancy; the
  per-slot path prefills only the admitted prompt's bucket — independent
  of how many slots are active (the admission-cost acceptance criterion).
* ``jit compiles``      — distinct XLA compilations. Legacy re-prefill
  compiles per distinct padded batch length; prefix-length bucketing
  bounds the per-slot path to one compile per bucket.

    PYTHONPATH=src python benchmarks/serving_bench.py            # full
    PYTHONPATH=src python benchmarks/serving_bench.py --smoke    # CI lane

A ``BENCH_serving.json`` artifact (all rows + config, written through the
schema-versioned ``repro.bench`` envelope shared with vision_bench.py) is
written next to the working directory (``--out`` overrides). ``--smoke``
runs a seconds-scale configuration and exits non-zero if any path fails to
serve every request (the CI fast lane runs it so serving-path regressions
fail visibly).
"""
from __future__ import annotations

import argparse
import sys
import time


def make_requests(cfg, num: int, prompt_lo: int, prompt_hi: int,
                  new_lo: int, new_hi: int, seed: int):
    import numpy as np

    from repro.serving import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(num):
        plen = int(rng.integers(prompt_lo, prompt_hi + 1))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=int(rng.integers(new_lo, new_hi + 1))))
    return reqs


MODES = (
    # (name, continuous, per_slot_prefill)
    ("static", False, True),
    ("cont-reprefill", True, False),   # PR-2 baseline: whole-batch re-prefill
    ("continuous", True, True),        # per-slot prefill admission
)


def bench(arch: str, num: int, slots: int, prompt_lo: int, prompt_hi: int,
          new_lo: int, new_hi: int, kv_prune: float, seed: int):
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving import EngineConfig, ServeEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))

    results = {}
    for mode, continuous, per_slot in MODES:
        ec = EngineConfig(
            max_batch=slots,
            # legacy re-prefill pads a finished-prefix slot (prompt + up to
            # new_hi generated) against a slot with up to new_hi still to
            # go, so the cache high-water mark is prompt_hi + 2*new_hi - 1
            max_len=prompt_hi + 2 * new_hi + 8,
            kv_prune_interval=4 if kv_prune < 1.0 else 0,
            kv_prune_keep=kv_prune,
            per_slot_prefill=per_slot)
        engine = ServeEngine(cfg, params, ec)
        reqs = make_requests(cfg, num, prompt_lo, prompt_hi,
                             new_lo, new_hi, seed)
        engine.serve(  # warmup/compile
            make_requests(cfg, min(num, slots), prompt_lo, prompt_hi,
                          new_lo, new_lo, seed + 1), continuous=continuous)
        # snapshot so every reported stat covers ONLY the measured run
        warm = engine.stats()
        t0 = time.time()
        out = engine.serve(reqs, continuous=continuous)
        dt = time.time() - t0
        tokens = sum(len(v) for v in out.values())
        st = engine.stats()
        admissions = st["admissions"] - warm["admissions"]
        prefill_tokens = (st["admission_prefill_tokens"]
                          - warm["admission_prefill_tokens"])
        results[mode] = {
            "seconds": dt, "tokens": tokens, "tok_s": tokens / dt,
            "served": len(out), "expected": num,
            "admissions": admissions,
            "prefill_tok_per_admission":
                prefill_tokens / admissions if admissions else 0.0,
            "jit_compiles": engine.runner.jit_compile_count(),
            "jit_compiles_measured_run":
                engine.runner.jit_compile_count() - warm["jit_compile_count"],
        }
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-lo", type=int, default=8)
    ap.add_argument("--prompt-hi", type=int, default=24)
    ap.add_argument("--new-lo", type=int, default=4)
    ap.add_argument("--new-hi", type=int, default=24)
    ap.add_argument("--kv-prune", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON artifact path")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for the CI fast lane")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots = 6, 2
        args.prompt_lo, args.prompt_hi = 4, 8
        args.new_lo, args.new_hi = 2, 8

    res = bench(args.arch, args.requests, args.slots, args.prompt_lo,
                args.prompt_hi, args.new_lo, args.new_hi, args.kv_prune,
                args.seed)
    ok = True
    hdr = (f"{'mode':15s} {'tok/s':>8s} {'served':>8s} "
           f"{'prefill tok/admit':>18s} {'jit compiles':>13s}")
    print(hdr)
    for mode, r in res.items():
        served = f"{r['served']}/{r['expected']}"
        print(f"{mode:15s} {r['tok_s']:8.1f} {served:>8s} "
              f"{r['prefill_tok_per_admission']:18.1f} "
              f"{r['jit_compiles']:13d}")
        ok &= r["served"] == r["expected"]
    speedup = res["continuous"]["tok_s"] / res["static"]["tok_s"]
    vs_legacy = (res["continuous"]["tok_s"]
                 / res["cont-reprefill"]["tok_s"])
    print(f"continuous vs static: {speedup:.2f}x; "
          f"per-slot vs re-prefill admission: {vs_legacy:.2f}x")
    from repro.bench import write_bench_artifact
    write_bench_artifact(
        args.out, kind="serving",
        config={k: v for k, v in vars(args).items() if k != "out"},
        results=res,
        extra={"continuous_vs_static": speedup,
               "per_slot_vs_reprefill": vs_legacy},
        seed=args.seed)
    print(f"wrote {args.out}")
    if not ok:
        print("FAIL: not every request was served", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
