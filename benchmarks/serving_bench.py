"""Static-wave vs continuous-batching serving throughput.

A static wave holds every slot until the *longest* request in the wave
finishes, so skewed request lengths strand capacity; the continuous path
re-admits waiting requests into slots the moment one retires. This bench
serves an identical skewed request mix through both paths and reports
tokens/s — the continuous speedup is the scheduling win, independent of
the per-step kernel costs.

Caveat at reference scale: every admission re-prefills the batch at a new
prefix length, which jit-recompiles — on a CPU-reduced model that compile
cost dominates and continuous can *lose*. The ROADMAP open item (per-slot
prefill writes + prefix-length bucketing) removes exactly this overhead;
the bench exists to make the crossover measurable.

    PYTHONPATH=src python benchmarks/serving_bench.py            # full
    PYTHONPATH=src python benchmarks/serving_bench.py --smoke    # CI lane

``--smoke`` runs a seconds-scale configuration and exits non-zero if either
path fails to serve every request (the CI fast lane runs it so serving-path
regressions fail visibly).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def make_requests(cfg, num: int, prompt_lo: int, prompt_hi: int,
                  new_lo: int, new_hi: int, seed: int):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(num):
        plen = int(rng.integers(prompt_lo, prompt_hi + 1))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=int(rng.integers(new_lo, new_hi + 1))))
    return reqs


def bench(arch: str, num: int, slots: int, prompt_lo: int, prompt_hi: int,
          new_lo: int, new_hi: int, kv_prune: float, seed: int):
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving import EngineConfig, ServeEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ec = EngineConfig(
        max_batch=slots,
        # continuous re-prefill pads a finished-prefix slot (prompt + up to
        # new_hi generated) against a slot with up to new_hi still to go,
        # so the cache high-water mark is prompt_hi + 2*new_hi - 1
        max_len=prompt_hi + 2 * new_hi + 8,
        kv_prune_interval=4 if kv_prune < 1.0 else 0,
        kv_prune_keep=kv_prune)

    results = {}
    for mode in ("static", "continuous"):
        engine = ServeEngine(cfg, params, ec)
        reqs = make_requests(cfg, num, prompt_lo, prompt_hi,
                             new_lo, new_hi, seed)
        run = engine.run if mode == "static" else engine.run_continuous
        run(make_requests(cfg, min(num, slots), prompt_lo, prompt_hi,
                          new_lo, new_lo, seed + 1))  # warmup/compile
        t0 = time.time()
        out = run(reqs)
        dt = time.time() - t0
        tokens = sum(len(v) for v in out.values())
        results[mode] = {"seconds": dt, "tokens": tokens,
                         "tok_s": tokens / dt, "served": len(out),
                         "expected": num}
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-lo", type=int, default=8)
    ap.add_argument("--prompt-hi", type=int, default=24)
    ap.add_argument("--new-lo", type=int, default=4)
    ap.add_argument("--new-hi", type=int, default=24)
    ap.add_argument("--kv-prune", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for the CI fast lane")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots = 6, 2
        args.prompt_lo, args.prompt_hi = 4, 8
        args.new_lo, args.new_hi = 2, 8

    res = bench(args.arch, args.requests, args.slots, args.prompt_lo,
                args.prompt_hi, args.new_lo, args.new_hi, args.kv_prune,
                args.seed)
    ok = True
    for mode, r in res.items():
        served = f"{r['served']}/{r['expected']}"
        print(f"{mode:10s}: {r['tokens']:5d} tokens in {r['seconds']:6.2f}s "
              f"({r['tok_s']:7.1f} tok/s, served {served})")
        ok &= r["served"] == r["expected"]
    speedup = res["continuous"]["tok_s"] / res["static"]["tok_s"]
    print(f"continuous vs static: {speedup:.2f}x")
    if not ok:
        print("FAIL: not every request was served", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
