"""Benchmark: paper Table VI — the pruning-setting sweep.

For every (b, r_b, r_t) row of the paper we report our analytic MACs,
model size, compression ratio, and the cycle-model latency band, next to
the paper's published numbers. This is the faithful-reproduction artifact
for the paper's headline claims (3.4× MACs reduction, 1.6× compression)."""
from __future__ import annotations

from repro.configs import DEIT_SMALL, PruningConfig
from repro.core import complexity as C
from repro.core import perf_model as PM

# (b, r_b, r_t, paper_MACs_G, paper_size_Mparams, paper_latency_ms)
PAPER_ROWS = [
    (16, 1.0, 1.0, 4.27, 22.00, 3.190),
    (32, 1.0, 1.0, 4.27, 22.00, 3.550),
    (16, 0.5, 0.5, 1.32, 14.29, 0.868),
    (16, 0.5, 0.7, 1.79, 14.29, 1.169),
    (16, 0.5, 0.9, 2.43, 14.39, 1.479),
    (16, 0.7, 0.5, 1.62, 17.63, 1.140),
    (16, 0.7, 0.7, 2.20, 17.63, 1.553),
    (16, 0.7, 0.9, 2.98, 17.63, 1.953),
    (32, 0.5, 0.5, 1.25, 13.80, 1.621),
    (32, 0.5, 0.7, 1.70, 13.70, 1.796),
    (32, 0.5, 0.9, 2.31, 13.80, 1.999),
    (32, 0.7, 0.5, 1.61, 17.53, 2.126),
    (32, 0.7, 0.7, 2.16, 17.33, 2.353),
    (32, 0.7, 0.9, 2.93, 17.33, 2.590),
]


def run() -> list:
    rows = []
    dense_macs = None
    for (b, rb, rt, p_macs, p_size, p_lat) in PAPER_ROWS:
        pc = PruningConfig(block_size=b, r_b=rb, r_t=rt,
                           tdm_layers=(2, 6, 9) if rt < 1 else ())
        macs = C.model_macs(DEIT_SMALL, 1, pc)["total"] / 1e9
        size = C.model_size_bytes(DEIT_SMALL, pc) / 4e6  # fp32 M-params
        lat = PM.model_latency_ms(DEIT_SMALL, pc)
        if dense_macs is None and rb == 1.0:
            dense_macs = macs
        tag = f"b{b}_rb{rb}_rt{rt}"
        rows.append((f"table_vi.{tag}.macs_G", round(macs, 3),
                     f"paper={p_macs} delta={macs/p_macs-1:+.1%}"))
        rows.append((f"table_vi.{tag}.size_Mparams", round(size, 2),
                     f"paper={p_size}"))
        rows.append((f"table_vi.{tag}.latency_ms", round(lat["latency_ms"], 3),
                     f"paper={p_lat} band=[{lat['latency_ms']:.2f},"
                     f"{lat['latency_noverlap_ms']:.2f}]"))
    # headline claims
    best = C.model_macs(DEIT_SMALL, 1, PruningConfig(
        block_size=32, r_b=0.5, r_t=0.5, tdm_layers=(2, 6, 9)))["total"] / 1e9
    rows.append(("table_vi.headline.macs_reduction_x",
                 round(dense_macs / best, 2), "paper=3.42x"))
    ratio = C.compression_ratio(DEIT_SMALL, PruningConfig(
        block_size=16, r_b=0.5, r_t=0.5, tdm_layers=(2, 6, 9)))
    rows.append(("table_vi.headline.compression_x", round(ratio, 2),
                 "paper=1.60x (ours counts packed blocks+headers)"))
    return rows
