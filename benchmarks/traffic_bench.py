"""Traffic bench: offered-load sweep, SLOs, and the admission-control knee.

The serving benches so far measure throughput on closed request lists;
this bench asks the production question instead: **what happens past
saturation?** A seeded bursty trace (``repro.traffic.workload``) is
replayed through the ``VisionEngine`` on the harness's virtual clock at a
ladder of offered loads, expressed as multiples of the engine's measured
saturation capacity, under three arms:

    unbounded  admission off, quality strict — the pre-traffic engine
               path byte-for-byte (the harness's outputs digest is
               asserted equal to a direct ``serve()`` call).
    admission  cost-model admission control (``traffic.admission``),
               quality strict: accept-or-reject against a modeled
               backlog budget.
    degrade    the same controller on a quality-enabled engine: requests
               that would be rejected are first retried at the quality
               floor (PR 7's QualityController) — quality degrades
               before goodput does.

Past the knee (offered > capacity) unbounded queueing serves everything
but the queue — and therefore every completion's latency — grows without
bound, so *goodput* (deadline-met completions per virtual second)
collapses while throughput looks healthy. The full run asserts the two
defining properties: the admission arms' queue depth stays bounded, and
their goodput strictly dominates unbounded queueing at every past-knee
load point.

Everything is virtual-time deterministic: the cost model is deliberately
left uncalibrated (calibration fits wall clock), so the artifact's
numbers — including every admission decision — are a pure function of
(seed, trace, config). The ``BENCH_traffic.json`` envelope records the
trace fingerprint + seed + git SHA (schema v3 provenance).

    PYTHONPATH=src python benchmarks/traffic_bench.py --smoke

``--smoke`` (the CI fast lane) replays one bursty trace at 4x capacity
under the unbounded and degrade arms, checks digest equality against the
direct serve path, bounded queues, and the envelope schema.
"""
from __future__ import annotations

import argparse
import math
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import VisionEngine, VisionEngineConfig
from repro.traffic import (TraceSpec, TrafficHarness, VisionDriver,
                           make_trace, outputs_digest, trace_fingerprint)

LOAD_FACTORS = (0.5, 2.0, 4.0)   # offered load as multiples of capacity
KNEE = 1.0


def build_engine(cfg, masked, packed, slots: int, quality: str):
    return VisionEngine(
        cfg, masked, packed,
        VisionEngineConfig(max_batch=slots, planner="full", quality=quality,
                           keep_floor=0.4))


def measure_capacity_rps(cfg, masked, packed, slots, sizes, seed):
    """Saturation throughput on the virtual clock: replay a back-to-back
    trace (offered load far above any plausible capacity) and read the
    drain rate. Deterministic — it prices modeled cycles, not wall
    time."""
    eng = build_engine(cfg, masked, packed, slots, "strict")
    probe = TraceSpec(n=4 * slots, rate_rps=1e6, process="poisson",
                      sizes=sizes, r_ts=(None,), deadlines_ms=(None,))
    h = TrafficHarness(VisionDriver(eng))
    rep = h.run(make_trace(probe, seed=seed + 101))
    mean_service_ms = rep["virtual_ms"] / probe.n
    return rep["throughput_rps"], mean_service_ms, eng


def run_arm(cfg, masked, packed, slots, trace, arm, limit_ms):
    quality = "auto" if arm == "degrade" else "strict"
    eng = build_engine(cfg, masked, packed, slots, quality)
    h = TrafficHarness(VisionDriver(eng),
                       admission_limit_ms=(None if arm == "unbounded"
                                           else limit_ms))
    rep = h.run(trace)
    rep["arm"] = arm
    return rep, h


def bench(arch: str, num: int, slots: int, seed: int, smoke: bool):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    from repro.core import packed_runner as PR
    packed = PR.pack_model(cfg, params, scores)

    side = cfg.image_size // cfg.patch_size
    sizes = tuple(sorted({max(1, side - 1) ** 2, side ** 2}))
    capacity_rps, mean_service_ms, cap_engine = measure_capacity_rps(
        cfg, masked, packed, slots, sizes, seed)

    # SLO + budget geometry. Two different units on purpose: the
    # admission budget (``limit_ms``) is in MODELED solo ms — the units
    # the controller prices backlog in — while the deadline is in the
    # harness's virtual ms, anchored to the measured saturated service
    # time. A ~2-solo backlog budget drains in a few service times, so
    # admitted requests land inside a 6-service-time SLO; an unbounded
    # queue at 4x offered load pushes tail waits to ~9 service times and
    # blows through it. (Solo pricing overstates drain time — batching
    # and lane fusion make real steps cheaper — which only makes the
    # admitted arm's deadlines safer.)
    from repro.serving.vision import VisionRequest
    probe_req = VisionRequest(uid=-1, patches=np.zeros(
        (sizes[-1], cfg.patch_size ** 2 * 3), np.float32))
    solo_ms = cap_engine.modeled_request_ms(probe_req)
    limit_ms = 2.0 * solo_ms
    deadline_ms = 6.0 * mean_service_ms

    factors = (4.0,) if smoke else LOAD_FACTORS
    arms = ("unbounded", "degrade") if smoke else ("unbounded", "admission",
                                                   "degrade")
    results = {"capacity_rps": capacity_rps, "solo_ms": solo_ms,
               "mean_service_ms": mean_service_ms, "limit_ms": limit_ms,
               "deadline_ms": deadline_ms, "loads": {}}
    fingerprints = {}
    ok = True
    for lf in factors:
        spec = TraceSpec(n=num, rate_rps=lf * capacity_rps,
                         process="bursty", sizes=sizes, r_ts=(None,),
                         deadlines_ms=(deadline_ms,))
        trace = make_trace(spec, seed=seed)
        fingerprints[f"x{lf:g}"] = trace_fingerprint(trace)
        point = {}
        for arm in arms:
            rep, h = run_arm(cfg, masked, packed, slots, trace, arm,
                             limit_ms)
            point[arm] = rep
            print(f"  load {lf:g}x {arm:>9}: completed={rep['completed']} "
                  f"rejected={rep['rejected']} "
                  f"goodput={rep['goodput_rps']:.1f}/s "
                  f"p50={rep['latency_p50_ms']:.2f}ms "
                  f"p99={rep['latency_p99_ms']:.2f}ms "
                  f"miss={rep['deadline_miss_rate']:.0%} "
                  f"peakq={rep['peak_queue_depth']}")
            if arm == "unbounded" and lf == factors[0]:
                # pre-PR equivalence: the harness with admission off must
                # serve byte-identical outputs to a direct engine.serve()
                # on the same materialized requests
                eng = build_engine(cfg, masked, packed, slots, "strict")
                drv = VisionDriver(eng)
                direct = eng.serve([drv.materialize(t)
                                    for t in trace.requests])
                same = outputs_digest(direct) == rep["outputs_digest"]
                point["harness_matches_direct_serve"] = same
                print(f"  load {lf:g}x harness==direct serve: {same}")
                ok &= same
        results["loads"][f"x{lf:g}"] = point

        if lf > KNEE:
            unb = point["unbounded"]
            for arm in arms[1:]:
                adm = point[arm]
                dominates = adm["goodput_rps"] > unb["goodput_rps"]
                bounded = (adm["peak_queue_depth"]
                           < unb["peak_queue_depth"])
                print(f"  load {lf:g}x {arm}: goodput dominates unbounded="
                      f"{dominates} queue bounded={bounded}")
                ok &= dominates and bounded

    return results, fingerprints, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-small")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast lane: one bursty trace at 4x capacity, "
                         "unbounded vs degrade arms")
    ap.add_argument("--out", default="BENCH_traffic.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 12)

    res, fps, ok = bench(args.arch, args.requests, args.slots, args.seed,
                         args.smoke)

    from repro.bench import load_bench_artifact, write_bench_artifact
    # the sweep replays several traces; the envelope's provenance slot
    # records the first (the knee trace), the full set rides in extra
    first_fp = next(iter(fps.values()))
    write_bench_artifact(
        args.out, kind="traffic",
        config={k: v for k, v in vars(args).items() if k != "out"},
        results=res,
        extra={"trace_fingerprints": fps, "assertions_ok": ok},
        seed=args.seed, trace_fingerprint=first_fp)
    load_bench_artifact(args.out, expect_kind="traffic")  # self-check
    print(f"wrote {args.out} (trace {first_fp[:12]}..., "
          f"assertions_ok={ok})")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
