"""Execution planning for ragged ViT serving: naive padding vs balanced
bucketing vs the cost-model-driven TilePlanner.

The packed ViT's token pruning leaves the in-flight population ragged:
images enter at different resolutions and shed tokens at every TDM layer at
their own keep rates. This bench serves identical request streams through
the ``VisionEngine`` under the batching/planning strategies, over two
scenarios:

* ``mixed``  — the PR-4 workload: skewed resolution mix, dense arrivals.
  Modes: ``naive`` (pad-to-max batch), ``balanced`` (RaggedBatcher exact
  buckets, planner off), ``planned`` (TilePlanner — ``--planner`` selects
  merge/fuse/full; ``off`` makes this an A/A control).
* ``sparse`` — singleton-heavy: every request has a distinct patch count
  and arrivals are spread out, so buckets almost never batch and the
  balanced path pays one dispatch per segment per image. This is the
  express-lane case: the planner fuses each bucket-singleton's remaining
  trajectory into ONE jitted program. Modes: ``balanced`` vs ``planned``.

Before the timed windows (full runs), the bench calibrates the planner's
``TileCostModel`` from measured dispatch timings
(``TileCostModel.calibrate``), so merge decisions trade measured host
dispatch overhead against modeled padding cost instead of the FPGA-era
default constant.

Reported per mode: throughput (images/s and token·segment cells/s), padding
waste, the recompile-discipline columns (jit compiles vs the bucket ∪
trajectory budget), and the plan-stats columns (merge count, fused-lane
count, deadline dispatches, modeled saving).

    PYTHONPATH=src python benchmarks/vision_bench.py                # full
    PYTHONPATH=src python benchmarks/vision_bench.py --smoke        # CI lane
    PYTHONPATH=src python benchmarks/vision_bench.py --smoke --planner off

Every timed arm runs at ``--pipeline-depth`` (1 = synchronous) and under
``--quality`` / ``--keep-floor`` (the QualityController: ``strict`` = off,
the bit-exact control CI also runs; ``degrade``/``auto`` enable keep-rate
tightening). A quality Pareto block always sweeps the ``degrade`` floor
over the keep-level grid on a uniform-rate stream and asserts the
elasticity property: modeled latency strictly decreases as the floor
tightens, recompiles stay within the bucket ∪ trajectory budget, and a
top-1 agreement column proxies the accuracy cost. A cross-depth
comparison block always serves the planned mixed arm at depths
1 and 2: outputs must be bit-identical (sha256 ``outputs_digest`` — the CI
fast lane also compares digests between whole ``--pipeline-depth 1`` and
``2`` artifacts), and the ``wall_vs_device`` / ``device_idle_s`` columns
quantify how much host time the double-buffered pipeline hides behind
device execution.

A ``BENCH_vision.json`` artifact is written through the schema-versioned
``repro.bench`` envelope shared with serving_bench.py (``--out``
overrides). Exit is non-zero if any mode fails to serve every request,
exceeds its recompile budget, or disagrees across pipeline depths; the
full run additionally requires balanced bucketing to beat naive padding,
``--planner full`` to be at least as fast as balanced on the mixed
workload, strictly faster on the sparse singleton-heavy scenario (the
planner's acceptance claims), and pipeline depth 2 to idle the device
strictly less than depth 1.
"""
from __future__ import annotations

import argparse
import sys
import time


def make_requests(cfg, num: int, arrival_spread: int, seed: int,
                  unique_sizes: bool = False):
    from repro.launch.serve_vision import make_requests as _mk

    if unique_sizes:
        # the sparse scenario: every patch count distinct -> every bucket a
        # singleton; arrivals spread so the population stays thin
        return _mk(cfg, num, arrival_spread, seed,
                   r_ts=[0.5, cfg.pruning.r_t], unique_sizes=True)
    # the launcher's stream generator, skewed toward small images (the
    # realistic mix where naive padding hurts: most requests pay the
    # largest in-flight image's cost)
    return _mk(cfg, num, arrival_spread, seed,
               r_ts=[0.5, cfg.pruning.r_t],
               size_weights=[0.5, 0.3, 0.2])


def calibrate_cost_model(cfg, masked, packed, cost_model, seed: int,
                         reps: int = 3):
    """Fit the cost model's dispatch-overhead constant and cycle->seconds
    scale from measured wall-clock dispatch timings (the satellite hook:
    ``TileCostModel.calibrate``). Probes a jitted encoder segment at two
    batch widths on a THROWAWAY executor so the serving engines' compile
    ledgers stay untouched."""
    import jax
    import numpy as np

    from repro.core import packed_runner as PR
    from repro.serving import Tile

    probe = PR.PackedVitSegments(cfg, masked, packed)
    seg = next(s for s in probe.plan if s[0] == "layers")
    si = probe.plan.index(seg)
    rng = np.random.default_rng(seed)
    n = 16
    samples = []
    for b in (1, 8):
        x = rng.standard_normal((b, n, cfg.d_model)).astype(np.float32)
        jax.block_until_ready(probe.run(seg, x))  # compile outside timing
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(probe.run(seg, x))
            times.append(time.perf_counter() - t0)
        tile = Tile(stage=(si, seg, None), members=tuple(range(b)),
                    n_tokens=(n,) * b, n_tile=n, b_tile=b)
        samples.append((cost_model.tile_work_cycles(tile), min(times)))
    return cost_model.calibrate(samples)


def outputs_digest(out) -> str:
    """Order-independent sha256 over every served logit vector — equal
    digests mean bit-identical outputs (the cross-depth CI check compares
    these between ``--pipeline-depth 1`` and ``2`` artifacts)."""
    import hashlib

    import numpy as np

    h = hashlib.sha256()
    for uid in sorted(out):
        h.update(np.asarray(out[uid], np.float32).tobytes())
    return h.hexdigest()


def run_mode(cfg, masked, packed, cost_model, reqs_factory, *, slots: int,
             bmode: str, planner: str, pipeline_depth: int = 1,
             quality: str = "strict", keep_floor: float = 0.4,
             precision: str = "fp32",
             tracer=None, registry=None, metrics_prefix: str = "vision"):
    """Serve the stream twice (warmup compiles every shape on the identical
    stream — arrival dynamics replay exactly) and time the second pass.
    ``tracer`` (repro.obs) records wall-clock plan/stage/dispatch/complete
    spans; ``registry`` receives the engine's end-of-run metrics under
    ``metrics_prefix``. Both observe only — results and digests are
    identical with or without them."""
    from repro.serving import VisionEngine, VisionEngineConfig

    vc = VisionEngineConfig(max_batch=slots, mode=bmode, token_tile=1,
                            planner=planner, pipeline_depth=pipeline_depth,
                            quality=quality, keep_floor=keep_floor,
                            precision=precision)
    engine = VisionEngine(cfg, masked, packed, vc, cost_model=cost_model,
                          tracer=tracer)
    engine.serve(reqs_factory())
    warm = engine.stats()
    reqs = reqs_factory()
    t0 = time.time()
    out = engine.serve(reqs)
    dt = time.time() - t0
    st = engine.stats()
    real = (st["batcher_real_cells"] - warm["batcher_real_cells"]
            + st["plan_lane_cells"] - warm["plan_lane_cells"])
    # device-busy proxy: at depth 1 the host dispatches then immediately
    # blocks, so dispatch + block wall time brackets the device's work;
    # wall_vs_device > 1 is host overhead the pipeline can hide
    busy = (st["pipeline_block_s"] - warm["pipeline_block_s"]
            + st["pipeline_dispatch_s"] - warm["pipeline_dispatch_s"])
    if registry is not None:
        engine.export_metrics(registry, prefix=metrics_prefix)
    return {
        "seconds": dt,
        "images_s": len(out) / dt,
        "cells_s": real / dt,
        "outputs_digest": outputs_digest(out),
        "pipeline_depth": pipeline_depth,
        "pipeline_block_s": st["pipeline_block_s"] - warm["pipeline_block_s"],
        "wall_vs_device": dt / max(busy, 1e-9),
        "served": len(out), "expected": len(reqs),
        "padding_waste": st["batcher_padding_waste"],
        "buckets": st["bucket_count"],
        "trajectories": st["trajectory_count"],
        "compile_budget": st["compile_budget"],
        "jit_compiles": st["jit_compile_count"],
        "recompile_bound_ok":
            st["jit_compile_count"] <= st["compile_budget"],
        # plan-stats columns (schema: vision kind, v1 envelope)
        "planner": st["plan_mode"],
        "merge_count": st["plan_merges"],
        "fused_lane_count": st["plan_lanes"],
        "fused_segments": st["plan_fused_segments"],
        "deadline_dispatches": st["plan_deadline_urgent"],
        "modeled_saving_ms": st["plan_modeled_saving_ms"],
        "calibrated": st["plan_calibrated"],
        # quantized-serving columns (fp32 arms: tier dispatches only)
        "precision": st["precision"],
        "dequant_dispatches": st["dequant_dispatches"],
    }


def quality_pareto(cfg, masked, packed, cost_model, reqs_factory, *,
                   slots: int, planner: str, registry=None):
    """The quality-elasticity Pareto sweep: serve the identical stream at
    progressively tighter keep floors (``degrade`` mode pins every
    consenting request to the lowest usable grid level, so each arm IS one
    floor) and report modeled latency vs a top-1 agreement accuracy proxy
    against the ``strict`` (controller off) arm.

    ``modeled_ms`` is a deterministic end-to-end price of the stream under
    each arm's resolved schedules — the Pareto x-axis the acceptance
    criterion asserts on (strictly decreasing as the floor tightens),
    immune to shared-CI wall-clock noise. It is priced at token
    resolution (the cost model's attention-shaped proxy, quadratic +
    linear in the token count); the paper's accelerator tile model
    (``modeled_tile_ms``, also reported) quantizes token counts to tile
    boundaries, which ties neighboring keep counts at smoke scale and
    would hide real load reductions. Recompiles must stay within the
    bucket ∪ trajectory budget in every arm: the controller only resolves
    onto the quantized grid."""
    import numpy as np

    from repro.serving import TileCostModel, VisionEngine, VisionEngineConfig

    # cfg=None -> every stage priced by the token-resolution proxy; same
    # overhead/scale as the (possibly calibrated) tile model
    proxy_cm = TileCostModel(
        None, dispatch_overhead_cycles=cost_model.dispatch_overhead_cycles,
        seconds_per_cycle=cost_model.seconds_per_cycle)
    levels = (1.0, 0.8, 0.65, 0.5, 0.35)
    arms = [("strict", "strict", 0.35)] + [
        (f"floor={f}", "degrade", f) for f in (0.65, 0.5, 0.35)]
    rows = []
    base_top1 = None
    for name, qmode, floor in arms:
        vc = VisionEngineConfig(max_batch=slots, mode="balanced",
                                token_tile=1, planner=planner,
                                quality=qmode, keep_levels=levels,
                                keep_floor=floor)
        eng = VisionEngine(cfg, masked, packed, vc, cost_model=cost_model)
        eng.serve(reqs_factory())  # warmup compiles the arm's shapes
        reqs = reqs_factory()
        t0 = time.time()
        out = eng.serve(reqs)
        dt = time.time() - t0
        st = eng.stats()
        # price the whole stream under this arm's resolved schedules
        # (degrade resolution is pressure-independent, so the host-side
        # replay here matches what the engine dispatched)
        q = eng.planner.quality
        modeled = tile_modeled = 0.0
        for r in reqs:
            eff = q.resolve(eng._base_schedule(r), preference=r.quality)
            traj = eng._traj_from(0, r.n_patches, eff, r.soft_prune)
            modeled += proxy_cm.ms(proxy_cm.trajectory_cycles(traj))
            tile_modeled += cost_model.ms(
                cost_model.trajectory_cycles(traj))
        top1 = {u: int(np.argmax(lg)) for u, lg in out.items()}
        if base_top1 is None:
            base_top1 = top1
        if registry is not None:
            # per-floor quality-tighten counters (schema-v4 metrics block)
            pfx = f"pareto.floor_{floor:g}" if qmode != "strict" \
                else "pareto.strict"
            registry.gauge(f"{pfx}.tightened_steps").set(
                st["quality_tightened"])
            for lvl, n in sorted(q.level_counts.items()):
                registry.gauge(
                    f"{pfx}.quality_tightened_level_{lvl:g}").set(n)
        rows.append({
            "arm": name, "quality": qmode, "keep_floor": floor,
            "keep_levels": list(levels),
            "modeled_ms": modeled,
            "modeled_tile_ms": tile_modeled,
            "seconds": dt, "images_s": len(out) / dt,
            "top1_agreement": (sum(top1[u] == base_top1[u] for u in top1)
                               / max(len(top1), 1)),
            "served": len(out), "expected": len(reqs),
            "tightened_steps": st["quality_tightened"],
            "levels_used": list(st["quality_levels_used"]),
            "jit_compiles": st["jit_compile_count"],
            "compile_budget": st["compile_budget"],
            "recompile_bound_ok":
                st["jit_compile_count"] <= st["compile_budget"],
        })
    return rows


def precision_compare(cfg, masked, packed, cost_model, reqs_factory, *,
                      slots: int, planner: str, pipeline_depth: int = 1,
                      quality: str = "strict", keep_floor: float = 0.4,
                      registry=None):
    """The quantized-serving accuracy/latency gate: serve the identical
    mixed stream through engines at every precision tier and report, per
    tier, top-1 agreement against the fp32 arm (the accuracy proxy the
    acceptance criterion gates at >= 0.98), the modeled end-to-end latency
    of the stream under the tier's dispatched precisions (deterministic —
    the cost model prices each request's trajectory exactly as the planner
    did at admission, so int8 < fp32 is a cycle-model fact, immune to CI
    wall-clock noise), the measured weight-quantization error, and the
    packed model bytes at the tier. The fp32 arm doubles as the
    no-regression control: its ``outputs_digest`` must equal the planned
    mixed arm's whenever that arm also runs fp32 (the pre-quantization
    serving path, byte-identical stage keys and all)."""
    import numpy as np

    from repro.serving import VisionEngine, VisionEngineConfig

    rows = []
    base_top1 = None
    for tier in ("fp32", "fp16", "int8"):
        vc = VisionEngineConfig(max_batch=slots, mode="balanced",
                                token_tile=1, planner=planner,
                                pipeline_depth=pipeline_depth,
                                quality=quality, keep_floor=keep_floor,
                                precision=tier)
        eng = VisionEngine(cfg, masked, packed, vc, cost_model=cost_model)
        eng.serve(reqs_factory())  # warmup compiles the tier's shapes
        reqs = reqs_factory()
        t0 = time.time()
        out = eng.serve(reqs)
        dt = time.time() - t0
        st = eng.stats()
        # modeled stream latency under the precisions the planner actually
        # dispatched (strict requests pin fp32; the rest price at the tier)
        modeled = 0.0
        for r in reqs:
            prec = eng._precision_for(r)
            traj = eng._traj_from(0, r.n_patches, eng._base_schedule(r),
                                  r.soft_prune, precision=prec)
            modeled += cost_model.ms(cost_model.trajectory_cycles(traj))
        top1 = {u: int(np.argmax(lg)) for u, lg in out.items()}
        if base_top1 is None:
            base_top1 = top1
        agreement = (sum(top1[u] == base_top1[u] for u in top1)
                     / max(len(top1), 1))
        rep = eng.quantization_report()
        if registry is not None:
            registry.gauge(f"precision.top1_agreement_{tier}").set(agreement)
            registry.gauge(f"precision.modeled_ms_{tier}").set(modeled)
            registry.gauge(f"precision.quant_max_abs_error_{tier}").set(
                rep["quant_max_abs_error"])
            registry.gauge(f"precision.packed_bytes_{tier}").set(
                rep["packed_bytes"])
        rows.append({
            "precision": tier,
            "granularity": rep["granularity"],
            "modeled_ms": modeled,
            "seconds": dt, "images_s": len(out) / dt,
            "top1_agreement": agreement,
            "quant_max_abs_error": rep["quant_max_abs_error"],
            "packed_bytes": rep["packed_bytes"],
            "outputs_digest": outputs_digest(out),
            "served": len(out), "expected": len(reqs),
            "dispatches": {p: st[f"dispatch_{p}"]
                           for p in ("fp32", "fp16", "int8")},
            "dequant_dispatches": st["dequant_dispatches"],
            "precision_decisions": {
                p: st[f"plan_precision_{p}"]
                for p in ("fp32", "fp16", "int8")},
            "jit_compiles": st["jit_compile_count"],
            "compile_budget": st["compile_budget"],
            "recompile_bound_ok":
                st["jit_compile_count"] <= st["compile_budget"],
        })
    return rows


def pipeline_compare(cfg, masked, packed, cost_model, reqs_factory, *,
                     slots: int, planner: str):
    """Serve the identical mixed stream through the planned arm at
    pipeline depth 1 (synchronous) and 2 (double-buffered): outputs must
    be bit-identical, and the ``wall_vs_device`` column shows how much
    host overhead sits on top of the depth-1 device-busy proxy (dispatch
    + block — at depth 1 the host blocks right after each dispatch, so
    that sum brackets the device's work). ``device_idle_s`` is the
    pipeline's measured starvation time: wall seconds with zero steps in
    flight, i.e. the device waiting while the host plans/stages. Depth 2
    keeps a step queued across every stage window, so on the full bench
    it must idle the device strictly less than depth 1 — a queue-
    occupancy fact that holds even on shared-core CPU hosts where
    overlap cannot shrink the wall clock itself."""
    from repro.serving import VisionEngine, VisionEngineConfig

    rows = {}
    for depth in (1, 2):
        vc = VisionEngineConfig(max_batch=slots, mode="balanced",
                                token_tile=1, planner=planner,
                                pipeline_depth=depth)
        engine = VisionEngine(cfg, masked, packed, vc,
                              cost_model=cost_model)
        engine.serve(reqs_factory())
        warm = engine.stats()
        t0 = time.time()
        out = engine.serve(reqs_factory())
        wall = time.time() - t0
        st = engine.stats()
        rows[f"depth{depth}"] = {
            "wall_s": wall,
            "block_s": st["pipeline_block_s"] - warm["pipeline_block_s"],
            "dispatch_s": (st["pipeline_dispatch_s"]
                           - warm["pipeline_dispatch_s"]),
            "steps": st["pipeline_steps"] - warm["pipeline_steps"],
            "overlap_hits": (st["pipeline_overlap_hits"]
                             - warm["pipeline_overlap_hits"]),
            "device_idle_s": (st["pipeline_starved_s"]
                              - warm["pipeline_starved_s"]),
            "plan_ahead_hits": st["plan_ahead_hits"],
            "served": len(out),
            "outputs_digest": outputs_digest(out),
        }
    busy_ref = rows["depth1"]["block_s"] + rows["depth1"]["dispatch_s"]
    for r in rows.values():
        r["wall_vs_device"] = r["wall_s"] / max(busy_ref, 1e-9)
    rows["bitexact"] = (rows["depth1"]["outputs_digest"]
                        == rows["depth2"]["outputs_digest"])
    return rows


def bench(arch: str, num: int, slots: int, arrival_spread: int,
          image_size: int, d_model: int, seed: int, planner: str,
          calibrate: bool, pipeline_depth: int = 1,
          quality: str = "strict", keep_floor: float = 0.4,
          precision: str = "fp32", tracer=None, registry=None):
    import jax

    from repro.configs import get_config
    from repro.core import packed_runner as PR
    from repro.models import model as M
    from repro.models import pruning_glue as PG
    from repro.serving import TileCostModel

    # reduced() shrinks depth/width for CPU; image_size and d_model set
    # the per-cell compute — big enough that cell count (not dispatch
    # overhead) dominates, which is where load balancing pays
    cfg = get_config(arch).reduced().replace(image_size=image_size)
    if d_model:
        cfg = cfg.replace(d_model=d_model, d_ff=2 * d_model,
                          head_dim=d_model // cfg.num_heads)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)

    cost_model = TileCostModel(cfg)
    fit = None
    if calibrate:
        fit = calibrate_cost_model(cfg, masked, packed, cost_model, seed)

    mixed = lambda: make_requests(cfg, num, arrival_spread, seed)
    sparse = lambda: make_requests(cfg, num, max(2 * num, arrival_spread),
                                   seed + 1, unique_sizes=True)
    # the Pareto stream runs every request at the config keep rate so each
    # sweep floor below it actually tightens (mixed per-request rates would
    # leave sub-floor requests untouched and flatten the curve)
    from repro.launch.serve_vision import make_requests as _mk
    pareto = lambda: _mk(cfg, num, arrival_spread, seed + 2, r_ts=[None],
                         size_weights=[0.5, 0.3, 0.2])
    results = {"mixed": {}, "sparse": {}}
    for mode, bmode, pmode in (("naive", "naive", "off"),
                               ("balanced", "balanced", "off"),
                               ("planned", "balanced", planner)):
        # the planned mixed arm is the bench's headline configuration —
        # it is the one that carries the trace and the metrics snapshot
        planned = mode == "planned"
        results["mixed"][mode] = run_mode(
            cfg, masked, packed, cost_model, mixed,
            slots=slots, bmode=bmode, planner=pmode,
            pipeline_depth=pipeline_depth,
            quality=quality, keep_floor=keep_floor, precision=precision,
            tracer=tracer if planned else None,
            registry=registry if planned else None)
    for mode, pmode in (("balanced", "off"), ("planned", planner)):
        results["sparse"][mode] = run_mode(
            cfg, masked, packed, cost_model, sparse,
            slots=slots, bmode="balanced", planner=pmode,
            pipeline_depth=pipeline_depth,
            quality=quality, keep_floor=keep_floor, precision=precision)
    results["pipeline"] = pipeline_compare(
        cfg, masked, packed, cost_model, mixed, slots=slots,
        planner=planner)
    results["quality_pareto"] = quality_pareto(
        cfg, masked, packed, cost_model, pareto, slots=slots,
        planner=planner, registry=registry)
    results["precision_compare"] = precision_compare(
        cfg, masked, packed, cost_model, mixed, slots=slots,
        planner=planner, pipeline_depth=pipeline_depth, quality=quality,
        keep_floor=keep_floor, registry=registry)
    return results, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-small")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arrival-spread", type=int, default=4,
                    help="admission staggered over this many engine steps")
    ap.add_argument("--image-size", type=int, default=64,
                    help="reduced-config image size (token load knob)")
    ap.add_argument("--d-model", type=int, default=128,
                    help="reduced-config width override (0 = keep)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--planner", default="full",
                    choices=("off", "merge", "fuse", "full"),
                    help="TilePlanner mode for the 'planned' arm (off = "
                         "A/A control against balanced)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="StepPipeline depth for every timed arm (1 = "
                         "synchronous; 2 = stage N+1 while the device "
                         "runs N). The cross-depth comparison block "
                         "always runs at both depths regardless.")
    ap.add_argument("--quality", default="strict",
                    choices=("strict", "auto", "degrade"),
                    help="QualityController mode for the timed arms "
                         "(strict = off, bit-exact control; the Pareto "
                         "sweep block always runs its own strict + "
                         "degrade-floor arms regardless)")
    ap.add_argument("--keep-floor", type=float, default=0.4,
                    help="controller keep-rate floor for the timed arms "
                         "(no request is tightened below it)")
    ap.add_argument("--precision", default="fp32",
                    choices=("fp32", "fp16", "int8"),
                    help="serving precision tier for the timed arms "
                         "(fp32 = the bit-exact reference path; the "
                         "precision_compare block always runs all three "
                         "tiers regardless)")
    ap.add_argument("--out", default="BENCH_vision.json",
                    help="JSON artifact path")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Chrome trace_event JSON (Perfetto-"
                         "loadable) of the planned mixed arm's plan/"
                         "stage/dispatch/complete spans; tracing observes "
                         "only — outputs_digest is identical with it on "
                         "or off (CI asserts this)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for the CI fast lane (skips "
                         "cost-model calibration and perf assertions)")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots = 8, 4
        args.arrival_spread, args.image_size, args.d_model = 3, 32, 0

    from repro.obs import MetricsRegistry, Tracer
    tracer = Tracer() if args.trace_out else None
    registry = MetricsRegistry()
    res, fit = bench(args.arch, args.requests, args.slots,
                     args.arrival_spread, args.image_size, args.d_model,
                     args.seed, args.planner, calibrate=not args.smoke,
                     pipeline_depth=args.pipeline_depth,
                     quality=args.quality, keep_floor=args.keep_floor,
                     precision=args.precision,
                     tracer=tracer, registry=registry)
    if args.trace_out:
        tracer.write_chrome_trace(args.trace_out)
        print(f"wrote {args.trace_out} ({tracer.event_count} trace events)")
    if fit:
        print(f"cost model calibrated: overhead="
              f"{fit['dispatch_overhead_cycles']:.0f} cycles "
              f"({fit['overhead_seconds'] * 1e6:.0f}us), "
              f"r2={fit['r2']:.3f}")
    ok = True
    hdr = (f"{'scenario':9s} {'mode':9s} {'img/s':>8s} {'cells/s':>10s} "
           f"{'served':>7s} {'waste':>7s} {'jit<=budget':>11s} "
           f"{'merges':>6s} {'lanes':>6s} {'save_ms':>8s}")
    print(hdr)
    for scen, modes in res.items():
        if scen in ("pipeline", "quality_pareto", "precision_compare"):
            continue
        for mode, r in modes.items():
            served = f"{r['served']}/{r['expected']}"
            budget = f"{r['jit_compiles']}<={r['compile_budget']}"
            print(f"{scen:9s} {mode:9s} {r['images_s']:8.2f} "
                  f"{r['cells_s']:10.0f} {served:>7s} "
                  f"{r['padding_waste']:7.1%} {budget:>11s} "
                  f"{r['merge_count']:6d} {r['fused_lane_count']:6d} "
                  f"{r['modeled_saving_ms']:8.2f}")
            ok &= r["served"] == r["expected"]
            ok &= r["recompile_bound_ok"]

    mixed, sparse = res["mixed"], res["sparse"]
    bal_naive = mixed["balanced"]["images_s"] / mixed["naive"]["images_s"]
    plan_mixed = mixed["planned"]["images_s"] / mixed["balanced"]["images_s"]
    plan_sparse = (sparse["planned"]["images_s"]
                   / sparse["balanced"]["images_s"])
    measured_saving_ms = (sparse["balanced"]["seconds"]
                          - sparse["planned"]["seconds"]) * 1e3
    print(f"balanced vs naive (mixed): {bal_naive:.2f}x images/s; padding "
          f"waste {mixed['naive']['padding_waste']:.1%} -> "
          f"{mixed['balanced']['padding_waste']:.1%}")
    print(f"planner={args.planner} vs balanced: {plan_mixed:.2f}x (mixed), "
          f"{plan_sparse:.2f}x (sparse); sparse saving modeled="
          f"{sparse['planned']['modeled_saving_ms']:.1f}ms measured="
          f"{measured_saving_ms:.1f}ms")
    pareto = res["quality_pareto"]
    print(f"{'pareto arm':12s} {'modeled_ms':>10s} {'img/s':>8s} "
          f"{'top1_agree':>10s} {'tightened':>9s} {'jit<=budget':>11s}")
    for row in pareto:
        budget = f"{row['jit_compiles']}<={row['compile_budget']}"
        print(f"{row['arm']:12s} {row['modeled_ms']:10.4f} "
              f"{row['images_s']:8.2f} {row['top1_agreement']:10.2f} "
              f"{row['tightened_steps']:9d} {budget:>11s}")
        ok &= row["served"] == row["expected"]
        ok &= row["recompile_bound_ok"]
    # the quality-elasticity acceptance property: tightening the keep
    # floor must strictly shrink the modeled latency of the stream, in
    # smoke and full runs alike (it is a deterministic cost-model fact)
    pareto_monotone = all(
        a["modeled_ms"] > b["modeled_ms"]
        for a, b in zip(pareto, pareto[1:]))
    print(f"pareto modeled latency strictly decreasing as keep floor "
          f"tightens: {pareto_monotone}")
    ok &= pareto_monotone

    # quantized-serving gate: every tier serves the stream, stays within
    # its recompile budget, agrees with fp32 on >= 98% of top-1 labels,
    # and int8's modeled stream latency is strictly below fp32's (the
    # planner-facing claim: the cheaper tier is really priced cheaper)
    prec_rows = res["precision_compare"]
    by_tier = {row["precision"]: row for row in prec_rows}
    print(f"{'precision':10s} {'modeled_ms':>10s} {'img/s':>8s} "
          f"{'top1_agree':>10s} {'max|dW|':>9s} {'packed_MB':>9s} "
          f"{'dequant':>7s} {'jit<=budget':>11s}")
    for row in prec_rows:
        budget = f"{row['jit_compiles']}<={row['compile_budget']}"
        print(f"{row['precision']:10s} {row['modeled_ms']:10.4f} "
              f"{row['images_s']:8.2f} {row['top1_agreement']:10.2f} "
              f"{row['quant_max_abs_error']:9.5f} "
              f"{row['packed_bytes'] / 1e6:9.3f} "
              f"{row['dequant_dispatches']:7d} {budget:>11s}")
        ok &= row["served"] == row["expected"]
        ok &= row["recompile_bound_ok"]
        if row["top1_agreement"] < 0.98:
            print(f"FAIL: {row['precision']} top-1 agreement "
                  f"{row['top1_agreement']:.3f} < 0.98", file=sys.stderr)
            ok = False
    if by_tier["int8"]["modeled_ms"] >= by_tier["fp32"]["modeled_ms"]:
        print(f"FAIL: int8 modeled latency "
              f"({by_tier['int8']['modeled_ms']:.4f}ms) must be strictly "
              f"below fp32 ({by_tier['fp32']['modeled_ms']:.4f}ms)",
              file=sys.stderr)
        ok = False
    if args.precision == "fp32" and (
            by_tier["fp32"]["outputs_digest"]
            != mixed["planned"]["outputs_digest"]):
        print("FAIL: fp32 precision_compare arm digest differs from the "
              "planned mixed arm — the fp32 serving path regressed",
              file=sys.stderr)
        ok = False

    pipe = res["pipeline"]
    d1, d2 = pipe["depth1"], pipe["depth2"]
    print(f"pipeline (planned, mixed): depth1 wall={d1['wall_s']:.3f}s "
          f"wall_vs_device={d1['wall_vs_device']:.2f} "
          f"idle={d1['device_idle_s'] * 1e3:.0f}ms | depth2 "
          f"wall={d2['wall_s']:.3f}s "
          f"wall_vs_device={d2['wall_vs_device']:.2f} "
          f"idle={d2['device_idle_s'] * 1e3:.0f}ms "
          f"overlap={d2['overlap_hits']}/{d2['steps']} "
          f"bitexact={pipe['bitexact']}")
    ok &= pipe["bitexact"]

    from repro.bench import write_bench_artifact
    write_bench_artifact(
        args.out, kind="vision",
        config={k: v for k, v in vars(args).items()
                if k not in ("out", "trace_out")},
        results=res,
        extra={"balanced_vs_naive": bal_naive,
               "planned_vs_balanced_mixed": plan_mixed,
               "planned_vs_balanced_sparse": plan_sparse,
               "sparse_measured_saving_ms": measured_saving_ms,
               "calibration": fit},
        seed=args.seed,
        metrics=registry.snapshot())
    print(f"wrote {args.out}")
    if not ok:
        print("FAIL: unserved requests, recompile budget exceeded, "
              "pipeline depths disagreed bit-for-bit, or the quality "
              "Pareto sweep was not strictly monotone", file=sys.stderr)
        sys.exit(1)
    if not args.smoke:
        if bal_naive <= 1.0:
            print(f"FAIL: balanced bucketing ({bal_naive:.2f}x) did not "
                  f"beat naive padding", file=sys.stderr)
            sys.exit(1)
        if args.planner != "off" and plan_mixed < 1.0:
            print(f"FAIL: planner {args.planner} ({plan_mixed:.2f}x) lost "
                  f"to balanced on the mixed workload", file=sys.stderr)
            sys.exit(1)
        if args.planner in ("fuse", "full") and plan_sparse <= 1.0:
            print(f"FAIL: planner {args.planner} ({plan_sparse:.2f}x) must "
                  f"be strictly faster than balanced on the sparse "
                  f"singleton-heavy scenario", file=sys.stderr)
            sys.exit(1)
        if d2["device_idle_s"] >= d1["device_idle_s"]:
            print(f"FAIL: pipeline depth 2 must idle the device strictly "
                  f"less than depth 1 "
                  f"({d2['device_idle_s'] * 1e3:.0f}ms >= "
                  f"{d1['device_idle_s'] * 1e3:.0f}ms)", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
