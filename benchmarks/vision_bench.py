"""Naive padded batching vs load-balanced ragged bucketing for ViT serving.

The packed ViT's token pruning leaves the in-flight population ragged:
images enter at different resolutions and shed tokens at every TDM layer at
their own keep rates. This bench serves an identical mixed request stream
through the ``VisionEngine`` under both batching strategies:

* ``naive``    — per segment, one tile padded to the largest member's token
  count and to the full slot width (the classic padded batch). Small
  images pay the largest image's quadratic attention cost.
* ``balanced`` — the ``RaggedBatcher`` regroups into dense token-count
  buckets (the software twin of the paper's load balancing across PE
  lanes); with ``token_tile=1`` results are additionally bit-exact against
  the single-request offline path.

Reported per mode: throughput (images/s and token·segment cells/s), padding
waste, and the two compile-discipline columns (distinct buckets planned vs
jit compiles actually paid — the engine's recompile bound).

    PYTHONPATH=src python benchmarks/vision_bench.py            # full
    PYTHONPATH=src python benchmarks/vision_bench.py --smoke    # CI lane

A ``BENCH_vision.json`` artifact is written through the schema-versioned
``repro.bench`` envelope shared with serving_bench.py (``--out``
overrides). Exit is non-zero if any mode fails to serve every request or
exceeds its recompile bound; the full run additionally requires balanced
bucketing to beat naive padding in throughput (the paper's load-balancing
claim, acceptance-tested here).
"""
from __future__ import annotations

import argparse
import sys
import time


def make_requests(cfg, num: int, arrival_spread: int, seed: int):
    from repro.launch.serve_vision import make_requests as _mk

    # the launcher's stream generator, skewed toward small images (the
    # realistic mix where naive padding hurts: most requests pay the
    # largest in-flight image's cost)
    return _mk(cfg, num, arrival_spread, seed,
               r_ts=[0.5, cfg.pruning.r_t],
               size_weights=[0.5, 0.3, 0.2])


MODES = (
    # (name, batcher mode, token_tile)
    ("naive", "naive", 1),
    ("balanced", "balanced", 1),
)


def bench(arch: str, num: int, slots: int, arrival_spread: int,
          image_size: int, d_model: int, seed: int):
    import jax

    from repro.configs import get_config
    from repro.core import packed_runner as PR
    from repro.models import model as M
    from repro.models import pruning_glue as PG
    from repro.serving import VisionEngine, VisionEngineConfig

    # reduced() shrinks depth/width for CPU; image_size and d_model set
    # the per-cell compute — big enough that cell count (not dispatch
    # overhead) dominates, which is where load balancing pays
    cfg = get_config(arch).reduced().replace(image_size=image_size)
    if d_model:
        cfg = cfg.replace(d_model=d_model, d_ff=2 * d_model,
                          head_dim=d_model // cfg.num_heads)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)

    results = {}
    for mode, bmode, tile in MODES:
        vc = VisionEngineConfig(max_batch=slots, mode=bmode,
                                token_tile=tile)
        engine = VisionEngine(cfg, masked, packed, vc)
        # warmup on the IDENTICAL stream: arrival dynamics replay exactly,
        # so every tile shape compiles outside the timed window
        engine.serve(make_requests(cfg, num, arrival_spread, seed))
        warm = engine.stats()
        reqs = make_requests(cfg, num, arrival_spread, seed)
        t0 = time.time()
        out = engine.serve(reqs)
        dt = time.time() - t0
        st = engine.stats()
        real = st["batcher_real_cells"] - warm["batcher_real_cells"]
        results[mode] = {
            "seconds": dt,
            "images_s": len(out) / dt,
            "cells_s": real / dt,
            "served": len(out), "expected": num,
            "padding_waste": st["batcher_padding_waste"],
            "buckets": st["bucket_count"],
            "jit_compiles": st["jit_compile_count"],
            "recompile_bound_ok":
                st["jit_compile_count"] <= st["bucket_count"],
        }
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-small")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arrival-spread", type=int, default=4,
                    help="admission staggered over this many engine steps")
    ap.add_argument("--image-size", type=int, default=64,
                    help="reduced-config image size (token load knob)")
    ap.add_argument("--d-model", type=int, default=128,
                    help="reduced-config width override (0 = keep)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_vision.json",
                    help="JSON artifact path")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for the CI fast lane")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots = 8, 4
        args.arrival_spread, args.image_size, args.d_model = 3, 32, 0

    res = bench(args.arch, args.requests, args.slots, args.arrival_spread,
                args.image_size, args.d_model, args.seed)
    ok = True
    hdr = (f"{'mode':10s} {'img/s':>8s} {'cells/s':>10s} {'served':>8s} "
           f"{'pad waste':>10s} {'buckets':>8s} {'jit':>5s}")
    print(hdr)
    for mode, r in res.items():
        served = f"{r['served']}/{r['expected']}"
        print(f"{mode:10s} {r['images_s']:8.2f} {r['cells_s']:10.0f} "
              f"{served:>8s} {r['padding_waste']:10.1%} "
              f"{r['buckets']:8d} {r['jit_compiles']:5d}")
        ok &= r["served"] == r["expected"]
        ok &= r["recompile_bound_ok"]
    speedup = res["balanced"]["images_s"] / res["naive"]["images_s"]
    print(f"balanced vs naive: {speedup:.2f}x images/s; padding waste "
          f"{res['naive']['padding_waste']:.1%} -> "
          f"{res['balanced']['padding_waste']:.1%}")

    from repro.bench import write_bench_artifact
    write_bench_artifact(
        args.out, kind="vision",
        config={k: v for k, v in vars(args).items() if k != "out"},
        results=res,
        extra={"balanced_vs_naive": speedup})
    print(f"wrote {args.out}")
    if not ok:
        print("FAIL: unserved requests or recompile bound exceeded",
              file=sys.stderr)
        sys.exit(1)
    if not args.smoke and speedup <= 1.0:
        print(f"FAIL: balanced bucketing ({res['balanced']['images_s']:.2f} "
              f"img/s) did not beat naive padding "
              f"({res['naive']['images_s']:.2f} img/s)", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
