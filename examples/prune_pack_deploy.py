"""Offline model-optimization pipeline (paper Fig. 1 right side):

  trained+pruned ViT  →  hard masks  →  block-compressed packing with
  load-balanced column order  →  SBMM execution  →  accuracy parity check.

This is the deployment path a real accelerator run would take; here every
packed weight is validated against its masked-dense oracle and the packed
model size is compared with the paper's compression claims.

Run: PYTHONPATH=src python examples/prune_pack_deploy.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import DEIT_SMALL
from repro.core import packing
from repro.core.complexity import model_size_bytes
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.kernels.sbmm import sbmm


def main():
    key = jax.random.PRNGKey(0)
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    masks = PG.hard_masks(cfg, params, scores)
    b = cfg.pruning.block_size

    total_dense = total_packed = 0
    checked = 0
    for path, mask in masks.items():
        layer_idx = int(path.split("/")[1])
        leafname = path.split("/")[-1]
        w = np.asarray(params["layers"][layer_idx]["attn"][leafname],
                       np.float32)
        mk = np.asarray(mask)
        pk = packing.pack_weight(w, mk, b)
        total_dense += w.size * 4
        total_packed += pk.nbytes()
        # load balance audit
        loads = packing.lane_loads(mk.sum(0).astype(np.int64), pk.col_perm, 8)
        if checked < 2:  # validate a couple of kernels end to end
            x = jax.random.normal(key, (16, w.shape[0]))
            err = float(jnp.abs(sbmm(x, pk, tm=16) - x @ pk.to_dense()).max())
            print(f"  {path}: kept {int(mk.sum())}/{mk.size} blocks, "
                  f"lane loads {loads.tolist()}, sbmm err {err:.1e}")
            assert err < 1e-3
        checked += 1

    print(f"packed {checked} pruned attention weights: "
          f"{total_dense/1e6:.2f} MB dense -> {total_packed/1e6:.2f} MB "
          f"packed ({total_dense/total_packed:.2f}x)")
    full = model_size_bytes(cfg) / 1e6
    dense_full = model_size_bytes(
        cfg, cfg.pruning.__class__()) / 1e6
    print(f"whole-model analytic size: {dense_full:.2f} MB -> {full:.2f} MB "
          f"({dense_full/full:.2f}x; paper claims up to 1.6x)")


if __name__ == "__main__":
    main()
