"""Quickstart: the paper's technique end to end on a small ViT.

1. Build a reduced DeiT config with BOTH prunings enabled.
2. Run simultaneous fine-pruning (Algorithm 1) for a few steps with a
   teacher, watching the loss recover while the cubic schedule tightens r_b.
3. Harden the masks, pack the pruned weights into the block-compressed
   format, and run the SBMM kernel against the masked-dense oracle.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import DEIT_SMALL
from repro.core import simultaneous as SIM
from repro.core import packing
from repro.data import DataConfig, synthetic_vit_batch
from repro.kernels.sbmm import sbmm
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.optim import AdamW


def main():
    key = jax.random.PRNGKey(0)
    cfg = DEIT_SMALL.reduced()
    print(f"config: {cfg.name} (reduced) L={cfg.num_layers} D={cfg.d_model} "
          f"r_b={cfg.pruning.r_b} r_t={cfg.pruning.r_t} "
          f"TDM layers={cfg.pruning.tdm_layers}")

    # --- Algorithm 1: simultaneous fine-pruning with distillation --------
    state, opt = SIM.init_state(cfg, key, AdamW(lr=2e-3))
    teacher = M.init_params(cfg, jax.random.fold_in(key, 1))
    step = jax.jit(SIM.make_simultaneous_step(cfg, cfg, opt, total_steps=30))
    dc = DataConfig(seed=0)
    for i in range(10):
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_vit_batch(cfg, 8, dc, i).items()}
        state, m = step(state, teacher, batch)
        if i % 3 == 0:
            print(f"  step {i}: loss={float(m['loss']):.4f} "
                  f"ce={float(m['ce']):.4f} distill={float(m['distill']):.4f} "
                  f"r_b(t)={float(m['r_b']):.3f}")

    # --- harden masks + pack one weight for the accelerator path ---------
    masks = PG.hard_masks(cfg, state.params, state.scores)
    path = next(p for p in masks if p.endswith("attn/wq"))
    layer_idx = int(path.split("/")[1])
    w = np.asarray(state.params["layers"][layer_idx]["attn"]["wq"],
                   np.float32)
    mask = np.asarray(masks[path])
    pk = packing.pack_weight(w, mask, cfg.pruning.block_size)
    kept = int(np.asarray(pk.counts).sum())
    print(f"packed {path}: {kept}/{mask.size} blocks kept "
          f"({kept/mask.size:.0%}), {pk.nbytes()/1e3:.1f} KB packed")

    # --- SBMM kernel vs masked-dense oracle ------------------------------
    x = jax.random.normal(key, (32, w.shape[0]), jnp.float32)
    y_kernel = sbmm(x, pk, tm=32)
    y_oracle = x @ pk.to_dense()
    err = float(jnp.abs(y_kernel - y_oracle).max())
    print(f"SBMM kernel vs oracle: max |err| = {err:.2e}")
    assert err < 1e-3
    print("quickstart OK")


if __name__ == "__main__":
    main()
