"""Serving with dynamic KV-cache pruning — the paper's token scoring
adapted to autoregressive decode (beyond-paper extension, DESIGN.md §5) —
on the layered serving API (Scheduler / KVCacheManager / ModelRunner
composed by ServeEngine).

Serves the same skewed batch twice through the continuous per-slot path
(full cache vs 50% pruned cache) and reports agreement of the generated
tokens, the cache-size saving, and the admission cost the layered redesign
bounds: tokens prefilled per admission (one bucketed prompt, independent
of slot occupancy) and jit compiles (one per prefix-length bucket).

Run: PYTHONPATH=src python examples/serve_kv_pruned.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving import EngineConfig, Request, ServeEngine


def make_requests(cfg, seed=0, num=4):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(12, 25)),
                                        dtype=np.int32),
                    max_new_tokens=12)
            for i in range(num)]


def serve_once(cfg, params, kv_prune: float):
    ec = EngineConfig(max_batch=2, max_len=64,
                      kv_prune_interval=4 if kv_prune < 1.0 else 0,
                      kv_prune_keep=kv_prune)
    engine = ServeEngine(cfg, params, ec)
    out = engine.serve(make_requests(cfg), continuous=True)
    return out, engine


def main():
    cfg = get_config("qwen3-14b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    full, eng_full = serve_once(cfg, params, kv_prune=1.0)
    pruned, eng_pruned = serve_once(cfg, params, kv_prune=0.5)

    agree = total = 0
    for uid in full:
        a, b = full[uid], pruned[uid]
        agree += sum(x == y for x, y in zip(a, b))
        total += len(a)
    st = eng_pruned.stats()
    print(f"token agreement under 50% KV pruning: {agree}/{total} "
          f"({agree/total:.0%}) — high-mass tokens carry the prediction")
    print(f"cache memory: 0.5x of full (by construction; "
          f"{st['prune_events']} prune compactions fired)")
    print(f"admission cost: {st['prefill_tokens_per_admission']:.1f} "
          f"prefilled tokens per admission over {st['admissions']} "
          f"admissions into {eng_pruned.ec.max_batch} slots")
    print(f"jit compiles: {st['jit_compile_count']} "
          f"(bounded by prefix-length buckets, shapes: "
          f"{eng_pruned.runner.compiled_shapes()})")
    # the three layers are independently inspectable:
    print(f"scheduler events: {eng_pruned.scheduler.events[:4]}... "
          f"({len(eng_pruned.scheduler.events)} total)")


if __name__ == "__main__":
    main()
