"""Serving with dynamic KV-cache pruning — the paper's token scoring
adapted to autoregressive decode (beyond-paper extension, DESIGN.md §5).

Serves the same batch twice (full cache vs 50% pruned cache) and reports
agreement of the generated tokens plus the cache-size saving.

Run: PYTHONPATH=src python examples/serve_kv_pruned.py
"""
import numpy as np

from repro.launch.serve import serve


def main():
    kw = dict(arch="qwen3-14b", num_requests=4, prompt_len=24, max_new=12)
    full = serve(**kw, kv_prune=1.0)
    pruned = serve(**kw, kv_prune=0.5)

    agree = total = 0
    for uid in full["outputs"]:
        a, b = full["outputs"][uid], pruned["outputs"][uid]
        agree += sum(x == y for x, y in zip(a, b))
        total += len(a)
    print(f"full cache    : {full['tokens_per_s']:.1f} tok/s")
    print(f"pruned (50%)  : {pruned['tokens_per_s']:.1f} tok/s")
    print(f"token agreement under 50% KV pruning: {agree}/{total} "
          f"({agree/total:.0%}) — high-mass tokens carry the prediction")
    print("cache memory: 0.5x of full (by construction)")


if __name__ == "__main__":
    main()
