"""Traffic & SLOs — replaying a bundled bursty trace through both engines.

Walkthrough of the ``repro.traffic`` subsystem:

  1. **Load a trace.** ``examples/traces/bursty_vision.jsonl`` is a
     12-request bursty (Markov-modulated Poisson) arrival stream with a
     0.05 ms virtual SLO per request, committed to the repo. Traces store
     request *descriptions* plus a content seed — not pixels — so the
     file is a few KB and replays byte-for-byte: its sha256 fingerprint
     is the workload's identity (bench artifacts record it).
  2. **Replay it on the virtual clock.** The ``TrafficHarness`` drives
     the ``VisionEngine`` tick by tick; time advances by the cost model's
     price of each dispatched step, so every latency / deadline verdict
     below is deterministic — same on any machine, any pipeline depth.
     With admission off, the served logits are byte-identical to calling
     ``engine.serve()`` directly (asserted).
  3. **Turn on admission control.** The burst overruns the engine;
     the cost-model ``AdmissionController`` bounds the modeled backlog,
     degrading consenting requests to the quality floor before rejecting
     (QualityController composition) — the queue stays bounded and the
     accepted requests keep their SLOs.
  4. **Same interface, LM engine.** The bundled
     ``examples/traces/bursty_lm.jsonl`` replays through ``ServeEngine``
     (continuous batching) with dispatched tokens priced onto the same
     virtual clock.

Run: PYTHONPATH=src python examples/serve_trace.py
"""
import os

import jax

from repro.configs import get_config
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import (EngineConfig, ServeEngine, VisionEngine,
                           VisionEngineConfig)
from repro.traffic import (LMDriver, TrafficHarness, VisionDriver,
                           load_trace, outputs_digest, trace_fingerprint)

TRACE_DIR = os.path.join(os.path.dirname(__file__), "traces")


def show(tag, rep):
    print(f"  [{tag}] completed {rep['completed']}/{rep['offered']} "
          f"(rejected {rep['rejected']}) goodput={rep['goodput_rps']:.0f}/s "
          f"p50={rep['latency_p50_ms']:.3f}ms "
          f"p99={rep['latency_p99_ms']:.3f}ms "
          f"miss={rep['deadline_miss_rate']:.0%} "
          f"peak_queue={rep['peak_queue_depth']}")


def main():
    # --- 1. the bundled vision trace --------------------------------------
    trace = load_trace(os.path.join(TRACE_DIR, "bursty_vision.jsonl"))
    print(f"vision trace: {len(trace.requests)} requests, "
          f"offered {trace.offered_load_rps:.0f}/s, "
          f"fingerprint {trace_fingerprint(trace)[:16]}...")

    cfg = get_config("deit-small").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)

    def vision_engine(quality="strict"):
        return VisionEngine(cfg, masked, packed, VisionEngineConfig(
            max_batch=2, planner="full", quality=quality))

    # --- 2. unbounded replay == the plain engine, byte for byte -----------
    h = TrafficHarness(VisionDriver(vision_engine()))
    rep = h.run(trace)
    show("vision, unbounded", rep)

    eng = vision_engine()
    direct = eng.serve([VisionDriver(eng).materialize(t)
                        for t in trace.requests])
    assert outputs_digest(direct) == rep["outputs_digest"], \
        "harness replay must equal direct serve()"
    print("  unbounded replay is byte-identical to engine.serve()")

    # --- 3. admission control: bounded backlog, degrade before reject -----
    h2 = TrafficHarness(VisionDriver(vision_engine(quality="auto")),
                        admission_limit_ms=0.03)
    rep2 = h2.run(trace)
    show("vision, admission", rep2)
    a = rep2["admission"]
    print(f"  admission verdicts: {a['accepts']} accepted, "
          f"{a['degrades']} degraded to the quality floor, "
          f"{a['rejects']} rejected "
          f"(queue {rep2['peak_queue_depth']} vs "
          f"{rep['peak_queue_depth']} unbounded)")
    assert rep2["peak_queue_depth"] <= rep["peak_queue_depth"]

    # --- 4. the LM engine behind the same interface -----------------------
    lm_trace = load_trace(os.path.join(TRACE_DIR, "bursty_lm.jsonl"))
    print(f"lm trace: {len(lm_trace.requests)} requests, "
          f"offered {lm_trace.offered_load_rps:.0f}/s")
    lm_cfg = get_config("stablelm-1.6b").reduced()
    lm = ServeEngine(lm_cfg, M.init_params(lm_cfg, jax.random.PRNGKey(0)),
                     EngineConfig(max_batch=2, max_len=128))
    rep3 = TrafficHarness(LMDriver(lm, per_token_ms=1.0)).run(lm_trace)
    show("lm, unbounded", rep3)


if __name__ == "__main__":
    main()
