"""Serving the simultaneously-pruned ViT — walkthrough of the vision
serving engine (the paper's system-level claim as software).

Pipeline:
  1. simultaneous pruning, hardened: score init -> hard block masks ->
     masked params (the DBMM path) + SBMM-packed attention weights;
  2. a continuous-batching ``VisionEngine``: image requests of mixed
     resolutions and per-request token keep rates admitted through the
     shared ``Scheduler`` (prune-pressure-aware policy), executed as
     per-stage segments; the ``TilePlanner`` (planner='full') prices the
     ragged population with the accelerator cost model each step and
     emits an ``ExecutionPlan`` — dense token-count tiles (bucket-merged
     when the model says padding is cheaper than a dispatch) plus fused
     express lanes for bucket-singleton requests;
  3. verification: every served logit vector is BIT-EXACT against the
     single-request offline path (``forward_vit_packed``), regardless of
     what else was in flight and of what the planner merged or fused;
  4. quality elasticity: the same stream re-served through a
     ``QualityController`` (quality='degrade') with per-request
     accuracy/latency preferences — consenting requests are tightened
     onto the controller's quantized keep-level grid (here the 0.55
     floor), ``quality='strict'`` requests are pinned to their base
     schedule, and soft-pruning requests fold dropped tokens into a
     weighted package token instead of discarding them. Every degraded
     logit is still bit-exact against the offline path run at the
     schedule the controller resolved.

Run: PYTHONPATH=src python examples/serve_vit_pruned.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import VisionEngine, VisionEngineConfig, VisionRequest


def main():
    cfg = get_config("deit-small").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))

    # --- 1. harden the pruning (offline, once per model) ------------------
    masked = PG.apply_pruning(cfg, params, scores)   # DBMM: masked-dense
    packed = PR.pack_model(cfg, params, scores)      # SBMM: block-packed
    print(f"packed {len(packed)} attention weights "
          f"(block_size={cfg.pruning.block_size}, r_b={cfg.pruning.r_b}); "
          f"segment plan: {PR.vit_segments(cfg)}")

    # --- 2. a mixed request stream ----------------------------------------
    rng = np.random.default_rng(0)
    side = cfg.image_size // cfg.patch_size
    pdim = cfg.patch_size ** 2 * 3
    mixes = [(side ** 2, None), ((side - 1) ** 2, 0.5),
             ((side // 2) ** 2, 0.7), (side ** 2, 0.5),
             ((side - 1) ** 2, None), ((side // 2) ** 2, 0.5)]
    reqs = [VisionRequest(
        uid=i, patches=rng.standard_normal((n, pdim)).astype(np.float32),
        r_t=r_t, arrival_step=i // 2)
        for i, (n, r_t) in enumerate(mixes)]

    engine = VisionEngine(cfg, masked, packed,
                          VisionEngineConfig(max_batch=3, planner="full"),
                          policy="prune_pressure_aware")
    out = engine.serve(reqs)
    st = engine.stats()
    print(f"served {st['images_served']} images in {st['steps']} engine "
          f"steps over {st['batcher_tiles']} tiles + "
          f"{st['plan_lanes']} express lanes "
          f"(merges {st['plan_merges']}, padding waste "
          f"{st['batcher_padding_waste']:.1%}, jit compiles "
          f"{st['jit_compile_count']} <= buckets+trajectories "
          f"{st['compile_budget']}, modeled saving "
          f"{st['plan_modeled_saving_ms']:.2f}ms)")
    admit_order = [uid for kind, uid in engine.events if kind == "admit"]
    print(f"admission order (prune-pressure-aware): {admit_order}")

    # --- 3. bit-exactness vs the offline single-request path --------------
    for r in reqs:
        c = cfg if r.r_t is None else cfg.replace(
            pruning=dataclasses.replace(cfg.pruning, r_t=r.r_t))
        ref = PR.forward_vit_packed(c, masked, packed, r.patches[None],
                                    segments=engine.segments)
        exact = np.array_equal(np.asarray(ref.logits[0]), out[r.uid])
        print(f"  uid {r.uid} ({r.n_patches:2d} patches, "
              f"r_t={r.r_t if r.r_t is not None else cfg.pruning.r_t}): "
              f"top-1 class {int(np.argmax(out[r.uid]))}, "
              f"bit-exact vs offline: {exact}")
        assert exact, "batched serving must not change logits"

    # --- 4. quality-elastic serving ---------------------------------------
    # The controller maps scheduler pressure + per-request preference to a
    # per-step keep schedule at plan time. 'degrade' sheds load: every
    # consenting request drops to the grid floor; a request that asks for
    # quality='strict' keeps its base schedule; soft_prune=True swaps the
    # hard top-k drop for the package-token kernel (dropped tokens live on
    # as one score-weighted summary row).
    print("\nquality-elastic re-serve (degrade controller, floor 0.55):")
    qreqs = [VisionRequest(
        uid=i, patches=r.patches.copy(), r_t=r.r_t,
        arrival_step=r.arrival_step) for i, r in enumerate(reqs)]
    qreqs[1].quality = "strict"     # accuracy-critical: opts out
    qreqs[3].soft_prune = True      # latency-tolerant: package token
    qeng = VisionEngine(cfg, masked, packed,
                        VisionEngineConfig(
                            max_batch=3, planner="full", quality="degrade",
                            keep_levels=(1.0, 0.85, 0.7, 0.55),
                            keep_floor=0.55),
                        policy="prune_pressure_aware")
    qout = qeng.serve(qreqs)
    qst = qeng.stats()
    print(f"tightened {qst['quality_tightened']}/"
          f"{qst['quality_decisions']} keep decisions onto grid levels "
          f"{qst['quality_levels_used']} (jit compiles "
          f"{qst['jit_compile_count']} <= {qst['compile_budget']})")
    for r in qreqs:
        # the reference schedule is whatever the controller resolved (the
        # resolution is pure, so we can replay it: strict preference pins
        # the base schedule; everyone else tightens down the grid — a
        # base rate already below the floor level is left untouched)
        base = PR.keep_schedule(cfg, r_t=r.r_t)
        sched = qeng.planner.quality.resolve(base, preference=r.quality)
        ref = PR.forward_vit_packed(cfg, masked, packed, r.patches[None],
                                    schedule=sched, soft=r.soft_prune,
                                    segments=qeng.segments)
        exact = np.array_equal(np.asarray(ref.logits[0]), qout[r.uid])
        tag = ("strict" if r.quality == "strict"
               else "soft" if r.soft_prune else "hard")
        print(f"  uid {r.uid} ({tag:6s}): schedule {sched} -> top-1 "
              f"{int(np.argmax(qout[r.uid]))}, bit-exact vs offline "
              f"at the resolved schedule: {exact}")
        assert exact, "the controller changes WHICH schedule runs, " \
                      "never the math"

    # --- 5. quantized serving (int8 tier) ---------------------------------
    # The same stream once more through an int8 engine: the planner prices
    # every request's trajectory at fp32 AND int8 with the accelerator
    # cycle model and dispatches the dequant-in-kernel SBMM variant when
    # the tier is strictly cheaper. quality='strict' requests are pinned
    # to fp32 — their logits are bit-exact with the fp32 engine's — and
    # every quantized request is still bit-exact against the offline
    # forward run at the SAME precision (quantization changes the weights
    # once, offline; serving never changes the math).
    print("\nquantized re-serve (precision=int8, per-channel scales):")
    zreqs = [VisionRequest(
        uid=i, patches=r.patches.copy(), r_t=r.r_t,
        arrival_step=r.arrival_step) for i, r in enumerate(reqs)]
    zreqs[1].quality = "strict"     # accuracy-critical: stays fp32
    zeng = VisionEngine(cfg, masked, packed,
                        VisionEngineConfig(max_batch=3, planner="full",
                                           precision="int8"),
                        policy="prune_pressure_aware")
    zout = zeng.serve(zreqs)
    zst = zeng.stats()
    rep = zeng.quantization_report()
    print(f"packed model {rep['packed_bytes_fp32']} -> "
          f"{rep['packed_bytes']} bytes, max|dW|="
          f"{rep['quant_max_abs_error']:.5f}; dispatches "
          f"fp32={zst['dispatch_fp32']} int8={zst['dispatch_int8']} "
          f"(dequant kernels {zst['dequant_dispatches']}), jit compiles "
          f"{zst['jit_compile_count']} <= {zst['compile_budget']}")
    agree = 0
    for r in zreqs:
        c = cfg if r.r_t is None else cfg.replace(
            pruning=dataclasses.replace(cfg.pruning, r_t=r.r_t))
        prec = "fp32" if r.quality == "strict" else "int8"
        ref = PR.forward_vit_packed(c, masked, packed, r.patches[None],
                                    segments=zeng.segments, precision=prec)
        exact = np.array_equal(np.asarray(ref.logits[0]), zout[r.uid])
        top1 = int(np.argmax(zout[r.uid]))
        agree += top1 == int(np.argmax(out[r.uid]))
        tag = "strict/fp32" if r.quality == "strict" else "int8"
        print(f"  uid {r.uid} ({tag:11s}): top-1 {top1}, bit-exact vs "
              f"offline at {prec}: {exact}")
        assert exact, "quantized serving must match the quantized oracle"
    assert np.array_equal(zout[1], out[1]), \
        "a strict request on the int8 engine must be bit-exact fp32"
    print(f"top-1 agreement vs the fp32 serve: {agree}/{len(zreqs)}")


if __name__ == "__main__":
    main()
