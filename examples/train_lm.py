"""End-to-end training driver: train a reduced LM for a few hundred steps
with checkpointing + fault-tolerant restart, optionally with the paper's
block weight pruning active.

Run: PYTHONPATH=src python examples/train_lm.py [--arch minitron-4b]
     [--steps 300] [--prune]
"""
import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--prune", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    ckpt = args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"training {args.arch} (reduced) for {args.steps} steps; "
          f"checkpoints -> {ckpt}")
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                lr=1e-3, ckpt_dir=ckpt, prune=args.prune,
                checkpoint_every=50)
    events = [k for _, k in out["events"]]
    print(f"done: restarts={out['restarts']} "
          f"checkpoints={events.count('checkpoint')}")


if __name__ == "__main__":
    main()
