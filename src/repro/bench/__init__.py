"""Benchmark support: the schema-versioned JSON artifact writer shared by
every bench script (serving_bench, vision_bench, ...)."""
from repro.bench.artifacts import (SCHEMA_VERSION, git_sha,
                                   load_bench_artifact,
                                   write_bench_artifact)

__all__ = ["SCHEMA_VERSION", "write_bench_artifact", "load_bench_artifact",
           "git_sha"]
