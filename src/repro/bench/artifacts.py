"""Schema-versioned JSON bench artifacts.

Every benchmark that CI uploads (``BENCH_serving.json``,
``BENCH_vision.json``, ``BENCH_traffic.json``, ...) writes through
:func:`write_bench_artifact`, so downstream consumers (dashboards,
regression diffing, the nightly lane) see ONE envelope instead of
per-script ad-hoc dicts:

    {
      "schema_version": 4,
      "kind":    "<benchmark family, e.g. 'serving' | 'vision'>",
      "created_unix": <float epoch seconds>,
      "provenance": {           # what it takes to REPRODUCE the run
        "git_sha": "<HEAD sha or null outside a checkout>",
        "seed": <int | null>,   # the run's root RNG seed
        "trace_fingerprint": "<sha256 | null>"  # replayed workload id
      },
      "config":  {...},         # the knobs the run was configured with
      "results": {...},         # per-mode measurements
      "metrics": {...} | null,  # optional repro.obs MetricsRegistry
                                # snapshot (name -> typed metric entry)
      ...extra top-level summary keys (speedups etc.)
    }

Bump ``SCHEMA_VERSION`` when the envelope itself changes shape; kind-local
result layouts may evolve freely (consumers dispatch on ``kind``).

Version history:
  1 — initial envelope.
  2 — vision artifacts grew the ``quality_pareto`` results block (keep-
      floor sweep rows: modeled_ms, top1_agreement, tightened_steps) and
      the timed arms record the controller's quality/keep-floor knobs in
      ``config``.
  3 — reserved ``provenance`` block (git_sha, seed, trace_fingerprint):
      a bench row is only evidence if the run is reconstructible — which
      code, which RNG stream, and (for trace-replay benches) which exact
      workload. Fields are null when unknown; the block is always present.
  4 — reserved optional ``metrics`` block: a ``repro.obs.MetricsRegistry``
      snapshot (``{name: {"type": counter|gauge|histogram, ...}}``) taken
      at the end of the run — recompile counts, planner modeled-vs-
      measured cost error, quality-tighten counters, SLO histograms.
      Null when the bench collected no metrics; the key is always
      present. Purely observational: adding it must not change any
      ``results`` value or digest.
"""
from __future__ import annotations

import json
import subprocess
import time
from typing import Any, Dict, Optional

SCHEMA_VERSION = 4

_RESERVED = ("schema_version", "kind", "created_unix", "provenance",
             "config", "results", "metrics")


def git_sha() -> Optional[str]:
    """HEAD commit of the working tree (None outside a git checkout or
    without git on PATH) — recorded, never trusted for logic."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def write_bench_artifact(path: str, kind: str, config: Dict[str, Any],
                         results: Dict[str, Any],
                         extra: Optional[Dict[str, Any]] = None,
                         seed: Optional[int] = None,
                         trace_fingerprint: Optional[str] = None,
                         metrics: Optional[Dict[str, Any]] = None
                         ) -> Dict[str, Any]:
    """Write the envelope to ``path``; returns the dict written. ``extra``
    keys land at the top level (summary scalars) and must not collide with
    the envelope's own fields. ``seed`` / ``trace_fingerprint`` fill the
    provenance block (the git SHA is captured automatically). ``metrics``
    is an optional ``repro.obs.MetricsRegistry.snapshot()`` dict (schema
    v4); pass None when the bench collected none."""
    artifact: Dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "kind": kind,
        "created_unix": time.time(),
        "provenance": {
            "git_sha": git_sha(),
            "seed": seed,
            "trace_fingerprint": trace_fingerprint,
        },
        "config": config,
        "results": results,
        "metrics": metrics,
    }
    for key, value in (extra or {}).items():
        if key in _RESERVED:
            raise ValueError(f"extra key {key!r} collides with the "
                             f"artifact envelope")
        artifact[key] = value
    with open(path, "w") as f:
        json.dump(artifact, f, indent=2, default=str)
    return artifact


def load_bench_artifact(path: str,
                        expect_kind: Optional[str] = None) -> Dict[str, Any]:
    """Read + validate an artifact envelope (schema version, provenance
    block shape and, if given, kind). The smoke lanes use this to fail
    loudly on malformed output."""
    with open(path) as f:
        artifact = json.load(f)
    missing = [k for k in _RESERVED if k not in artifact]
    if missing:
        raise ValueError(f"{path}: not a bench artifact — missing {missing}")
    if artifact["schema_version"] != SCHEMA_VERSION:
        raise ValueError(
            f"{path}: schema_version {artifact['schema_version']} != "
            f"supported {SCHEMA_VERSION}")
    prov = artifact["provenance"]
    missing_prov = [k for k in ("git_sha", "seed", "trace_fingerprint")
                    if k not in prov]
    if missing_prov:
        raise ValueError(f"{path}: provenance block missing {missing_prov}")
    if expect_kind is not None and artifact["kind"] != expect_kind:
        raise ValueError(f"{path}: kind {artifact['kind']!r} != "
                         f"{expect_kind!r}")
    return artifact
