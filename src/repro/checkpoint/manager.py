"""Checkpointing: atomic, shard-aware save/restore with step metadata.

Fault-tolerance contract (dist/fault.py relies on all three):
  * atomicity  — writes go to ``step_<n>.tmp/`` then ``os.rename`` to
    ``step_<n>/``; a crash mid-save never corrupts the latest checkpoint.
  * latest()   — scans for the highest complete step; restart resumes there.
  * retention  — keep the last ``keep`` checkpoints, delete older ones.

Arrays are saved leaf-per-file (npy) with a json manifest of the pytree
structure. On restore, leaves are device_put against the *current* mesh's
shardings — which is what makes elastic re-sharding (dist/elastic.py) work:
the same checkpoint restores onto a different device count.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_part(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten(tree)
        manifest = {"step": step, "keys": sorted(leaves),
                    "extra": extra or {}}
        for k, arr in leaves.items():
            np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                    os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template`` (values ignored).
        ``shardings``: optional pytree of NamedShardings to place leaves
        onto the current mesh (elastic restore path)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (path, leaf), sh in zip(flat, shard_flat):
            key = "/".join(_part(p) for p in path)
            arr = np.load(os.path.join(d, key.replace("/", "__") + ".npy"))
            dt = getattr(leaf, "dtype", None)
            if dt is not None and arr.dtype != dt:
                arr = arr.astype(dt)  # sharded path must cast too
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def extra(self, step: Optional[int] = None) -> Dict:
        if step is None:
            step = self.latest_step()
        with open(os.path.join(self.directory, f"step_{step:010d}",
                               "manifest.json")) as f:
            return json.load(f)["extra"]
