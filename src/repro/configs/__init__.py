"""Config registry: ``get_config("<arch-id>")`` and the assigned shape grid."""
from __future__ import annotations

from typing import Dict, List, Tuple

from .base import ModelConfig, PruningConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME
from .archs import (
    ALL_ARCHS,
    DEIT_SMALL,
    COMMAND_R_PLUS_104B,
    QWEN3_14B,
    MINITRON_4B,
    STABLELM_1_6B,
    QWEN2_MOE_A2_7B,
    GRANITE_MOE_3B_A800M,
    LLAMA_3_2_VISION_90B,
    WHISPER_BASE,
    ZAMBA2_1_2B,
    RWKV6_1_6B,
)

_REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in ALL_ARCHS}
_REGISTRY[DEIT_SMALL.name] = DEIT_SMALL


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]


def list_archs(include_vit: bool = False) -> List[str]:
    names = [c.name for c in ALL_ARCHS]
    if include_vit:
        names.append(DEIT_SMALL.name)
    return names


def grid_cells(arch: str | None = None) -> List[Tuple[ModelConfig, ShapeConfig]]:
    """The assigned (arch x shape) grid, with per-arch skips applied."""
    cells = []
    archs = [get_config(arch)] if arch else list(ALL_ARCHS)
    for cfg in archs:
        for shape in SHAPES:
            if shape.name in cfg.skip_shapes:
                continue
            cells.append((cfg, shape))
    return cells


__all__ = [
    "ModelConfig",
    "PruningConfig",
    "ShapeConfig",
    "SHAPES",
    "SHAPES_BY_NAME",
    "get_config",
    "list_archs",
    "grid_cells",
    "ALL_ARCHS",
    "DEIT_SMALL",
]
