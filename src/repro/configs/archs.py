"""The 10 assigned architectures (public-literature configs) + the paper's own
DeiT-Small. Each is a module-level ``ModelConfig``; the registry in
``configs/__init__.py`` exposes them by id for ``--arch <id>``.

Sources are noted inline: [hf:...] / [arXiv:...] per the assignment sheet.
"""
from __future__ import annotations

from .base import ModelConfig, PruningConfig

# Default pruning posture for LM archs: the paper's technique is available as
# a first-class switch; configs ship with it OFF (r_b=r_t=1.0) so the faithful
# dense baseline is the default, and benchmarks/examples flip it on.
_NO_PRUNE = PruningConfig()

# --------------------------------------------------------------------------
# The paper's own model: DeiT-Small (12L, D=384, 6 heads, ImageNet-1k).
# TDM at encoders {3,7,10} (1-indexed in the paper) -> 0-indexed {2,6,9}.
# --------------------------------------------------------------------------
DEIT_SMALL = ModelConfig(
    name="deit-small",
    family="vit",
    num_layers=12,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=0,
    use_bias=True,
    image_size=224,
    patch_size=16,
    num_classes=1000,
    pruning=PruningConfig(
        block_size=16, r_b=0.5, r_t=0.7, tdm_layers=(2, 6, 9),
        lambda_reg=1e-4, distill_temperature=4.0,
    ),
    skip_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

# --------------------------------------------------------------------------
# Dense LM family
# --------------------------------------------------------------------------
# [hf:CohereForAI/c4ai-command-r-v01; unverified]
COMMAND_R_PLUS_104B = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),  # full attention: O(N^2) at 524k — skipped
)

# [hf:Qwen/Qwen3-8B; hf] — qk_norm, GQA
QWEN3_14B = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),
)

# [arXiv:2407.14679; hf] — pruned nemotron
MINITRON_4B = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),
)

# [hf:stabilityai/stablelm-2-1_6b; unverified] — MHA (kv=32)
STABLELM_1_6B = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),
)

# --------------------------------------------------------------------------
# MoE family
# --------------------------------------------------------------------------
# [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 4 shared + 60 routed top-4, d_ff per expert
QWEN2_MOE_A2_7B = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    moe_num_experts=60,
    moe_top_k=4,
    moe_num_shared=4,
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),
)

# [hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — 40 experts top-8
GRANITE_MOE_3B_A800M = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe_num_experts=40,
    moe_top_k=8,
    moe_num_shared=0,
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),
)

# --------------------------------------------------------------------------
# VLM — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
# --------------------------------------------------------------------------
LLAMA_3_2_VISION_90B = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_period=5,  # a cross-attention layer every 5 decoder layers
    num_vision_tokens=1601,  # stub frontend: precomputed patch embeddings
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),
)

# --------------------------------------------------------------------------
# Audio enc-dec — backbone only; conv frontend is a STUB (precomputed frames).
# [arXiv:2212.04356; unverified]
# --------------------------------------------------------------------------
WHISPER_BASE = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,  # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    use_bias=True,
    num_audio_frames=1500,
    pruning=_NO_PRUNE,
    skip_shapes=("long_500k",),
)

# --------------------------------------------------------------------------
# Hybrid — Mamba2 + shared attention blocks [arXiv:2411.15242; hf]
# --------------------------------------------------------------------------
ZAMBA2_1_2B = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    attn_layer_period=6,  # shared attention block applied every 6 mamba layers
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=(),  # sub-quadratic: long_500k runs
)

# --------------------------------------------------------------------------
# SSM (attention-free) — RWKV6 "Finch" [arXiv:2404.05892; unverified]
# --------------------------------------------------------------------------
RWKV6_1_6B = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # rwkv6 heads for the wkv state (head_dim=64)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    use_bias=False,
    pruning=_NO_PRUNE,
    skip_shapes=(),  # attention-free: long_500k runs
)

ALL_ARCHS = (
    COMMAND_R_PLUS_104B,
    QWEN3_14B,
    MINITRON_4B,
    STABLELM_1_6B,
    QWEN2_MOE_A2_7B,
    GRANITE_MOE_3B_A800M,
    LLAMA_3_2_VISION_90B,
    WHISPER_BASE,
    ZAMBA2_1_2B,
    RWKV6_1_6B,
)
