"""Config dataclasses for the repro framework.

A single ``ModelConfig`` covers every assigned architecture family:
dense GQA LMs, MoE LMs, cross-attention VLMs, encoder-decoder audio
models, Mamba2 hybrids, RWKV6, and the paper's own ViT/DeiT family.

``PruningConfig`` carries the paper's two pruning knobs:
  * static block weight pruning  (block size ``b``, top-k keep rate ``r_b``)
  * dynamic token pruning        (keep rate ``r_t``, TDM layer indices)

``ShapeConfig`` is one cell of the assigned (arch x shape) grid.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class PruningConfig:
    """Hyper-parameters of the paper's simultaneous pruning.

    ``block_size`` is the logical score-block granularity (paper: 16/32).
    ``r_b`` is the weight-block top-k keep rate (paper: 0.5/0.7; 1.0 = dense).
    ``r_t`` is the token keep rate at each TDM layer (paper: 0.5/0.7/0.9).
    ``tdm_layers`` are encoder indices where the TDM is inserted (paper: 3,7,10;
    1-indexed in the paper, we store 0-indexed).
    ``prune_msa`` / ``prune_mlp`` select which weight groups are block-pruned.
    ``kv_prune_keep`` (beyond-paper) enables dynamic KV-cache pruning in decode:
    keep rate of cached tokens ranked by aggregated attention mass.
    """

    block_size: int = 16
    r_b: float = 1.0
    r_t: float = 1.0
    tdm_layers: Tuple[int, ...] = ()
    prune_msa: bool = True
    prune_mlp: bool = True
    lambda_reg: float = 1e-4
    distill_temperature: float = 4.0
    lambda_distill: float = 0.5
    lambda_task: float = 0.5
    kv_prune_keep: float = 1.0

    @property
    def weight_pruning_enabled(self) -> bool:
        return self.r_b < 1.0

    @property
    def token_pruning_enabled(self) -> bool:
        return self.r_t < 1.0 and len(self.tdm_layers) > 0

    @property
    def kv_pruning_enabled(self) -> bool:
        return self.kv_prune_keep < 1.0


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assigned grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes. ``decode_*``/``long_*`` lower ``serve_step``
# (one new token against a KV cache of ``seq_len``), not ``train_step``.
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description.

    Only the fields relevant to ``family`` are consulted by the model
    builder; the rest keep their defaults.
    """

    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm | vit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_shared_d_ff: int = 0  # 0 -> d_ff * moe_num_shared
    moe_capacity_factor: float = 1.25
    # pad the routed-expert bank so it divides the TP axis (EP sharding);
    # padded experts receive no tokens (router logits stay at E real experts)
    moe_expert_pad_to: int = 1

    # --- VLM (cross-attention image layers) ---
    cross_attn_period: int = 0  # insert a cross-attn layer every N layers
    num_vision_tokens: int = 0
    vision_d_model: int = 0  # frontend stub output dim (0 -> d_model)

    # --- audio enc-dec ---
    encoder_layers: int = 0  # for family=="audio"; num_layers = decoder layers
    num_audio_frames: int = 1500  # stub frontend output length for train kind

    # --- hybrid / ssm ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    attn_layer_period: int = 0  # zamba2: shared attn block every N ssm layers

    # --- ViT (the paper's own family) ---
    image_size: int = 224
    patch_size: int = 16
    num_classes: int = 1000
    pool_type: str = "cls"

    # --- pruning (the paper's technique, first-class) ---
    pruning: PruningConfig = field(default_factory=PruningConfig)

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # --- perf levers (§Perf hillclimbs; defaults = paper-faithful baseline) ---
    remat_policy: str = "full"      # full | dots | none
    fuse_qkv: bool = False          # single QKV matmul + split
    loss_chunk: int = 1024          # chunked-CE sequence chunk
    serve_param_dtype: str = "float32"  # bf16 halves decode weight reads
    microbatches: int = 1           # gradient-accumulation splits
    shard_rwkv_kv: bool = False     # TP-shard rwkv time-mix wk/wv (§Perf)
    rwkv_chunk: int = 0             # flash-linear-attention WKV chunking

    # shapes this arch should skip, with reasons (recorded in DESIGN.md)
    skip_shapes: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads={self.num_heads} must be divisible by "
            f"num_kv_heads={self.num_kv_heads}"
        )

    @property
    def moe_num_experts_padded(self) -> int:
        pad = max(self.moe_expert_pad_to, 1)
        return -(-self.moe_num_experts // pad) * pad

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter counting (used by benchmarks + roofline MODEL_FLOPS).
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; ``active_only`` counts only routed
        experts actually used per token (for MoE 6*N_active*D rooflines)."""
        d, h, kv, hd, ff, v = (
            self.d_model,
            self.num_heads,
            self.num_kv_heads,
            self.head_dim,
            self.d_ff,
            self.vocab_size,
        )
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d  # q,k,v,o
        if self.family == "ssm":  # rwkv6-style: r,k,v,g,o + channel mix
            inner = d
            per_layer = 5 * d * inner + 2 * d * ff + ff * d  # time-mix + channel-mix
            emb = v * d
            return self.num_layers * per_layer + emb + (0 if self.tie_embeddings else v * d)
        if self.family == "hybrid":
            inner = self.ssm_expand * d
            mamba = d * 2 * inner + inner * d + inner * (2 * self.ssm_state)
            n_attn = (
                self.num_layers // self.attn_layer_period if self.attn_layer_period else 0
            )
            shared_attn = attn + 2 * d * ff + ff * d  # one shared block
            return (
                self.num_layers * mamba
                + shared_attn
                + v * d
                + (0 if self.tie_embeddings else v * d)
            )
        if self.family == "moe":
            n_e = self.moe_num_experts if not active_only else self.moe_top_k
            shared_ff = self.moe_shared_d_ff or (self.d_ff * max(self.moe_num_shared, 0))
            ffn = n_e * 3 * d * ff + (3 * d * shared_ff if shared_ff else 0)
            per_layer = attn + ffn
        else:
            glu = self.family in ("dense", "moe")
            ffn = (3 if glu else 2) * d * ff
            per_layer = attn + ffn
        layers = self.num_layers + self.encoder_layers
        if self.cross_attn_period:
            n_cross = self.num_layers // self.cross_attn_period
            layers_extra = n_cross * (attn + 3 * d * ff)
        else:
            layers_extra = 0
        emb = v * d + (0 if self.tie_embeddings else v * d)
        if self.family == "vit":
            emb = (self.patch_size**2 * 3) * d + self.num_classes * d
        return layers * per_layer + layers_extra + emb

    # ------------------------------------------------------------------
    # Reduced config for CPU smoke tests.
    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config: few layers, narrow width, small vocab."""
        heads = min(self.num_heads, 4)
        q_per_kv = max(1, self.num_heads // self.num_kv_heads)
        kv = max(1, heads // min(q_per_kv, heads))
        kw = dict(
            num_layers=min(self.num_layers, 3),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.family == "moe":
            # capacity high enough that reduced smoke tests never drop
            # tokens (capacity overflow makes prefill+decode diverge from
            # the full forward — real GShard semantics, noisy for tests)
            kw.update(moe_num_experts=4, moe_top_k=2,
                      moe_num_shared=min(self.moe_num_shared, 1),
                      moe_shared_d_ff=128, moe_capacity_factor=8.0)
        if self.family == "audio":
            kw.update(encoder_layers=2, num_audio_frames=32)
        if self.family == "vlm":
            kw.update(cross_attn_period=2, num_vision_tokens=8, vision_d_model=0)
        if self.family == "hybrid":
            kw.update(ssm_state=8, attn_layer_period=2, num_layers=4)
        if self.family == "vit":
            kw.update(image_size=32, patch_size=8, num_classes=10)
            # TDM layers must precede the final encoder to be observable
            # through the CLS readout (reduced depth = 3)
            if self.pruning.token_pruning_enabled:
                kw.setdefault("pruning", dataclasses.replace(
                    self.pruning, block_size=16, tdm_layers=(1,)))
        # keep the paper's pruning knobs but shrink the block size so tiny
        # matrices still have multiple blocks
        if self.pruning.block_size > 16:
            kw["pruning"] = dataclasses.replace(self.pruning, block_size=16)
        return self.replace(**kw)
