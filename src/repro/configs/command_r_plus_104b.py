"""Config module for --arch command-r-plus-104b."""
from .archs import COMMAND_R_PLUS_104B as CONFIG

__all__ = ["CONFIG"]
