"""Config module for --arch deit-small."""
from .archs import DEIT_SMALL as CONFIG

__all__ = ["CONFIG"]
