"""Config module for --arch granite-moe-3b-a800m."""
from .archs import GRANITE_MOE_3B_A800M as CONFIG

__all__ = ["CONFIG"]
