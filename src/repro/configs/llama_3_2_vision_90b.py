"""Config module for --arch llama-3-2-vision-90b."""
from .archs import LLAMA_3_2_VISION_90B as CONFIG

__all__ = ["CONFIG"]
