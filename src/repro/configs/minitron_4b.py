"""Config module for --arch minitron-4b."""
from .archs import MINITRON_4B as CONFIG

__all__ = ["CONFIG"]
