"""Config module for --arch qwen2-moe-a2-7b."""
from .archs import QWEN2_MOE_A2_7B as CONFIG

__all__ = ["CONFIG"]
