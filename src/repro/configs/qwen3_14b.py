"""Config module for --arch qwen3-14b."""
from .archs import QWEN3_14B as CONFIG

__all__ = ["CONFIG"]
