"""Config module for --arch rwkv6-1-6b."""
from .archs import RWKV6_1_6B as CONFIG

__all__ = ["CONFIG"]
