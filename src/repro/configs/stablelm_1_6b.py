"""Config module for --arch stablelm-1-6b."""
from .archs import STABLELM_1_6B as CONFIG

__all__ = ["CONFIG"]
