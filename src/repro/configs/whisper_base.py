"""Config module for --arch whisper-base."""
from .archs import WHISPER_BASE as CONFIG

__all__ = ["CONFIG"]
