"""Config module for --arch zamba2-1-2b."""
from .archs import ZAMBA2_1_2B as CONFIG

__all__ = ["CONFIG"]
