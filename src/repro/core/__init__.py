"""Core: the paper's contribution — simultaneous static block weight pruning
and dynamic token pruning, plus the analytic models used for validation."""
from repro.core import block_pruning, token_pruning, packing, schedule
from repro.core import complexity, perf_model, simultaneous

__all__ = [
    "block_pruning",
    "token_pruning",
    "packing",
    "schedule",
    "complexity",
    "perf_model",
    "simultaneous",
]
