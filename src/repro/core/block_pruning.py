"""Static block weight pruning (paper §IV-A).

Every prunable weight ``W ∈ R^{M1×M2}`` owns a learnable score matrix
``S ∈ R^{⌈M1/b⌉×⌈M2/b⌉}`` (one score per ``b×b`` block). The binary mask is
built by global top-k selection over ``S`` (keep rate ``r_b``) and applied as
``W ⊙ M``. Gradients flow to ``S`` through a straight-through estimator (STE)
that ignores the non-differentiable top-k (movement-pruning style [17]):

    forward :  M = 1[S ∈ top-k(S)]
    backward:  dL/dS_ij = Σ_{(u,v) ∈ block ij} dL/d(W⊙M)_uv · W_uv

MLP weights are pruned by whole columns (``W_int``) / rows (``W_out``) via
score *vectors* (paper Fig. 3); MSA weights use 2-D block scores with the
*alternate pattern* tying ``W_p`` column structure to ``W_proj`` row structure
(paper Fig. 2).

The sparsity regularizer (Eq. 8) is ``λ · Σ σ(S)`` summed over all scores.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# STE top-k mask
# ---------------------------------------------------------------------------
@jax.custom_vjp
def ste_topk_mask(scores: jax.Array, keep: int) -> jax.Array:
    """Binary mask keeping the ``keep`` largest entries of ``scores``.

    Straight-through: backward passes the cotangent through unchanged, i.e.
    the top-k selection is treated as identity for gradient purposes.
    """
    return _hard_topk(scores, keep)


def _hard_topk(scores: jax.Array, keep: int) -> jax.Array:
    flat = scores.reshape(-1)
    keep = int(keep)
    if keep >= flat.shape[0]:
        return jnp.ones_like(scores)
    if keep <= 0:
        return jnp.zeros_like(scores)
    # threshold = keep-th largest value; ties broken toward keeping more.
    kth = jax.lax.top_k(flat, keep)[0][-1]
    return (scores >= kth).astype(scores.dtype)


def _ste_fwd(scores, keep):
    return _hard_topk(scores, keep), None


def _ste_bwd(_, g):
    return (g, None)


ste_topk_mask.defvjp(_ste_fwd, _ste_bwd)


# ---------------------------------------------------------------------------
# Block geometry
# ---------------------------------------------------------------------------
def score_shape(w_shape: Tuple[int, int], block_size: int) -> Tuple[int, int]:
    m1, m2 = w_shape
    b = block_size
    return (math.ceil(m1 / b), math.ceil(m2 / b))


def expand_block_mask(block_mask: jax.Array, w_shape: Tuple[int, int],
                      block_size: int) -> jax.Array:
    """Expand a (m, n) block mask to a full (M1, M2) element mask."""
    b = block_size
    full = jnp.repeat(jnp.repeat(block_mask, b, axis=0), b, axis=1)
    return full[: w_shape[0], : w_shape[1]]


def num_kept_blocks(w_shape: Tuple[int, int], block_size: int, r_b: float) -> int:
    m, n = score_shape(w_shape, block_size)
    return max(1, math.ceil(m * n * r_b))


# ---------------------------------------------------------------------------
# Masked weights
# ---------------------------------------------------------------------------
def masked_weight(w: jax.Array, scores: jax.Array, r_b: float,
                  block_size: int) -> jax.Array:
    """``W ⊙ M`` with the STE mask derived from block ``scores``.

    ``scores`` has shape ``score_shape(w.shape, block_size)``.
    """
    if r_b >= 1.0:
        return w
    keep = num_kept_blocks(w.shape, block_size, r_b)
    bm = ste_topk_mask(scores, keep)
    full = expand_block_mask(bm, w.shape, block_size)
    return w * full.astype(w.dtype)


def masked_weight_vector(w: jax.Array, scores: jax.Array, r_b: float,
                         axis: int) -> jax.Array:
    """MLP column/row pruning (paper Fig. 3).

    ``scores`` is a vector of length ``w.shape[axis]``; whole columns
    (``axis=1``, for W_int) or rows (``axis=0``, for W_out) are pruned via
    top-k on the score vector.
    """
    if r_b >= 1.0:
        return w
    n = w.shape[axis]
    keep = max(1, math.ceil(n * r_b))
    m = ste_topk_mask(scores, keep)
    shape = [1, 1]
    shape[axis] = n
    return w * m.reshape(shape).astype(w.dtype)


def alternate_tie_mask(block_mask_p: jax.Array) -> jax.Array:
    """Alternate pattern (paper Fig. 2): the column-block keep pattern of a
    ``W_p`` (``D × H·D'``, blocks ``m × n``) determines the row-block keep
    pattern of ``W_proj`` (``H·D' × D``, blocks ``n × m'``): a fully pruned
    ``W_p`` block-column makes the corresponding ``W_proj`` block-row
    redundant. Returns a per-block-row keep vector of length ``n``."""
    return (block_mask_p.sum(axis=0) > 0).astype(block_mask_p.dtype)


def head_retained_ratio(block_mask_p: jax.Array, heads: int) -> jax.Array:
    """Fraction of heads with at least one surviving block column
    (paper Table VI "Head Retained Ratio")."""
    n = block_mask_p.shape[1]
    per_head = block_mask_p.reshape(block_mask_p.shape[0], heads, n // heads)
    alive = (per_head.sum(axis=(0, 2)) > 0)
    return alive.mean()


# ---------------------------------------------------------------------------
# Score parameter trees
# ---------------------------------------------------------------------------
def init_scores_for(w: jax.Array, block_size: int, kind: str,
                    key: jax.Array) -> jax.Array:
    """Initialize a score parameter for weight ``w``.

    ``kind``: "block" -> 2-D block scores; "col"/"row" -> score vector for MLP
    column/row pruning. Small positive init so the cubic schedule starts from
    an (almost) dense model with meaningful top-k gradients.
    """
    if kind == "block":
        shape = score_shape(w.shape, block_size)
    elif kind == "col":
        shape = (w.shape[1],)
    elif kind == "row":
        shape = (w.shape[0],)
    else:
        raise ValueError(kind)
    return 0.01 * jax.random.normal(key, shape, dtype=jnp.float32)


def sparsity_regularizer(scores_tree) -> jax.Array:
    """λ-free Eq. 8 term: ``Σ σ(S)`` over every score tensor in the tree."""
    leaves = jax.tree_util.tree_leaves(scores_tree)
    if not leaves:
        return jnp.float32(0.0)
    return sum(jax.nn.sigmoid(s).sum() for s in leaves)


def apply_pruning_to_param(name: str, w: jax.Array, scores: jax.Array,
                           r_b: float, block_size: int) -> jax.Array:
    """Dispatch: MSA-style 2-D block masks vs MLP column/row vectors by the
    score tensor's rank."""
    if scores.ndim == 2:
        return masked_weight(w, scores, r_b, block_size)
    axis = 1 if name.endswith(("w_int", "wi", "w_in")) else 0
    return masked_weight_vector(w, scores, r_b, axis=axis)


# ---------------------------------------------------------------------------
# Measured sparsity (for packing + Table VI reproduction)
# ---------------------------------------------------------------------------
def hard_block_mask(scores: jax.Array, r_b: float,
                    w_shape: Tuple[int, int], block_size: int) -> jax.Array:
    keep = num_kept_blocks(w_shape, block_size, r_b)
    return _hard_topk(scores, keep)


def density_stats(block_mask: jax.Array) -> Dict[str, float]:
    """Per-column density statistics used by the load balancer and the
    analytic complexity model (α in Table II)."""
    col_counts = block_mask.sum(axis=0)
    total = block_mask.shape[0]
    return {
        "density": float(block_mask.mean()),
        "alpha": float((col_counts / total).mean()),
        "max_col": int(col_counts.max()),
        "min_col": int(col_counts.min()),
    }
