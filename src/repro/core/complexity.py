"""Analytic computational-complexity models (paper Tables I & II) and model
size (Table VI reproduction).

Table I (dense encoder, batch B, N tokens, H heads, per-head dim D',
embedding D, MLP dim D_mlp):

    LayerNorm (×2)     : B·N·D
    Residual  (×2)     : B·N·D
    MSA   (×1)         : 4·B·H·N·D·D' + 2·B·H·N²·D'
    MLP   (×1)         : 2·B·N·D·D_mlp

Table II (pruned encoder):

    LN1 + Res1         : 2·B·N·D
    LN2 + Res2         : 2·B·N_kept·D
    MSA                : B·H_kept·N·D'·D·(3α + α') + 2·B·H_kept·N²·D'
    TDM                : B·N·(H + N + D)
    MLP                : 2·B·N_kept·D·D_mlp·α_mlp       (α_mlp = r_b)

The paper reports **MACs** in Table VI; these formulas count MACs
(1 MAC = 2 FLOPs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.configs.base import ModelConfig, PruningConfig


@dataclasses.dataclass
class EncoderDims:
    B: int
    N: int
    H: int
    Dp: int      # per-head dim D'
    D: int
    Dmlp: int


def dense_encoder_macs(d: EncoderDims) -> Dict[str, float]:
    ln = d.B * d.N * d.D
    res = d.B * d.N * d.D
    msa = 4 * d.B * d.H * d.N * d.D * d.Dp + 2 * d.B * d.H * d.N ** 2 * d.Dp
    mlp = 2 * d.B * d.N * d.D * d.Dmlp
    return {
        "layernorm": 2 * ln,
        "residual": 2 * res,
        "msa": msa,
        "mlp": mlp,
        "total": 2 * ln + 2 * res + msa + mlp,
    }


def pruned_encoder_macs(d: EncoderDims, *, alpha: float, alpha_proj: float,
                        h_kept: int, n_kept: int, alpha_mlp: float,
                        has_tdm: bool) -> Dict[str, float]:
    ln1 = d.B * d.N * d.D
    ln2 = d.B * n_kept * d.D
    res1 = d.B * d.N * d.D
    res2 = d.B * n_kept * d.D
    msa = (d.B * h_kept * d.N * d.Dp * d.D * (3 * alpha + alpha_proj)
           + 2 * d.B * h_kept * d.N ** 2 * d.Dp)
    tdm = d.B * d.N * (d.H + d.N + d.D) if has_tdm else 0
    mlp = 2 * d.B * n_kept * d.D * d.Dmlp * alpha_mlp
    return {
        "layernorm": ln1 + ln2,
        "residual": res1 + res2,
        "msa": msa,
        "tdm": tdm,
        "mlp": mlp,
        "total": ln1 + ln2 + res1 + res2 + msa + tdm + mlp,
    }


def vit_num_tokens(cfg: ModelConfig) -> int:
    n_patches = (cfg.image_size // cfg.patch_size) ** 2
    return n_patches + 1  # + CLS


def model_macs(cfg: ModelConfig, batch: int = 1,
               pruning: PruningConfig | None = None) -> Dict[str, float]:
    """End-to-end MACs for a ViT under the paper's pruning model.

    Token counts shrink at each TDM layer (keep top ⌈(N−1)·r_t⌉ + CLS + 1
    fused). Weight pruning contributes α = α' = α_mlp = r_b on average
    (global top-k keeps r_b of all blocks; the expected per-column retained
    ratio equals r_b)."""
    p = pruning or cfg.pruning
    N = vit_num_tokens(cfg)
    H = cfg.num_heads
    Dp = cfg.head_dim
    D = cfg.d_model
    Dmlp = cfg.d_ff

    if p.weight_pruning_enabled:
        alpha = alpha_proj = alpha_mlp = p.r_b
        # head-retention measured empirically stays near 1 for r_b >= 0.5
        h_kept = H
    else:
        alpha = alpha_proj = alpha_mlp = 1.0
        h_kept = H

    per_layer: List[Dict[str, float]] = []
    total = 0.0
    n = N
    for layer in range(cfg.num_layers):
        has_tdm = p.token_pruning_enabled and layer in p.tdm_layers
        if has_tdm:
            n_body = n - 1
            n_kept = 1 + max(1, math.ceil(n_body * p.r_t)) + 1
        else:
            n_kept = n
        d = EncoderDims(B=batch, N=n, H=H, Dp=Dp, D=D, Dmlp=Dmlp)
        if p.weight_pruning_enabled or p.token_pruning_enabled:
            macs = pruned_encoder_macs(
                d, alpha=alpha, alpha_proj=alpha_proj, h_kept=h_kept,
                n_kept=n_kept, alpha_mlp=alpha_mlp, has_tdm=has_tdm)
        else:
            macs = dense_encoder_macs(d)
        per_layer.append(macs)
        total += macs["total"]
        n = n_kept
    # patch embedding + classifier head
    embed = batch * (N - 1) * (cfg.patch_size ** 2 * 3) * D
    head = batch * cfg.num_classes * D
    total += embed + head
    return {"total": total, "per_layer": per_layer, "embed": embed,
            "head": head}


def model_size_bytes(cfg: ModelConfig, pruning: PruningConfig | None = None,
                     dtype_bytes: int = 4) -> int:
    """Paper-style model size. Pruned MSA tensors store only surviving
    blocks (+4-byte headers per block); pruned MLP tensors shrink by r_b;
    embeddings / LN / biases stay dense. The paper's Table VI sizes are in
    fp32 'M parameters' equivalents (22M baseline)."""
    p = pruning or cfg.pruning
    D, H, Dp, Dmlp = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.d_ff
    r = p.r_b if p.weight_pruning_enabled else 1.0
    b = p.block_size

    msa_dense = 4 * D * H * Dp  # q,k,v,proj
    mlp_dense = 2 * D * Dmlp
    per_layer = 0
    if p.weight_pruning_enabled:
        n_blocks_msa = 4 * math.ceil(D / b) * math.ceil(H * Dp / b)
        kept = math.ceil(n_blocks_msa * r)
        per_layer += kept * b * b * dtype_bytes + kept * 4
        per_layer += int(mlp_dense * r) * dtype_bytes
    else:
        per_layer += (msa_dense + mlp_dense) * dtype_bytes
    per_layer += (4 * D + 2 * D + Dmlp + 2 * 2 * D) * dtype_bytes  # biases+LN
    embed = ((cfg.patch_size ** 2 * 3) * D + (vit_num_tokens(cfg)) * D
             + cfg.num_classes * D) * dtype_bytes
    return cfg.num_layers * per_layer + embed


def compression_ratio(cfg: ModelConfig, pruning: PruningConfig) -> float:
    dense = model_size_bytes(cfg, PruningConfig())
    pruned = model_size_bytes(cfg, pruning)
    return dense / pruned
