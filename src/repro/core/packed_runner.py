"""Packed-model execution — the deployment path of the paper's accelerator.

After simultaneous pruning, ``pack_model`` hardens the masks and converts
every block-pruned attention weight into the block-compressed SBMM format
(load-balanced column order included). ``forward_vit_packed`` then runs the
ViT with those weights executed THROUGH the SBMM kernel — the software
twin of the MPCA executing the pruned model, validated end-to-end against
the masked-dense forward (tests/test_packed_runner.py).

MLP column/row-pruned weights stay dense-masked (the paper maps them to
DBMM — a dense matmul over the shrunken width — which XLA already emits).

Per-stage segmentation (serving.vision)
---------------------------------------
The forward is decomposed into *segments* whose boundaries are the TDM
layers — exactly the points where per-image token counts change:

    ("embed",)          patches -> tokens          (count = n_patches + 1)
    ("layers", lo, hi)  encoder layers [lo, hi)    (count constant)
    ("tdm", i)          encoder layer i with the TDM (count shrinks)
    ("head",)           final norm + CLS readout   (-> logits)

``forward_vit_packed`` composes the segments sequentially (one request,
offline), while the vision serving engine schedules each segment over a
*ragged* population of in-flight images, regrouping between segments
(``repro.serving.ragged_batcher``). ``PackedVitSegments`` owns the jitted
per-segment step functions behind a compile ledger, mirroring
``serving.runner.ModelRunner`` for the LM path.

Every segment optionally takes ``n_valid`` ([B] int32, real token count per
row): token-padded rows are masked out of attention and accumulate exactly
zero TDM score, so batching never leaks padding into a request's logits.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packing
from repro.core import quant as Q
from repro.core import token_pruning as TP
from repro.kernels.sbmm import sbmm
from repro.models import attention as A
from repro.models import layers as L
from repro.models import model as M
from repro.models import pruning_glue as PG


def pack_model(cfg: ModelConfig, params: Dict, scores: Dict,
               lanes: int = 8) -> Dict[str, packing.PackedWeight]:
    """Block-compress every masked attention weight. Returns
    {path: PackedWeight}; paths match pruning_glue.hard_masks keys."""
    masks = PG.hard_masks(cfg, params, scores)
    out = {}
    for path, mask in masks.items():
        layer_idx = int(path.split("/")[1])
        leafname = path.split("/")[-1]
        w = np.asarray(params["layers"][layer_idx]["attn"][leafname],
                       np.float32)
        out[path] = packing.pack_weight(
            w, np.asarray(mask), cfg.pruning.block_size, lanes)
    return out


# ===========================================================================
# Stage plan
# ===========================================================================
Segment = Tuple  # ("embed",) | ("layers", lo, hi) | ("tdm", i) | ("head",)


def vit_segments(cfg: ModelConfig,
                 use_tdm: Optional[bool] = None) -> Tuple[Segment, ...]:
    """Per-stage segmentation of the packed ViT forward: one segment per
    maximal run of constant token count, TDM layers as their own segments
    (prune boundaries ARE batching boundaries for the serving engine)."""
    p = cfg.pruning
    if use_tdm is None:
        use_tdm = p.token_pruning_enabled
    tdm_layers = sorted(p.tdm_layers) if use_tdm else []
    segs: List[Segment] = [("embed",)]
    prev = 0
    for t in tdm_layers:
        if not 0 <= t < cfg.num_layers:
            raise ValueError(f"tdm layer {t} outside [0, {cfg.num_layers})")
        if t > prev:
            segs.append(("layers", prev, t))
        segs.append(("tdm", t))
        prev = t + 1
    if prev < cfg.num_layers:
        segs.append(("layers", prev, cfg.num_layers))
    segs.append(("head",))
    return tuple(segs)


def tdm_keep_count(n_tokens: int, r_t: float) -> int:
    """Static top-k count for a TDM applied at a *real* token count of
    ``n_tokens`` (CLS included) — the per-request ``k`` the serving engine
    passes into padded TDM segments. Derived from ``TP.num_kept_tokens``
    (the one source of truth for the clamp rule): output count is
    ``1 (CLS) + k + 1 (fused)``."""
    return TP.num_kept_tokens(n_tokens, r_t, has_cls=True) - 2


def tdm_soft_keep_count(n_tokens: int, r_t: float, has_pkg: bool) -> int:
    """Static top-k count for a SOFT TDM at ``n_tokens`` real tokens. Same
    rule as :func:`tdm_keep_count`, except that once a package row exists
    (``has_pkg``: every soft TDM after the first) it is pinned — the top-k
    draws from the ``n_tokens - 2`` real body rows, so ``k`` clamps there
    (only binds as ``r_t -> 1``; output count ``k + 2`` then never exceeds
    the input count, unlike the hard TDM's ``+1`` fused-row growth)."""
    k = tdm_keep_count(n_tokens, r_t)
    return min(k, n_tokens - 2) if has_pkg else k


def keep_schedule(cfg: ModelConfig, r_t: Optional[float] = None,
                  use_tdm: Optional[bool] = None) -> Tuple[float, ...]:
    """Uniform per-step keep schedule: ``r_t`` (default ``cfg.pruning.r_t``)
    broadcast over every TDM segment of ``vit_segments``, in segment order.
    The serving engine generalizes this — requests may carry a non-uniform
    schedule, and the QualityController may tighten entries at plan time —
    but a scalar ``r_t`` is always exactly this broadcast."""
    if r_t is None:
        r_t = cfg.pruning.r_t
    n_tdm = sum(1 for seg in vit_segments(cfg, use_tdm)
                if seg[0] == "tdm")
    return (float(r_t),) * n_tdm


def token_trajectory(cfg: ModelConfig, n_patches: int,
                     r_t: Optional[float] = None,
                     use_tdm: Optional[bool] = None,
                     schedule: Optional[Sequence[float]] = None,
                     soft: bool = False) -> Tuple[int, ...]:
    """Real token count a single image carries *after* each segment of
    ``vit_segments`` (head repeats the final count). Drives the ragged
    batcher's bucket keys and the prune-pressure-aware admission policy.

    ``schedule`` gives the keep rate per TDM segment (in segment order);
    ``None`` broadcasts ``r_t`` over every TDM segment (the classic
    frozen-scalar behavior, now a special case). ``soft`` prices the
    soft-pruning variant (``tdm_soft_keep_count``'s package-row clamp)."""
    n = n_patches + 1  # + CLS
    counts = []
    ordinal = 0
    if schedule is None:
        schedule_t: Tuple[float, ...] = keep_schedule(cfg, r_t, use_tdm)
    else:
        schedule_t = tuple(float(r) for r in schedule)
    for seg in vit_segments(cfg, use_tdm):
        if seg[0] == "tdm":
            if ordinal >= len(schedule_t):
                raise ValueError(
                    f"keep schedule has {len(schedule_t)} entries but the "
                    f"segment plan reaches TDM ordinal {ordinal}")
            r = schedule_t[ordinal]
            k = (tdm_soft_keep_count(n, r, has_pkg=ordinal > 0) if soft
                 else tdm_keep_count(n, r))
            n = k + 2
            ordinal += 1
        counts.append(n)
    return tuple(counts)


# ===========================================================================
# Segment bodies (pure functions; jitted by PackedVitSegments)
# ===========================================================================
def _proj(params: Dict, packed: Dict, i: int, name: str, inp: jax.Array
          ) -> jax.Array:
    key = f"layers/{i}/attn/{name}"
    if key in packed:
        return sbmm(inp, packed[key], tm=64)
    return L.linear(inp, params["layers"][i]["attn"][name])


def _encoder_attn(cfg: ModelConfig, params: Dict, packed: Dict,
                  x: jax.Array, i: int, *, collect_scores: bool = False,
                  n_valid: Optional[jax.Array] = None,
                  precision: str = "fp32"
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Attention sublayer + residual of encoder layer ``i`` (projections
    through SBMM when packed). ``n_valid`` masks token padding out of the
    attention and of the TDM scoring; padded rows' scores are exactly 0.

    ``precision`` is the quantized-serving knob: weight precision is
    carried by the ``packed`` dict itself (int8/fp16 entries dispatch the
    matching SBMM kernel), while ``"fp16"`` additionally quantizes the
    attention operands — q/k/v cast to float16 before the online-softmax
    attention (whose accumulation stays fp32) — with the output and TDM
    scores returned in fp32 so residuals and top-k run full-precision."""
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    lp = params["layers"][i]
    h = L.layer_norm(x, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
    Bc, Nc, _ = h.shape
    q = (_proj(params, packed, i, "wq", h)
         + lp["attn"].get("bq", 0.0)).reshape(Bc, Nc, H, Dh)
    k = (_proj(params, packed, i, "wk", h)
         + lp["attn"].get("bk", 0.0)).reshape(Bc, Nc, KV, Dh)
    v = (_proj(params, packed, i, "wv", h)
         + lp["attn"].get("bv", 0.0)).reshape(Bc, Nc, KV, Dh)
    if precision == "fp16":
        q = q.astype(jnp.float16)
        k = k.astype(jnp.float16)
        v = v.astype(jnp.float16)
    o = A.flash_attention_jnp(q, k, v, causal=False, kv_len=n_valid)
    o = o.astype(x.dtype)
    scores = None
    if collect_scores:
        probs = A.attention_probs_row(q[:, 0], k, kv_len=n_valid)
        scores = probs.mean(axis=1).astype(x.dtype)
    o = o.reshape(Bc, Nc, H * Dh)
    attn_out = _proj(params, packed, i, "wo", o) + lp["attn"].get("bo", 0.0)
    return x + attn_out, scores


def _encoder_mlp(cfg: ModelConfig, params: Dict, x: jax.Array,
                 i: int) -> jax.Array:
    lp = params["layers"][i]
    h = L.layer_norm(x, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
    return x + L.gelu_mlp(h, lp["mlp"])


def vit_embed(cfg: ModelConfig, params: Dict,
              patches: jax.Array) -> jax.Array:
    """patches [B, N, P²·3] -> tokens [B, N+1, D] (fp32, CLS prepended).
    Token-padded patch rows simply embed to don't-care rows; downstream
    segments mask them via ``n_valid``."""
    adt = jnp.float32  # kernel path runs fp32 end to end
    x = L.linear(patches.astype(adt), params["patch_embed"],
                 params["patch_bias"])
    B, N, D = x.shape
    cls = jnp.broadcast_to(params["cls"].astype(adt), (B, 1, D))
    x = jnp.concatenate([cls, x], axis=1)
    return x + params["pos"][None, : N + 1].astype(adt)


def vit_layers(cfg: ModelConfig, params: Dict, packed: Dict, x: jax.Array,
               lo: int, hi: int,
               n_valid: Optional[jax.Array] = None,
               precision: str = "fp32") -> jax.Array:
    """Encoder layers [lo, hi) at constant token count."""
    for i in range(lo, hi):
        x, _ = _encoder_attn(cfg, params, packed, x, i, n_valid=n_valid,
                             precision=precision)
        x = _encoder_mlp(cfg, params, x, i)
    return x


def vit_tdm_layer(cfg: ModelConfig, params: Dict, packed: Dict,
                  x: jax.Array, layer: int, r_t: Optional[float] = None,
                  k: Optional[int] = None,
                  n_valid: Optional[jax.Array] = None,
                  precision: str = "fp32") -> jax.Array:
    """Encoder layer ``layer`` with the TDM between its attention and MLP
    sublayers: [B, N, D] -> [B, k + 2, D] (CLS + k kept + fused). ``k``
    must be passed when rows are token-padded (see ``TP.tdm``); otherwise
    it derives from N and ``r_t`` exactly as the monolithic forward did."""
    if r_t is None:
        r_t = cfg.pruning.r_t
    x, scores = _encoder_attn(cfg, params, packed, x, layer,
                              collect_scores=True, n_valid=n_valid,
                              precision=precision)
    x, _ = TP.tdm(x, scores, r_t, has_cls=True, k=k)
    return _encoder_mlp(cfg, params, x, layer)


def vit_tdm_soft_layer(cfg: ModelConfig, params: Dict, packed: Dict,
                       x: jax.Array, layer: int, k: int,
                       pkg_mass: Optional[jax.Array] = None,
                       n_valid: Optional[jax.Array] = None,
                       precision: str = "fp32"
                       ) -> Tuple[jax.Array, jax.Array]:
    """Soft-pruning variant of :func:`vit_tdm_layer`: the dropped tokens
    fold into a persistent package token (``TP.tdm_soft``). Same output
    token count as the hard TDM, plus the accumulated package mass ([B])
    the NEXT soft TDM needs (``pkg_mass=None`` marks the first TDM, where
    no package row exists yet). With ``pkg_mass``, each row's package sits
    at its own valid-token boundary (body index ``n_valid - 2``) so
    token-padded tiles pin the right row."""
    x, scores = _encoder_attn(cfg, params, packed, x, layer,
                              collect_scores=True, n_valid=n_valid,
                              precision=precision)
    pkg_pos = None
    if pkg_mass is not None and n_valid is not None:
        pkg_pos = jnp.asarray(n_valid, jnp.int32) - 2
    x, mass = TP.tdm_soft(x, scores, has_cls=True, k=k, pkg_mass=pkg_mass,
                          pkg_pos=pkg_pos)
    return _encoder_mlp(cfg, params, x, layer), mass


def vit_head(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    """Final norm + CLS readout -> logits [B, num_classes] (fp32)."""
    x = L.layer_norm(x, params["ln_f_s"], params["ln_f_b"], cfg.norm_eps)
    logits = L.linear(x[:, 0], params["head"])
    return logits.astype(jnp.float32)


def run_fused_steps(cfg: ModelConfig, params: Dict, packed: Dict,
                    x: jax.Array, steps: Tuple[Tuple, ...],
                    pkg_mass: Optional[jax.Array] = None,
                    precision: str = "fp32") -> jax.Array:
    """Compose consecutive segments into ONE program: ``steps`` is a static
    tuple of ``(segment, k)`` pairs — or ``(segment, k, soft)`` triples for
    soft-pruning TDM steps (``k`` only for TDM segments). This is the
    express-lane body the planner compiles per trajectory for requests
    that are singletons in every bucket — unbatched and unpadded, so no
    ``n_valid`` is ever needed. All shapes are static given the entry shape
    and the ``k`` sequence. ``pkg_mass`` seeds the package mass for a lane
    entered AFTER a soft request's first TDM already ran tiled (``None``
    otherwise); the mass threads through in-program across soft steps.
    ``precision`` applies to the encoder steps only — embed and head run
    fp32 regardless, matching the tiled path's segment rule."""
    for step in steps:
        seg, k = step[0], step[1]
        soft = bool(step[2]) if len(step) > 2 else False
        kind = seg[0]
        if kind == "embed":
            x = vit_embed(cfg, params, x)
        elif kind == "layers":
            x = vit_layers(cfg, params, packed, x, seg[1], seg[2],
                           precision=precision)
        elif kind == "tdm":
            if k is None:
                raise ValueError("fused tdm steps need an explicit static k")
            if soft:
                x, pkg_mass = vit_tdm_soft_layer(cfg, params, packed, x,
                                                 seg[1], k=k,
                                                 pkg_mass=pkg_mass,
                                                 precision=precision)
            else:
                x = vit_tdm_layer(cfg, params, packed, x, seg[1], k=k,
                                  precision=precision)
                pkg_mass = None  # a hard TDM drops/keeps the package like
                #                  any token; its mass is meaningless after
        elif kind == "head":
            x = vit_head(cfg, params, x)
        else:
            raise ValueError(f"unknown segment {seg!r} in fused steps")
    return x


# ===========================================================================
# Offline single-batch forward — the segments composed sequentially
# ===========================================================================
# Executor memo for forward_vit_packed: id-keyed is safe here because the
# cached executor holds strong references to its params/packed trees, which
# pins their ids for exactly as long as the entry lives. Bounded FIFO so
# sweeps over many packed models don't accumulate jit caches.
_SEGMENT_MEMO: "dict[Tuple, PackedVitSegments]" = {}
_SEGMENT_MEMO_CAP = 8


def _cached_segments(cfg, params, packed, use_tdm) -> "PackedVitSegments":
    # r_t / tdm_layers only matter through the segment plan (the executor
    # always receives k explicitly), so cfgs differing only in keep rate —
    # the per-request-r_t reference loop — share one executor
    import dataclasses as _dc
    plan = vit_segments(cfg, use_tdm)
    cfg_norm = cfg.replace(pruning=_dc.replace(cfg.pruning, r_t=1.0,
                                               tdm_layers=()))
    key = (plan, cfg_norm, id(params), id(packed))
    runner = _SEGMENT_MEMO.get(key)
    if runner is None:
        runner = PackedVitSegments(cfg, params, packed, use_tdm=use_tdm)
        if len(_SEGMENT_MEMO) >= _SEGMENT_MEMO_CAP:
            _SEGMENT_MEMO.pop(next(iter(_SEGMENT_MEMO)))
        _SEGMENT_MEMO[key] = runner
    return runner
def forward_vit_packed(cfg: ModelConfig, params: Dict,
                       packed: Dict[str, packing.PackedWeight],
                       patches: jax.Array,
                       use_tdm: bool | None = None,
                       segments: "Optional[PackedVitSegments]" = None,
                       schedule: Optional[Sequence[float]] = None,
                       soft: bool = False,
                       precision: str = "fp32") -> M.Output:
    """ViT forward with attention projections executed via the SBMM kernel
    (interpret mode on CPU; native Pallas on TPU backends).

    ``params`` should be the MASKED tree (``PG.apply_pruning``) so the
    MLPs run masked-dense (the paper's DBMM path); the SBMM-packed
    attention weights carry their masks structurally.

    This is the single-request oracle the vision serving engine is
    bit-exact against: it walks the same ``vit_segments`` plan through the
    same *jitted* segment executor, unbatched and unpadded. (Executing the
    segments jitted matters for exactness — XLA's fusion choices shift FP
    reduction order relative to op-by-op eager dispatch, and jitted
    programs are deterministic given the HLO.) Pass ``segments`` to reuse
    an already-compiled executor (e.g. an engine's); otherwise one is
    memoized per (cfg, params, packed, use_tdm) so repeated calls — batch
    evaluation loops, parity tests — compile once.

    ``schedule`` is a per-TDM-segment keep schedule (``None`` broadcasts
    ``cfg.pruning.r_t``) and ``soft`` selects the package-token soft TDM —
    together the offline oracle for the serving engine's quality-elastic
    and soft-pruning paths. ``precision`` runs the encoder segments
    through the quantized weight set + kernels (``repro.core.quant``) —
    the single-request oracle for the engine's quantized tiles."""
    runner = segments if segments is not None else _cached_segments(
        cfg, params, packed, use_tdm)
    if schedule is None:
        schedule = keep_schedule(cfg, use_tdm=use_tdm)
    x = patches
    n = patches.shape[1] + 1  # + CLS after embed
    pkg_mass = None
    ordinal = 0
    for seg in runner.plan:
        if seg[0] == "tdm":
            r = schedule[ordinal]
            if soft:
                k = tdm_soft_keep_count(n, r, has_pkg=ordinal > 0)
                x, pkg_mass = runner.run(seg, x, k=k, soft=True,
                                         pkg_mass=pkg_mass,
                                         precision=precision)
            else:
                k = tdm_keep_count(n, r)
                x = runner.run(seg, x, k=k, precision=precision)
            n = k + 2
            ordinal += 1
        elif seg[0] == "head":
            return M.Output(runner.run(seg, x))
        else:
            x = runner.run(seg, x, precision=precision)
    raise AssertionError("vit_segments plan must end with ('head',)")


def masked_dense_reference(cfg: ModelConfig, params: Dict, scores: Dict,
                           patches: jax.Array,
                           use_tdm: bool | None = None) -> M.Output:
    """Oracle: same model with masked-dense weights (fp32 activations to
    match the kernel path's numerics)."""
    masked = PG.apply_pruning(cfg, params, scores)
    cfg32 = cfg.replace(dtype="float32")
    return M.forward_vit(cfg32, masked, patches, use_tdm=use_tdm)


# ===========================================================================
# Jitted segment executor (the vision serving engine's ModelRunner analog)
# ===========================================================================
class PackedVitSegments:
    """Owns the jitted per-segment step functions for one
    (cfg, params, packed) triple, behind a compile ledger.

    Shape discipline mirrors ``serving.runner.ModelRunner``: each distinct
    (segment, batch tile, token tile, masked?) combination compiles once;
    ``compile_count`` is our ledger and ``jit_compile_count()`` asks the
    jit caches themselves. The ragged batcher bounds the distinct
    combinations to its bucket set."""

    def __init__(self, cfg: ModelConfig, params: Dict,
                 packed: Dict[str, packing.PackedWeight],
                 use_tdm: Optional[bool] = None,
                 donate_activations: bool = False,
                 quant_granularity: str = "channel"):
        self.cfg = cfg
        self.params = params
        self.packed = packed
        self.plan = vit_segments(cfg, use_tdm)
        self.donate_activations = donate_activations
        if quant_granularity not in Q.GRANULARITIES:
            raise ValueError(
                f"quant_granularity must be one of {Q.GRANULARITIES}, "
                f"got {quant_granularity!r}")
        self.quant_granularity = quant_granularity
        # Quantized packed dicts are derived lazily on first use — an
        # fp32-only engine never pays the quantization pass, and precisions
        # share the one params tree (embed/MLP/head weights are
        # precision-independent: only the SBMM-packed attention weights
        # re-quantize).
        self._packed_by: Dict[str, Dict] = {"fp32": packed}
        # Only the "layers" segment preserves the activation shape
        # [B, n, D] input->output, so only its input tile is donatable
        # (embed/tdm/head change shapes — donating them would just warn
        # and allocate anyway). Donation requires callers never to re-read
        # a dispatched tile: the serving engine stages a fresh padded
        # batch per tile and forward_vit_packed rebinds x each segment,
        # so both satisfy it; keep the default off for ad-hoc callers
        # that reuse inputs across calls (e.g. timing probes).
        don = dict(donate_argnums=(2,)) if donate_activations else {}
        self._embed = jax.jit(
            lambda params, patches: vit_embed(cfg, params, patches))
        self._layers = jax.jit(
            lambda params, packed, x, n_valid, lo, hi, prec: vit_layers(
                cfg, params, packed, x, lo, hi, n_valid=n_valid,
                precision=prec),
            static_argnames=("lo", "hi", "prec"), **don)
        self._tdm = jax.jit(
            lambda params, packed, x, n_valid, layer, k, prec: vit_tdm_layer(
                cfg, params, packed, x, layer, k=k, n_valid=n_valid,
                precision=prec),
            static_argnames=("layer", "k", "prec"))
        self._tdm_soft = jax.jit(
            lambda params, packed, x, n_valid, pkg_mass, layer, k, prec:
            vit_tdm_soft_layer(cfg, params, packed, x, layer, k=k,
                               pkg_mass=pkg_mass, n_valid=n_valid,
                               precision=prec),
            static_argnames=("layer", "k", "prec"))
        self._head = jax.jit(lambda params, x: vit_head(cfg, params, x))
        self._fused = jax.jit(
            lambda params, packed, x, pkg_mass, steps, prec: run_fused_steps(
                cfg, params, packed, x, steps, pkg_mass=pkg_mass,
                precision=prec),
            static_argnames=("steps", "prec"))
        self._compiled: set = set()
        self._fused_trajectories: set = set()

    def packed_for(self, precision: str) -> Dict:
        """The packed dict at ``precision`` — quantized lazily on first use
        (``fp32`` is the original dict; ``fp16``/``int8`` derive from it
        via :func:`repro.core.quant.quantize_packed_dict` at this runner's
        ``quant_granularity``) and memoized so every tile/lane at a given
        precision shares one set of device buffers."""
        if precision not in Q.PRECISIONS:
            raise ValueError(
                f"precision must be one of {Q.PRECISIONS}, "
                f"got {precision!r}")
        pk = self._packed_by.get(precision)
        if pk is None:
            pk = Q.quantize_packed_dict(self.packed, precision,
                                        self.quant_granularity)
            self._packed_by[precision] = pk
        return pk

    def _ledger_key(self, base: Tuple, precision: str) -> Tuple:
        # fp32 keys stay byte-identical to the pre-quantization ledger so
        # fp32 compile counts / digests are unchanged; other precisions
        # append a marker (soft-marker ordering preserved: soft, then
        # precision).
        return base if precision == "fp32" else base + (precision,)

    def run(self, seg: Segment, x: jax.Array,
            n_valid: Optional[np.ndarray] = None,
            k: Optional[int] = None, soft: bool = False,
            pkg_mass: Optional[jax.Array] = None,
            precision: str = "fp32"):
        """Execute one segment on a dense tile ``x``. ``n_valid`` ([B]) is
        required whenever rows are token-padded; ``k`` is required for
        ``tdm`` segments (uniform across the tile by batcher construction).
        ``soft`` selects the package-token TDM variant: the call takes the
        tile's accumulated package masses (``None`` before the first TDM)
        and returns ``(y, new_mass)`` instead of ``y``. ``precision``
        selects the quantized weight set + kernels for the encoder
        segments; embed and head ignore it (always fp32, so those tiles
        are shared across precisions and never recompile).
        """
        kind = seg[0]
        nv = None if n_valid is None else jnp.asarray(n_valid, jnp.int32)
        base = ((seg, tuple(x.shape), nv is not None, k, "soft") if soft
                else (seg, tuple(x.shape), nv is not None, k))
        if kind == "embed":
            self._compiled.add(base)
            return self._embed(self.params, x)
        if kind == "layers":
            self._compiled.add(self._ledger_key(base, precision))
            return self._layers(self.params, self.packed_for(precision),
                                x, nv, lo=seg[1], hi=seg[2], prec=precision)
        if kind == "tdm":
            if k is None:
                raise ValueError("tdm segments need an explicit static k "
                                 "(per-request keep count)")
            self._compiled.add(self._ledger_key(base, precision))
            if soft:
                return self._tdm_soft(self.params,
                                      self.packed_for(precision), x, nv,
                                      pkg_mass, layer=seg[1], k=k,
                                      prec=precision)
            return self._tdm(self.params, self.packed_for(precision), x, nv,
                             layer=seg[1], k=k, prec=precision)
        if kind == "head":
            self._compiled.add(base)
            return self._head(self.params, x)
        raise ValueError(f"unknown segment {seg!r}")

    def run_fused(self, steps: Tuple[Tuple, ...], x: jax.Array,
                  pkg_mass: Optional[jax.Array] = None,
                  precision: str = "fp32") -> jax.Array:
        """Express lane: execute ``steps`` — consecutive ``(segment, k)``
        pairs, or ``(segment, k, soft)`` triples for soft TDM steps — as
        ONE jitted trajectory program (one dispatch for the whole remaining
        forward of a bucket-singleton request). ``pkg_mass`` ([1]) seeds
        the package mass when the lane starts after a soft request's first
        TDM. Compiles once per distinct (steps, entry shape, precision);
        the per-trajectory ledger is ``fused_trajectory_count`` and its
        keys bound the extra jit entries beyond the tile bucket set."""
        steps = tuple(
            (tuple(s[0]), None if s[1] is None else int(s[1]))
            + ((True,) if len(s) > 2 and s[2] else ())
            for s in steps)
        if not steps:
            raise ValueError("fused run needs at least one step")
        traj_key = self._ledger_key((steps, tuple(x.shape)), precision)
        self._fused_trajectories.add(traj_key)
        self._compiled.add(self._ledger_key(
            (("fused",) + steps, tuple(x.shape), False, None), precision))
        return self._fused(self.params, self.packed_for(precision),
                           jnp.asarray(x), pkg_mass, steps=steps,
                           prec=precision)

    # -- compile observability ---------------------------------------------
    @property
    def compile_count(self) -> int:
        """Distinct segment tiles dispatched so far (our ledger)."""
        return len(self._compiled)

    def compiled_tiles(self) -> List[Tuple]:
        return sorted(self._compiled, key=repr)

    @property
    def fused_trajectory_count(self) -> int:
        """Distinct fused trajectory programs dispatched (the express-lane
        half of the bucket ∪ trajectory recompile bound)."""
        return len(self._fused_trajectories)

    def jit_compile_count(self) -> int:
        """Total entries across the jit caches (what XLA actually
        compiled), fused trajectory programs included."""
        total = 0
        for fn in (self._embed, self._layers, self._tdm, self._tdm_soft,
                   self._head, self._fused):
            try:
                total += fn._cache_size()
            except AttributeError:  # older jax: fall back to the ledger
                return self.compile_count
        return total
