"""Packed-model execution — the deployment path of the paper's accelerator.

After simultaneous pruning, ``pack_model`` hardens the masks and converts
every block-pruned attention weight into the block-compressed SBMM format
(load-balanced column order included). ``forward_vit_packed`` then runs the
ViT with those weights executed THROUGH the SBMM kernel — the software
twin of the MPCA executing the pruned model, validated end-to-end against
the masked-dense forward (tests/test_packed_runner.py).

MLP column/row-pruned weights stay dense-masked (the paper maps them to
DBMM — a dense matmul over the shrunken width — which XLA already emits).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packing
from repro.core import token_pruning as TP
from repro.kernels.sbmm import sbmm
from repro.models import attention as A
from repro.models import layers as L
from repro.models import model as M
from repro.models import pruning_glue as PG


def pack_model(cfg: ModelConfig, params: Dict, scores: Dict,
               lanes: int = 8) -> Dict[str, packing.PackedWeight]:
    """Block-compress every masked attention weight. Returns
    {path: PackedWeight}; paths match pruning_glue.hard_masks keys."""
    masks = PG.hard_masks(cfg, params, scores)
    out = {}
    for path, mask in masks.items():
        layer_idx = int(path.split("/")[1])
        leafname = path.split("/")[-1]
        w = np.asarray(params["layers"][layer_idx]["attn"][leafname],
                       np.float32)
        out[path] = packing.pack_weight(
            w, np.asarray(mask), cfg.pruning.block_size, lanes)
    return out


def forward_vit_packed(cfg: ModelConfig, params: Dict,
                       packed: Dict[str, packing.PackedWeight],
                       patches: jax.Array,
                       use_tdm: bool | None = None) -> M.Output:
    """ViT forward with attention projections executed via the SBMM kernel
    (interpret mode on CPU; native Pallas on TPU backends).

    ``params`` should be the MASKED tree (``PG.apply_pruning``) so the
    MLPs run masked-dense (the paper's DBMM path); the SBMM-packed
    attention weights carry their masks structurally."""
    p = cfg.pruning
    if use_tdm is None:
        use_tdm = p.token_pruning_enabled
    adt = jnp.float32  # kernel path runs fp32 end to end

    x = L.linear(patches.astype(adt), params["patch_embed"],
                 params["patch_bias"])
    B, N, D = x.shape
    cls = jnp.broadcast_to(params["cls"].astype(adt), (B, 1, D))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + params["pos"][None, : N + 1].astype(adt)

    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    for i, lp in enumerate(params["layers"]):
        has_tdm = use_tdm and (i in p.tdm_layers)
        h = L.layer_norm(x, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        Bc, Nc, _ = h.shape

        def proj(name, inp):
            key = f"layers/{i}/attn/{name}"
            if key in packed:
                return sbmm(inp, packed[key], tm=64)
            return L.linear(inp, lp["attn"][name])

        q = (proj("wq", h) + lp["attn"].get("bq", 0.0)).reshape(
            Bc, Nc, H, Dh)
        k = (proj("wk", h) + lp["attn"].get("bk", 0.0)).reshape(
            Bc, Nc, KV, Dh)
        v = (proj("wv", h) + lp["attn"].get("bv", 0.0)).reshape(
            Bc, Nc, KV, Dh)
        o = A.flash_attention_jnp(q, k, v, causal=False)
        tdm_scores = None
        if has_tdm:
            probs = A.attention_probs_row(q[:, 0], k)
            tdm_scores = probs.mean(axis=1)
        o = o.reshape(Bc, Nc, H * Dh)
        attn_out = proj("wo", o) + lp["attn"].get("bo", 0.0)
        x = x + attn_out
        if has_tdm:
            x, _ = TP.tdm(x, tdm_scores, p.r_t, has_cls=True)
        h = L.layer_norm(x, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["mlp"])

    x = L.layer_norm(x, params["ln_f_s"], params["ln_f_b"], cfg.norm_eps)
    logits = L.linear(x[:, 0], params["head"])
    return M.Output(logits.astype(jnp.float32))


def masked_dense_reference(cfg: ModelConfig, params: Dict, scores: Dict,
                           patches: jax.Array,
                           use_tdm: bool | None = None) -> M.Output:
    """Oracle: same model with masked-dense weights (fp32 activations to
    match the kernel path's numerics)."""
    masked = PG.apply_pruning(cfg, params, scores)
    cfg32 = cfg.replace(dtype="float32")
    return M.forward_vit(cfg32, masked, patches, use_tdm=use_tdm)
