"""Block-compressed weight format + offline load balancing (paper §V-A, §V-D1).

The FPGA stores pruned weights column-major as *blocks*: per block-column, a
header of surviving row-block indices followed by the blocks themselves. The
MPCA's PE columns then gather the matching input row-blocks by header index.

TPU adaptation (DESIGN.md §2): the MXU wants lane-aligned tiles, so we keep
the *logical* pruning granularity ``b×b`` (16/32 — the accuracy-relevant knob)
but pack the surviving blocks into a dense **gathered** tensor

    blocks  : [n_cols, max_kept, b, b]   (zero-padded per column)
    header  : [n_cols, max_kept] int32   (row-block index, -1 = padding)
    counts  : [n_cols]          int32

so the SBMM Pallas kernel streams contiguous VMEM tiles and uses the header
to gather input row-blocks — the exact analog of the paper's CB/GFB flow.

Offline load balancing: block-wise top-k is *global*, so per-column block
counts differ. ``balance_columns`` computes a column permutation that snake-
assigns columns (sorted by block count) across the ``p_c``-analog lanes so
each lane's total work is near-equal; the permutation is folded into the
output layout, and the inverse permutation is fused into the next operator's
input gather (free at runtime).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class PackedWeight:
    """Block-compressed representation of a pruned weight matrix."""

    blocks: jnp.ndarray   # [n_cols, max_kept, b, b]
    header: jnp.ndarray   # [n_cols, max_kept] int32; -1 padding
    counts: jnp.ndarray   # [n_cols] int32
    col_perm: np.ndarray  # permutation applied to block-columns
    shape: Tuple[int, int]
    block_size: int

    @property
    def n_cols(self) -> int:
        return self.blocks.shape[0]

    @property
    def max_kept(self) -> int:
        return self.blocks.shape[1]

    def nbytes(self) -> int:
        """Model-size contribution: stored blocks + headers (paper metric),
        each at its actual dtype width — fp16-quantized blocks halve the
        block term; the header term follows the header dtype rather than
        assuming 4 bytes."""
        kept = int(np.asarray(self.counts).sum())
        b = self.block_size
        return (kept * b * b * self.blocks.dtype.itemsize
                + kept * self.header.dtype.itemsize)

    def to_dense(self) -> jnp.ndarray:
        """Reconstruct the (masked) dense weight — the packing oracle."""
        m1, m2 = self.shape
        b = self.block_size
        n_rows = math.ceil(m1 / b)
        n_cols = self.n_cols
        dense = np.zeros((n_rows * b, n_cols * b), dtype=self.blocks.dtype)
        blocks = np.asarray(self.blocks)
        header = np.asarray(self.header)
        for pc in range(n_cols):
            c = int(self.col_perm[pc])  # logical column stored at slot pc
            for s in range(self.max_kept):
                r = int(header[pc, s])
                if r < 0:
                    continue
                dense[r * b:(r + 1) * b, c * b:(c + 1) * b] = blocks[pc, s]
        return jnp.asarray(dense[:m1, :m2])


# PackedWeight is a jit-traversable pytree: the device tensors are children,
# the host-side layout metadata (permutation, logical shape, granularity)
# rides as hashable static aux data — so {path: PackedWeight} dicts can be
# passed straight into jitted segment runners (serving.vision) instead of
# being baked into the trace as constants.
def _pw_flatten(pw: "PackedWeight"):
    children = (pw.blocks, pw.header, pw.counts)
    aux = (tuple(int(c) for c in np.asarray(pw.col_perm)),
           tuple(pw.shape), pw.block_size)
    return children, aux


def _pw_unflatten(aux, children) -> "PackedWeight":
    col_perm, shape, block_size = aux
    blocks, header, counts = children
    return PackedWeight(blocks=blocks, header=header, counts=counts,
                        col_perm=np.asarray(col_perm, dtype=np.int64),
                        shape=tuple(shape), block_size=block_size)


jax.tree_util.register_pytree_node(PackedWeight, _pw_flatten, _pw_unflatten)


def balance_columns(col_counts: np.ndarray, lanes: int = 8) -> np.ndarray:
    """Offline workload assignment (paper §V-D1): a deterministic column
    permutation such that processing columns in ``perm`` order with
    round-robin lane assignment (lane ``i`` handles ``perm[i::lanes]``)
    balances the per-lane block totals.

    Heaviest-first ordering + round-robin is the classic LPT heuristic: the
    max lane load is within (4/3 − 1/3·lanes) of optimal. ``lane_loads``
    audits the result in tests."""
    return np.argsort(-np.asarray(col_counts), kind="stable")


def lane_loads(col_counts: np.ndarray, perm: np.ndarray, lanes: int) -> np.ndarray:
    """Per-lane total blocks when columns are processed in ``perm`` order with
    round-robin lane assignment — the balance audit used in tests."""
    loads = np.zeros(lanes, dtype=np.int64)
    for i, col in enumerate(perm):
        loads[i % lanes] += col_counts[col]
    return loads


def pack_weight(w: np.ndarray, block_mask: np.ndarray, block_size: int,
                lanes: int = 8) -> PackedWeight:
    """Pack ``w`` under ``block_mask`` (shape ``score_shape(w.shape, b)``)."""
    m1, m2 = w.shape
    b = block_size
    n_rows, n_cols = block_mask.shape
    pad = np.zeros((n_rows * b, n_cols * b), dtype=w.dtype)
    pad[:m1, :m2] = w

    col_counts = block_mask.sum(axis=0).astype(np.int64)
    perm = balance_columns(col_counts, lanes)
    max_kept = max(1, int(col_counts.max()))

    blocks = np.zeros((n_cols, max_kept, b, b), dtype=w.dtype)
    header = np.full((n_cols, max_kept), -1, dtype=np.int32)
    counts = np.zeros((n_cols,), dtype=np.int32)
    for pc, c in enumerate(perm):
        rows = np.nonzero(block_mask[:, c])[0]
        counts[pc] = len(rows)
        for s, r in enumerate(rows):
            header[pc, s] = r
            blocks[pc, s] = pad[r * b:(r + 1) * b, c * b:(c + 1) * b]
    return PackedWeight(
        blocks=jnp.asarray(blocks),
        header=jnp.asarray(header),
        counts=jnp.asarray(counts),
        col_perm=np.asarray(perm),
        shape=(m1, m2),
        block_size=b,
    )


def packed_model_size_bytes(masks_and_weights, block_size: int,
                            dtype_bytes: int = 2,
                            header_bytes: int = 4,
                            scale_bytes: int = 0,
                            scales_per_block: int = 1) -> int:
    """Aggregate paper-style model size: only surviving blocks + headers for
    pruned tensors, full size for dense tensors.

    ``masks_and_weights``: iterable of (w_shape, block_mask or None).
    ``dtype_bytes`` is the stored element width (2 = the paper's int16
    weights; 4/2/1 for the serving fp32/fp16/int8 precisions —
    ``repro.core.quant.PRECISION_BYTES``); ``header_bytes`` the per-kept-
    block index width; ``scale_bytes`` (× ``scales_per_block`` per kept
    block, e.g. ``block_size`` for per-output-channel scales) accounts for
    quantization scales, so the model-size columns stay honest across
    precisions."""
    total = 0
    per_block_meta = header_bytes + scale_bytes * scales_per_block
    for w_shape, mask in masks_and_weights:
        if mask is None:
            total += int(np.prod(w_shape)) * dtype_bytes
        else:
            kept = int(np.asarray(mask).sum())
            total += (kept * block_size * block_size * dtype_bytes
                      + kept * per_block_meta)
    return total
