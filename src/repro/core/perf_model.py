"""Cycle-accurate performance model of the paper's accelerator (Table III,
Algorithm 2, §V-E) plus the TPU v5e roofline constants used by §Roofline.

FPGA cycle model (from Algorithm 2's loop nest): multiplying an
``(M1, M2)`` input by an ``(M2, D)`` weight, with ``H`` heads, block size
``b``, PE-array ``p_h × p_t × p_c`` and ``p_pe²`` MACs per PE, and per-column
retained-block ratio ``φ`` (φ=1 for DBMM):

    cycles = ⌈H/p_h⌉ · ⌈⌈D'/b⌉/p_c⌉ · ⌈⌈M1/b⌉/p_t⌉ · (φ·⌈M2/b⌉) · b³/p_pe²

DHBMM (per-head dense, e.g. Q·Kᵀ) uses per-head matrix sizes with the same
nest. The paper's U250 instance: p_h=4, p_t=12, p_c=2, p_pe=8, 300 MHz.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs.base import ModelConfig, PruningConfig
from repro.core.complexity import vit_num_tokens


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    p_h: int = 4
    p_t: int = 12
    p_c: int = 2
    p_pe: int = 8
    freq_hz: float = 300e6

    @property
    def macs_per_cycle(self) -> int:
        return self.p_h * self.p_t * self.p_c * self.p_pe ** 2


PAPER_U250 = AcceleratorConfig()

# Precision throughput multipliers for the modeled PE array: halving the
# operand width doubles the MACs each p_pe² cell packs per cycle (the
# classic DSP-packing argument on FPGAs; on the MXU, int8/fp16 tiles hit
# the higher-throughput systolic modes). Deliberately coarse — the planner
# only needs the ORDERING (int8 < fp16 < fp32) and a stable ratio;
# ``TileCostModel.calibrate`` owns the absolute scale.
PRECISION_SPEEDUP = {"fp32": 1.0, "fp16": 2.0, "int8": 4.0}


def precision_speedup(precision: str) -> float:
    try:
        return PRECISION_SPEEDUP[precision]
    except KeyError:
        raise ValueError(
            f"precision must be one of {tuple(PRECISION_SPEEDUP)}, "
            f"got {precision!r}") from None


# TPU v5e roofline constants (per chip) — §Roofline hardware terms.
TPU_PEAK_FLOPS = 197e12      # bf16
TPU_HBM_BW = 819e9           # bytes/s
TPU_ICI_BW = 50e9            # bytes/s per link


def _ceil(a: float, b: float) -> int:
    return math.ceil(a / b)


def sbmm_cycles(M1: int, M2: int, D: int, H: int, b: int,
                acc: AcceleratorConfig, phi: float = 1.0,
                mode: str = "pipelined") -> int:
    """Cycles for SBMM/DBMM per Table III. ``D = H·D'``.

    ``mode="strict"`` evaluates Algorithm 2's loop nest literally (every
    partially-filled iteration costs a full iteration) — an upper bound.
    ``mode="pipelined"`` is work-conserving: leftover PE rows of one
    iteration are filled with the next iteration's blocks (the paper's MPCA
    streams column blocks back-to-back, which is how the reported 3.19 ms
    dense / 0.868 ms pruned latencies are achievable on 6144 MACs)."""
    Dp = D // max(H, 1)
    per_block = b * b * b / acc.p_pe ** 2
    inner = max(1, math.ceil(phi * _ceil(M2, b)))
    if mode == "strict":
        outer = (_ceil(H, acc.p_h)
                 * _ceil(_ceil(Dp, b), acc.p_c)
                 * _ceil(_ceil(M1, b), acc.p_t))
        return int(outer * inner * per_block)
    n_block_pairs = H * _ceil(Dp, b) * _ceil(M1, b) * inner
    pes = acc.p_h * acc.p_t * acc.p_c
    return int(math.ceil(n_block_pairs / pes) * per_block)


def dhbmm_cycles(M1: int, M2: int, D: int, H: int, b: int,
                 acc: AcceleratorConfig, mode: str = "pipelined") -> int:
    """Per-head dense block matmul (stage ii/iii: Q·Kᵀ, A·V). ``(M1, M2)``
    and ``(M2, D)`` are the per-head operand shapes."""
    per_block = b * b * b / acc.p_pe ** 2
    inner = _ceil(M2, b)
    if mode == "strict":
        outer = (_ceil(H, acc.p_h)
                 * _ceil(_ceil(D, b), acc.p_c)
                 * _ceil(_ceil(M1, b), acc.p_t))
        return int(outer * inner * per_block)
    n_block_pairs = H * _ceil(D, b) * _ceil(M1, b) * inner
    pes = acc.p_h * acc.p_t * acc.p_c
    return int(math.ceil(n_block_pairs / pes) * per_block)


def encoder_cycles(N: int, cfg: ModelConfig, p: PruningConfig,
                   acc: AcceleratorConfig, has_tdm: bool,
                   mode: str = "pipelined") -> Dict[str, int]:
    """Cycle estimate for one pruned encoder layer at token count ``N``."""
    D, H, Dp, Dmlp = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.d_ff
    b = p.block_size
    phi = p.r_b if p.weight_pruning_enabled else 1.0
    n_kept = N
    if has_tdm:
        n_kept = 1 + max(1, math.ceil((N - 1) * p.r_t)) + 1

    # stage i: Z(N×D) × W_qkv(D×3D)  — SBMM (sparse weights)
    qkv = sbmm_cycles(N, D, 3 * H * Dp, H, b, acc, phi, mode)
    # stage ii: per-head Q(N×D')·Kᵀ(D'×N) — DHBMM
    qk = dhbmm_cycles(N, Dp, N, H, b, acc, mode)
    # stage iii: per-head A(N×N)·V(N×D') — DHBMM
    av = dhbmm_cycles(N, N, Dp, H, b, acc, mode)
    # stage iv: concat(N×HD') × W_proj(HD'×D) — SBMM
    proj = sbmm_cycles(N, H * Dp, D, 1, b, acc, phi, mode)
    # TDM: bitonic sort network is fully pipelined; shuffle streams one token
    # row per cycle through the index network -> ~N·D/(b·p_pe²) cycles
    tdm = _ceil(N * D, b * acc.p_pe ** 2) if has_tdm else 0
    # softmax/GELU stream through the EM overlapped with MPCA; LN/residual
    # add a non-overlapped epilogue per stage
    em = 4 * _ceil(N * D, acc.p_h * acc.p_t * acc.p_c * acc.p_pe)
    # MLP: two DBMMs at reduced width (column/row pruning keeps r_b of D_mlp)
    dmlp_kept = int(Dmlp * phi)
    mlp1 = sbmm_cycles(n_kept, D, dmlp_kept, 1, b, acc, 1.0, mode)
    mlp2 = sbmm_cycles(n_kept, dmlp_kept, D, 1, b, acc, 1.0, mode)
    total = qkv + qk + av + proj + tdm + em + mlp1 + mlp2
    return {"qkv": qkv, "qk": qk, "av": av, "proj": proj, "tdm": tdm,
            "em": em, "mlp": mlp1 + mlp2, "total": total}


def vit_segment_cycles(cfg: ModelConfig, seg, n_tokens: int,
                       acc: AcceleratorConfig = PAPER_U250,
                       mode: str = "pipelined",
                       precision: str = "fp32") -> float:
    """Cycles for ONE image row of a ``core.packed_runner`` segment at a
    (padded) token count of ``n_tokens`` — the per-stage pricing the
    serving ``TileCostModel`` uses to trade padding against dispatches
    (merge decisions) and to estimate remaining work (deadline slack).
    Segment forms: ``("embed",) | ("layers", lo, hi) | ("tdm", i) |
    ("head",)``. ``precision`` scales the encoder-segment cost by the PE
    array's narrower-operand throughput (``PRECISION_SPEEDUP``); embed and
    head always run fp32 in the serving path, so only the weight-bearing
    ``layers``/``tdm`` segments get the discount."""
    p = cfg.pruning
    kind = seg[0]
    speed = precision_speedup(precision)
    if kind == "embed":
        pdim = cfg.patch_size ** 2 * 3
        return float(sbmm_cycles(n_tokens, pdim, cfg.d_model, 1,
                                 p.block_size, acc, mode=mode))
    if kind == "layers":
        return float((seg[2] - seg[1]) * encoder_cycles(
            n_tokens, cfg, p, acc, has_tdm=False, mode=mode)["total"]
            / speed)
    if kind == "tdm":
        return float(encoder_cycles(n_tokens, cfg, p, acc, has_tdm=True,
                                    mode=mode)["total"] / speed)
    if kind == "head":
        return float(sbmm_cycles(1, cfg.d_model, cfg.num_classes, 1,
                                 p.block_size, acc, mode=mode)
                     + dhbmm_cycles(n_tokens, cfg.d_model, 1, 1,
                                    p.block_size, acc, mode=mode))
    raise ValueError(f"unknown segment kind {kind!r}")


def model_latency_ms(cfg: ModelConfig, p: PruningConfig,
                     acc: AcceleratorConfig = PAPER_U250,
                     mode: str = "pipelined") -> Dict[str, float]:
    """End-to-end single-image latency on the paper's accelerator model."""
    N = vit_num_tokens(cfg)
    cycles = 0
    n = N
    for layer in range(cfg.num_layers):
        has_tdm = p.token_pruning_enabled and layer in p.tdm_layers
        c = encoder_cycles(n, cfg, p, acc, has_tdm, mode)
        cycles += c["total"]
        if has_tdm:
            n = 1 + max(1, math.ceil((n - 1) * p.r_t)) + 1
    latency_ms = cycles / acc.freq_hz * 1e3
    # DDR weight-streaming bound (77 GB/s on U250, int16 weights). The real
    # accelerator double-buffers CBs, so the achieved latency lies between
    # ``latency_ms`` (full overlap) and ``latency_ms + ddr_ms`` (no overlap);
    # the paper's Table VI values fall inside this bracket (see
    # benchmarks/perf_model_bench.py).
    from repro.core.complexity import model_size_bytes  # local: avoid cycle
    ddr_ms = model_size_bytes(cfg, p, dtype_bytes=2) / 77e9 * 1e3
    return {"cycles": cycles, "latency_ms": latency_ms, "ddr_ms": ddr_ms,
            "latency_noverlap_ms": latency_ms + ddr_ms,
            "throughput_ips": 1e3 / latency_ms}


def tpu_roofline_seconds(hlo_flops: float, hlo_bytes: float,
                         collective_bytes: float, chips: int,
                         ici_links: int = 4) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (whole-mesh execution)."""
    compute = hlo_flops / (chips * TPU_PEAK_FLOPS)
    memory = hlo_bytes / (chips * TPU_HBM_BW)
    collective = collective_bytes / (chips * ici_links * TPU_ICI_BW)
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant}
