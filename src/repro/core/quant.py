"""Quantized packed weights — the serving-era twin of the paper's pruning.

The paper prunes weights so the FPGA streams less and computes denser; the
deployment-side analog of "smaller weights, denser compute" is
quantization (HeatViT pairs 8-bit quantization with token pruning;
EdgeVisionTransformer applies float16 to pruned ViTs). This module extends
the block-compressed format (``core.packing.PackedWeight``) with symmetric
int8 quantization: the int8 blocks keep the exact ``blocks``/``header``
layout the SBMM kernel streams, and per-block (or per-output-channel)
float scales ride alongside as one extra pytree child the dequant-in-kernel
variant (``kernels.sbmm.sbmm_quant``) prefetches next to the header.

Precisions (the ``precision`` axis the serving stack threads through):

* ``fp32``  — the reference path, bit-exact with everything before it.
* ``fp16``  — weights stored as float16 (the fast path: the existing SBMM
  kernel already accumulates in fp32 via ``preferred_element_type``, so
  fp16 blocks ride it unchanged); attention runs on fp16-cast q/k/v.
* ``int8``  — symmetric per-block/per-channel int8 blocks + f32 scales,
  dequantized inside the kernel.

Scale granularities:

* ``"block"``   — one scale per kept b×b block (``scales [C, S]``).
* ``"channel"`` — one scale per output channel of each kept block
  (``scales [C, S, b]``, axis over the block's output columns) — tighter
  error bounds, the serving default.

Symmetric quantization: ``scale = max|w| / 127`` (1.0 where the block is
all-zero, so dequant stays exact there), ``q = clip(round(w / scale))``.
The roundtrip error is bounded by ``scale / 2`` per element — the property
tests assert exactly that bound across block sizes and granularities.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.packing import PackedWeight

__all__ = ["PRECISIONS", "PRECISION_BYTES", "GRANULARITIES",
           "QuantizedPackedWeight", "quantize_packed", "dequantize_packed",
           "quantization_error", "quantize_packed_dict",
           "packed_dict_nbytes", "max_abs_error"]

PRECISIONS = ("fp32", "fp16", "int8")
PRECISION_BYTES = {"fp32": 4, "fp16": 2, "int8": 1}
GRANULARITIES = ("block", "channel")

_QMAX = 127.0  # symmetric int8: [-127, 127] (keeps -128 unused; |q| <= 127)


@dataclasses.dataclass
class QuantizedPackedWeight:
    """Block-compressed weight with int8 blocks + float dequant scales.

    Same gathered layout as :class:`PackedWeight` (``blocks [C, S, b, b]``,
    ``header [C, S]``, ``counts [C]``, load-balancing ``col_perm``), plus
    ``scales`` — ``[C, S]`` for per-block granularity or ``[C, S, b]`` for
    per-output-channel. Registered as a pytree so {path: weight} dicts pass
    straight into jitted segment runners, exactly like PackedWeight."""

    blocks: jnp.ndarray   # [n_cols, max_kept, b, b] int8
    scales: jnp.ndarray   # [n_cols, max_kept] or [n_cols, max_kept, b] f32
    header: jnp.ndarray   # [n_cols, max_kept] int32; -1 padding
    counts: jnp.ndarray   # [n_cols] int32
    col_perm: np.ndarray
    shape: Tuple[int, int]
    block_size: int
    granularity: str = "block"

    @property
    def n_cols(self) -> int:
        return self.blocks.shape[0]

    @property
    def max_kept(self) -> int:
        return self.blocks.shape[1]

    def nbytes(self) -> int:
        """Model-size contribution: int8 blocks + headers + dequant scales,
        each at its actual dtype width (kept entries only)."""
        kept = int(np.asarray(self.counts).sum())
        b = self.block_size
        scales_per_block = b if self.granularity == "channel" else 1
        return (kept * b * b * self.blocks.dtype.itemsize
                + kept * self.header.dtype.itemsize
                + kept * scales_per_block * self.scales.dtype.itemsize)

    def to_dense(self) -> jnp.ndarray:
        """Dequantized dense reconstruction (the quantization oracle)."""
        return dequantize_packed(self).to_dense()


def _qpw_flatten(q: "QuantizedPackedWeight"):
    children = (q.blocks, q.scales, q.header, q.counts)
    aux = (tuple(int(c) for c in np.asarray(q.col_perm)),
           tuple(q.shape), q.block_size, q.granularity)
    return children, aux


def _qpw_unflatten(aux, children) -> "QuantizedPackedWeight":
    col_perm, shape, block_size, granularity = aux
    blocks, scales, header, counts = children
    return QuantizedPackedWeight(
        blocks=blocks, scales=scales, header=header, counts=counts,
        col_perm=np.asarray(col_perm, dtype=np.int64),
        shape=tuple(shape), block_size=block_size, granularity=granularity)


jax.tree_util.register_pytree_node(QuantizedPackedWeight, _qpw_flatten,
                                   _qpw_unflatten)


def _expand_scales(scales: np.ndarray) -> np.ndarray:
    """Broadcast scales over block elements: [C,S] -> [C,S,1,1] (block) or
    [C,S,b] -> [C,S,1,b] (per-output-channel — axis 3 is the block's
    output-column axis, matching ``x_blk @ w_blk``'s column scaling)."""
    if scales.ndim == 2:
        return scales[:, :, None, None]
    return scales[:, :, None, :]


def _symmetric_scales(blocks: np.ndarray, granularity: str) -> np.ndarray:
    if granularity not in GRANULARITIES:
        raise ValueError(f"granularity must be one of {GRANULARITIES}, "
                         f"got {granularity!r}")
    if granularity == "block":
        amax = np.abs(blocks).max(axis=(2, 3))        # [C, S]
    else:
        amax = np.abs(blocks).max(axis=2)             # [C, S, b]
    return np.where(amax > 0.0, amax / _QMAX, 1.0).astype(np.float32)


def quantize_packed(pw: PackedWeight, precision: str = "int8",
                    granularity: str = "block"
                    ) -> Union[PackedWeight, "QuantizedPackedWeight"]:
    """Quantize a packed weight to ``precision``.

    ``fp32`` returns ``pw`` unchanged; ``fp16`` returns a
    :class:`PackedWeight` with float16 blocks (rides the existing SBMM
    kernel — fp32 accumulation via ``preferred_element_type``); ``int8``
    returns a :class:`QuantizedPackedWeight` with symmetric scales at
    ``granularity``."""
    if precision not in PRECISIONS:
        raise ValueError(f"precision must be one of {PRECISIONS}, "
                         f"got {precision!r}")
    if precision == "fp32":
        return pw
    if precision == "fp16":
        return PackedWeight(
            blocks=jnp.asarray(pw.blocks, jnp.float16),
            header=pw.header, counts=pw.counts, col_perm=pw.col_perm,
            shape=pw.shape, block_size=pw.block_size)
    blocks = np.asarray(pw.blocks, np.float32)
    scales = _symmetric_scales(blocks, granularity)
    q = np.clip(np.rint(blocks / _expand_scales(scales)),
                -_QMAX, _QMAX).astype(np.int8)
    return QuantizedPackedWeight(
        blocks=jnp.asarray(q), scales=jnp.asarray(scales),
        header=pw.header, counts=pw.counts, col_perm=pw.col_perm,
        shape=pw.shape, block_size=pw.block_size, granularity=granularity)


def dequantize_packed(qpw) -> PackedWeight:
    """Reference dequantization back to an fp32 :class:`PackedWeight` —
    the jnp oracle the dequant-in-kernel Pallas variant is tested against.
    Accepts an fp16-blocks PackedWeight too (plain upcast)."""
    if isinstance(qpw, PackedWeight):
        return PackedWeight(
            blocks=jnp.asarray(qpw.blocks, jnp.float32),
            header=qpw.header, counts=qpw.counts, col_perm=qpw.col_perm,
            shape=qpw.shape, block_size=qpw.block_size)
    scales = _expand_scales(np.asarray(qpw.scales, np.float32))
    blocks = np.asarray(qpw.blocks, np.float32) * scales
    return PackedWeight(
        blocks=jnp.asarray(blocks), header=qpw.header, counts=qpw.counts,
        col_perm=qpw.col_perm, shape=qpw.shape, block_size=qpw.block_size)


def quantization_error(pw: PackedWeight, qpw) -> float:
    """Max-abs weight delta between the fp32 packed weight and the
    dequantized ``qpw`` (the stats-line honesty number)."""
    a = np.asarray(pw.blocks, np.float32)
    b = np.asarray(dequantize_packed(qpw).blocks, np.float32)
    return float(np.abs(a - b).max()) if a.size else 0.0


def quantize_packed_dict(packed: Dict[str, PackedWeight],
                         precision: str = "int8",
                         granularity: str = "block") -> Dict[str, object]:
    """Quantize every weight of a ``pack_model`` dict to ``precision``."""
    return {k: quantize_packed(v, precision, granularity)
            for k, v in packed.items()}


def max_abs_error(packed: Dict[str, PackedWeight],
                  qpacked: Dict[str, object]) -> float:
    """Max-abs weight delta across a whole quantized model dict."""
    return max((quantization_error(packed[k], qpacked[k])
                for k in packed), default=0.0)


def packed_dict_nbytes(packed: Dict[str, object]) -> int:
    """Total packed model bytes (blocks + headers + scales) of a
    {path: PackedWeight | QuantizedPackedWeight} dict."""
    return sum(w.nbytes() for w in packed.values())
