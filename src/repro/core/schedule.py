"""Cubic sparsity scheduler (paper §VI, following movement pruning [17]).

``r_b`` is scheduled from full density 1.0 to its final value with a warm-up
(dense) phase, a cubic decay phase, and a cool-down (constant) phase:

    r(t) = r_f + (1 - r_f) * (1 - (t - t_w) / (T - t_w - t_c))^3
"""
from __future__ import annotations

import jax.numpy as jnp


def cubic_keep_rate(step, total_steps: int, final_rate: float,
                    warmup_steps: int = 0, cooldown_steps: int = 0):
    """Keep-rate at ``step`` (jnp-traceable)."""
    t = jnp.asarray(step, jnp.float32)
    t_w = float(warmup_steps)
    t_end = float(total_steps - cooldown_steps)
    span = max(t_end - t_w, 1.0)
    frac = jnp.clip((t - t_w) / span, 0.0, 1.0)
    r = final_rate + (1.0 - final_rate) * (1.0 - frac) ** 3
    return jnp.where(t < t_w, 1.0, jnp.where(t >= t_end, final_rate, r))


def linear_warmup_cosine(step, total_steps: int, base_lr: float,
                         warmup_steps: int = 0, min_lr: float = 0.0):
    """LR schedule for the fine-pruning runs (AdamW in the paper)."""
    t = jnp.asarray(step, jnp.float32)
    warm = base_lr * t / max(warmup_steps, 1)
    span = max(total_steps - warmup_steps, 1)
    frac = jnp.clip((t - warmup_steps) / span, 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(t < warmup_steps, warm, cos)
