"""Simultaneous Fine-Pruning (paper Algorithm 1).

Trains a student ViT with BOTH prunings active:
  * static block weight pruning — masks recomputed from scores every step,
    keep-rate ``r_b(t)`` driven by the cubic scheduler;
  * dynamic token pruning — TDM active in the student's forward pass at
    ``cfg.pruning.tdm_layers``;
and recovers accuracy via knowledge distillation from an unpruned teacher:

  L_net = λ_distill · T²·KL(p_t(T) || p_s(T)) + λ_task · (CE + λ‖σ(S)‖)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedule import cubic_keep_rate
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.optim.adamw import AdamW, AdamWState


def distillation_loss(student_logits: jax.Array, teacher_logits: jax.Array,
                      temperature: float) -> jax.Array:
    """Eq. 9: T² · KL(p_teacher(T) || p_student(T))."""
    T = temperature
    pt = jax.nn.softmax(teacher_logits / T, axis=-1)
    log_ps = jax.nn.log_softmax(student_logits / T, axis=-1)
    log_pt = jax.nn.log_softmax(teacher_logits / T, axis=-1)
    kl = (pt * (log_pt - log_ps)).sum(axis=-1).mean()
    return T * T * kl


class PruneTrainState(NamedTuple):
    params: Any
    scores: Any
    opt_state: AdamWState
    step: jax.Array


def init_state(cfg: ModelConfig, key: jax.Array,
               optimizer: Optional[AdamW] = None) -> Tuple[PruneTrainState, AdamW]:
    opt = optimizer or AdamW(lr=2e-5, weight_decay=0.01)  # paper §VI
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    tr = {"params": params, "scores": scores}
    return PruneTrainState(params, scores, opt.init(tr),
                           jnp.zeros((), jnp.int32)), opt


def make_simultaneous_step(cfg: ModelConfig, teacher_cfg: ModelConfig,
                           opt: AdamW, total_steps: int,
                           warmup_frac: float = 0.1,
                           cooldown_frac: float = 0.1):
    """Algorithm 1, one optimization step.

    ``teacher_params`` is the frozen unpruned teacher (ViT-Base in the
    paper; any same-task model works). The student's r_b follows the cubic
    schedule; r_t is constant (the TDM has no parameters)."""
    p = cfg.pruning
    warm = int(total_steps * warmup_frac)
    cool = int(total_steps * cooldown_frac)

    def loss_fn(trainables, teacher_params, batch, step):
        params, scores = trainables["params"], trainables["scores"]
        r_b = cubic_keep_rate(step, total_steps, p.r_b, warm, cool)
        # NOTE: r_b is traced; masks use a *static* keep count, so we pass
        # the final rate for mask sizing and modulate via the scheduler by
        # interpolating masked and dense weights (faithful to the cubic
        # schedule's intent while keeping shapes static).
        masked = PG.apply_pruning(cfg, params, scores, r_b=p.r_b)
        blend = (1.0 - r_b) / max(1.0 - p.r_b, 1e-6)  # 0 → dense, 1 → pruned
        eff = jax.tree.map(
            lambda d, m: (1 - blend) * d + blend * m, params, masked)

        s_out = M.forward_vit(cfg, eff, batch["patches"])
        t_out = M.forward_vit(teacher_cfg, teacher_params, batch["patches"],
                              use_tdm=False)
        t_logits = jax.lax.stop_gradient(t_out.logits)

        ce = M.softmax_xent(s_out.logits, batch["labels"])
        reg = PG.regularizer(scores)
        distill = distillation_loss(s_out.logits, t_logits,
                                    p.distill_temperature)
        task = ce + p.lambda_reg * reg
        total = p.lambda_distill * distill + p.lambda_task * task
        return total, {"ce": ce, "distill": distill, "reg": reg, "r_b": r_b}

    def step_fn(state: PruneTrainState, teacher_params, batch):
        trainables = {"params": state.params, "scores": state.scores}
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainables, teacher_params, batch, state.step)
        new_tr, new_opt = opt.update(grads, state.opt_state, trainables)
        new_state = PruneTrainState(new_tr["params"], new_tr["scores"],
                                    new_opt, state.step + 1)
        return new_state, {"loss": loss, **parts}

    return step_fn
