"""Dynamic token pruning (paper §IV-B) — the Token Dropping Module (TDM).

Token importance is non-parametric [28]: the attention matrix ``A`` from the
MSA is aggregated over heads, and the CLS row gives a score per token,

    S = (1/H) Σ_h A_h[cls, :]        S ∈ R^N.

Given keep-rate ``r_t``, the top ``⌈(N−1)·r_t⌉`` non-CLS tokens are retained
and the inattentive remainder is **fused** into a single token by
score-weighted aggregation. The CLS token is always kept. Output length is
therefore ``1 + ⌈(N−1)·r_t⌉ + 1`` — static given (N, r_t), which keeps JAX
shapes fixed per layer.

Adaptations recorded in DESIGN.md:
  * LM prefill: the scoring row is the *last* token (the position whose
    logits matter) instead of CLS.
  * decode: the same scoring drives dynamic KV-cache pruning
    (``kv_prune_scores`` below) — a beyond-paper extension.
  * SSM/hybrid recurrent paths: inapplicable (dropping mid-sequence corrupts
    recurrent state); those archs run without the TDM.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def num_kept_tokens(n_tokens: int, r_t: float, has_cls: bool = True) -> int:
    """Static retained-token count: CLS + top-k + 1 fused token."""
    n_body = n_tokens - 1 if has_cls else n_tokens
    k = max(1, math.ceil(n_body * r_t))
    return (1 if has_cls else 0) + k + 1  # +1 fused token


def token_importance(attn: jax.Array, score_row: int = 0) -> jax.Array:
    """Aggregate head attention into per-token importance.

    attn: ``[..., H, N_q, N_kv]`` attention probabilities.
    Returns ``[..., N_kv]`` = mean over heads of row ``score_row``.
    """
    return attn[..., :, score_row, :].mean(axis=-2)


def tdm(z: jax.Array, scores: jax.Array, r_t: float,
        has_cls: bool = True, k: int | None = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Token Dropping Module.

    z      : ``[B, N, D]`` token matrix (CLS at index 0 when ``has_cls``).
    scores : ``[B, N]`` importance (CLS position ignored when ``has_cls``).
    ``k``  : static kept-token override. The ragged serving path batches
    requests whose rows are token-padded (N here is the padded tile), so the
    keep count must come from each request's *real* token count — callers
    pass it explicitly; padded positions must carry score 0 so they are
    never selected (ties break toward lower indices, i.e. real tokens) and
    contribute nothing to the fused token. Default: derived from N and
    ``r_t`` as in the paper.
    Returns ``(z_out [B, N_kept, D], kept_idx [B, k])`` where
    ``N_kept = (1 if has_cls) + k + 1``.
    """
    B, N, D = z.shape
    n_body = N - 1 if has_cls else N
    if k is None:
        k = max(1, math.ceil(n_body * r_t))

    body = z[:, 1:, :] if has_cls else z
    s_body = scores[:, 1:] if has_cls else scores

    top_vals, top_idx = jax.lax.top_k(s_body, k)  # [B, k]
    kept = jnp.take_along_axis(body, top_idx[..., None], axis=1)  # [B,k,D]

    # Fuse the inattentive remainder: weighted aggregation by score (paper).
    keep_mask = jnp.zeros((B, n_body), dtype=bool)
    keep_mask = jnp.put_along_axis(keep_mask, top_idx, True, axis=1,
                                   inplace=False)
    drop_w = jnp.where(keep_mask, 0.0, s_body.astype(jnp.float32))
    denom = drop_w.sum(axis=1, keepdims=True) + 1e-9
    fused = jnp.einsum("bn,bnd->bd", (drop_w / denom).astype(z.dtype), body)

    parts = []
    if has_cls:
        parts.append(z[:, :1, :])
    parts += [kept, fused[:, None, :]]
    z_out = jnp.concatenate(parts, axis=1)
    return z_out, top_idx


def tdm_reference_unbatched(z: jnp.ndarray, scores: jnp.ndarray, r_t: float,
                            has_cls: bool = True) -> jnp.ndarray:
    """Oracle for property tests: direct, unbatched TDM."""
    out, _ = tdm(z[None], scores[None], r_t, has_cls)
    return out[0]


def tdm_soft(z: jax.Array, scores: jax.Array, r_t: float | None = None,
             has_cls: bool = True, k: int | None = None,
             pkg_mass: jax.Array | None = None,
             pkg_pos: jax.Array | None = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Soft-pruning TDM (SPViT-style): dropped tokens fold into ONE
    persistent "package token" instead of being re-fused from scratch.

    The hard :func:`tdm` synthesizes a fresh fused token at every TDM layer
    — content dropped at layer 3 is gone by layer 7. Here the package row
    carries an accumulated score *mass* across layers: at each TDM the
    previous package re-enters the weighted aggregation with its stored
    mass as weight,

        package' = (Σ_dropped s_i·z_i + mass·z_pkg) / (Σ s_i + mass),
        mass'    = Σ_dropped s_i + mass,

    so early-dropped content keeps influence proportional to the attention
    it once earned, instead of competing by its current (diluted) score.
    Weights stay RAW (un-normalized) so they share a scale with the carried
    mass — the exact form the ``token_package`` kernel computes.

    Output length is IDENTICAL to the hard TDM (``1 + k + 1``), which keeps
    keep-schedule trajectories and serving bucket math variant-agnostic.

    z, scores, ``k``: as in :func:`tdm` (padded rows must score 0 — they
    then contribute exactly 0 to the package and nothing to its mass).
    ``pkg_mass`` [B]: accumulated mass when a body row of ``z`` is a
    package from a previous soft TDM; ``None`` for the first TDM. The
    package is pinned out of the top-k (it always survives), so ``k`` must
    leave at least one real body row undropped: ``k <= N_body - 1`` — the
    derived-from-``r_t`` default clamps itself, explicit ``k`` raises.
    ``pkg_pos`` [B]: per-row *body* index of the package (default: last
    body row). The serving engine passes ``n_valid - 2`` so token-padded
    tiles pin each request's own package, not a padding row.

    Returns ``(z_out [B, k + 2, D], new_mass [B])``.
    """
    B, N, D = z.shape
    n_body = N - 1 if has_cls else N
    if k is None:
        k = max(1, math.ceil(n_body * r_t))
        if pkg_mass is not None:
            k = min(k, n_body - 1)
    if pkg_mass is not None and k > n_body - 1:
        raise ValueError(f"soft TDM with a package row keeps the package "
                         f"plus k={k} of {n_body - 1} real body tokens — "
                         f"k must be <= {n_body - 1}")

    body = z[:, 1:, :] if has_cls else z
    s_body = scores[:, 1:] if has_cls else scores

    is_pkg = None
    if pkg_mass is not None:
        if pkg_pos is None:
            pkg_pos = jnp.full((B,), n_body - 1, jnp.int32)
        is_pkg = (jnp.arange(n_body)[None, :]
                  == jnp.asarray(pkg_pos, jnp.int32)[:, None])  # [B, n_body]
        sel = jnp.where(is_pkg, -jnp.inf, s_body)  # pin pkg out of top-k
    else:
        sel = s_body

    _, top_idx = jax.lax.top_k(sel, k)  # [B, k]
    kept = jnp.take_along_axis(body, top_idx[..., None], axis=1)  # [B,k,D]

    keep_mask = jnp.zeros((B, n_body), dtype=bool)
    keep_mask = jnp.put_along_axis(keep_mask, top_idx, True, axis=1,
                                   inplace=False)
    w = jnp.where(keep_mask, 0.0, s_body.astype(jnp.float32))
    if is_pkg is not None:
        w = jnp.where(is_pkg, pkg_mass.astype(jnp.float32)[:, None], w)
    denom = w.sum(axis=1, keepdims=True) + 1e-9
    package = jnp.einsum("bn,bnd->bd", w, body.astype(jnp.float32)) / denom
    new_mass = w.sum(axis=1)

    parts = []
    if has_cls:
        parts.append(z[:, :1, :])
    parts += [kept, package.astype(z.dtype)[:, None, :]]
    return jnp.concatenate(parts, axis=1), new_mass


# ---------------------------------------------------------------------------
# Beyond-paper: dynamic KV-cache pruning for decode (SpAtten-style adaptation
# of the paper's token scoring to autoregressive serving).
# ---------------------------------------------------------------------------
def kv_prune_scores(accum_attn: jax.Array, cache_len,
                    start=None) -> jax.Array:
    """``accum_attn [B, N_cache]`` is attention mass accumulated over decode
    steps and heads. Returns the same scores, masked to the valid cache
    window ``[start, cache_len)`` — both ``cache_len`` and ``start`` may be
    scalar or per-slot ``[B]``; ``start`` masks left-padding so pad slots
    never compete with real tokens."""
    n = accum_attn.shape[-1]
    pos = jnp.arange(n)
    valid = pos < jnp.asarray(cache_len)[..., None]
    if start is not None:
        valid = valid & (pos >= jnp.asarray(start)[..., None])
    return jnp.where(valid, accum_attn, -jnp.inf)


def select_kv_keep(accum_attn: jax.Array, keep: int,
                   invalid_first: bool = False) -> jax.Array:
    """Indices of the ``keep`` highest-mass cached tokens. ``keep`` static.

    ``keep`` is clamped to the score width, and picks whose score is ``-inf``
    (slots masked out by ``kv_prune_scores``) are grouped away from the valid
    picks instead of interleaving with them: valid indices stay in temporal
    order (RoPE sanity) and invalid ones are packed at the back — or at the
    front with ``invalid_first=True``, which lets a caller express the
    resulting garbage prefix as a per-slot ``start`` offset."""
    n = accum_attn.shape[-1]
    keep = max(1, min(keep, n))
    vals, idx = jax.lax.top_k(accum_attn, keep)
    invalid = jnp.isneginf(vals)
    if invalid_first:
        key = jnp.where(invalid, idx, idx + n)
    else:
        key = jnp.where(invalid, idx + n, idx)
    return jnp.sort(key, axis=-1) % n


def compact_kv_cache(k_cache: jax.Array, v_cache: jax.Array,
                     keep_idx: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Gather kept cache entries to the front. Shapes: ``[B, N, H, Dh]``;
    keep_idx ``[B, keep]``."""
    gather = lambda c: jnp.take_along_axis(
        c, keep_idx[:, :, None, None], axis=1)
    return gather(k_cache), gather(v_cache)
