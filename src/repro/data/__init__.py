from repro.data.pipeline import (DataConfig, synthetic_lm_batch,
                                 synthetic_vit_batch, batches, shard_batch)

__all__ = ["DataConfig", "synthetic_lm_batch", "synthetic_vit_batch",
           "batches", "shard_batch"]
