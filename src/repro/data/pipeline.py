"""Data pipeline: deterministic, shardable synthetic sources for every
modality, plus the host-sharding logic a multi-pod run needs.

Determinism is the straggler/fault story's foundation: batch ``i`` is a pure
function of (seed, step, shard), so any replacement host can recompute its
shard without coordination, and restarts resume mid-epoch exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_shards: int = 1
    shard_index: int = 0


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def synthetic_lm_batch(cfg: ModelConfig, shape: ShapeConfig, dc: DataConfig,
                       step: int, local_batch: Optional[int] = None
                       ) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens (learnable structure, not uniform noise:
    token t+1 ~ (t*7 + noise) mod V), so train-loss decreasing is a real
    signal in integration tests."""
    B = local_batch or shape.global_batch // dc.num_shards
    S = shape.seq_len
    g = _rng(dc.seed, step, dc.shard_index)
    first = g.integers(0, cfg.vocab_size, size=(B, 1))
    noise = g.integers(0, 3, size=(B, S - 1))
    toks = [first]
    for i in range(S - 1):
        toks.append((toks[-1] * 7 + 11 + noise[:, i:i + 1]) % cfg.vocab_size)
    batch = {"tokens": np.concatenate(toks, axis=1).astype(np.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = g.standard_normal(
            (B, cfg.num_vision_tokens, cfg.vision_d_model or cfg.d_model),
            dtype=np.float32).astype(np.float32)
    if cfg.family == "audio":
        batch["audio_frames"] = g.standard_normal(
            (B, cfg.num_audio_frames, cfg.d_model)).astype(np.float32)
        batch["tokens"] = batch["tokens"][:, : max(S // 8, 8)]
    return batch


def synthetic_vit_batch(cfg: ModelConfig, batch_size: int, dc: DataConfig,
                        step: int) -> Dict[str, np.ndarray]:
    """Class-conditional Gaussian patches: images of class c are centered at
    pattern(c), so a ViT can actually fit them (accuracy-recovery tests)."""
    g = _rng(dc.seed, step, dc.shard_index)
    n = (cfg.image_size // cfg.patch_size) ** 2
    pdim = cfg.patch_size ** 2 * 3
    labels = g.integers(0, cfg.num_classes, size=(batch_size,))
    centers = _class_centers(cfg.num_classes, n, pdim, dc.seed)
    patches = centers[labels] + 0.5 * g.standard_normal(
        (batch_size, n, pdim)).astype(np.float32)
    return {"patches": patches.astype(np.float32),
            "labels": labels.astype(np.int32)}


_center_cache: Dict = {}


def _class_centers(num_classes: int, n: int, pdim: int, seed: int):
    key = (num_classes, n, pdim, seed)
    if key not in _center_cache:
        g = np.random.default_rng(seed + 1234)
        _center_cache[key] = g.standard_normal(
            (num_classes, n, pdim)).astype(np.float32)
    return _center_cache[key]


def batches(cfg: ModelConfig, shape: ShapeConfig, dc: DataConfig,
            start_step: int = 0, local_batch: Optional[int] = None
            ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_lm_batch(cfg, shape, dc, step, local_batch)
        step += 1


def shard_batch(batch: Dict[str, np.ndarray], mesh, data_axes=("pod", "data")
                ) -> Dict[str, jax.Array]:
    """Place a host-local batch onto the mesh, sharding the batch dim over
    the data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    spec = P(axes)
    return {k: jax.device_put(v, NamedSharding(mesh, spec))
            for k, v in batch.items()}
