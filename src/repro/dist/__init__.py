"""Distribution substrate for the production runs.

Three concerns, one per module:

  * ``sharding`` — per-architecture PartitionSpec rules mapping every param /
    batch / cache leaf onto the production meshes (TP on "model", DP on
    "pod"/"data", EP for MoE expert banks).
  * ``elastic``  — ``MeshPlan`` + ``replan``: shrink a mesh to the devices
    actually alive, preserving tensor-parallel degree (data absorbs losses).
  * ``fault``    — ``RestartableLoop``: checkpointed training that survives
    injected/real step failures with bit-exact resume, plus a straggler
    watchdog.
"""
from repro.dist.elastic import MeshPlan, degradation_path, replan
from repro.dist.fault import FaultConfig, RestartableLoop, StepWatchdog

# ``sharding`` is NOT imported eagerly: it pulls in jax, while elastic/fault
# stay importable on a jax-free coordinator. ``from repro.dist import
# sharding`` still works (submodule import).

__all__ = [
    "sharding",
    "MeshPlan",
    "replan",
    "degradation_path",
    "FaultConfig",
    "StepWatchdog",
    "RestartableLoop",
]
