"""Elastic mesh replanning: adapt a production mesh to the devices alive.

A ``MeshPlan`` is the pure-data description of a mesh (shape + axis names);
``repro.launch.mesh.make_mesh(plan.shape, plan.axes)`` realizes it. Keeping
this module jax-free means replanning logic can run on a coordinator that
never initializes a backend.

Policy: tensor parallelism is the expensive axis to change (weights must be
re-sharded and collectives re-tuned), so ``replan`` preserves the "model"
axis degree whenever it divides the surviving device count and shrinks the
data-parallel axes instead — a pod loss degrades throughput, not the model
partitioning. ``degradation_path`` precomputes the ladder of plans a run
walks down as capacity drops (e.g. ``(2,16,16) -> (16,16) -> (8,16)``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

# Axes that carry data parallelism, outermost first. Extra axes (e.g. "pod")
# are collapsed into "data" when a replan shrinks the mesh.
DATA_AXES: Tuple[str, ...] = ("pod", "data")
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Pure-data mesh description: ``shape[i]`` devices along ``axes[i]``."""

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")
        if any(s < 1 for s in self.shape):
            raise ValueError(f"axis sizes must be >= 1: {self.shape}")

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    def axis_size(self, name: str, default: int = 1) -> int:
        return self.shape[self.axes.index(name)] if name in self.axes else default

    @property
    def data_degree(self) -> int:
        return math.prod(self.axis_size(a) for a in DATA_AXES)

    def describe(self) -> str:
        return "x".join(str(s) for s in self.shape) + f" ({','.join(self.axes)})"


def replan(devices: int, plan: MeshPlan) -> MeshPlan:
    """Best plan for ``devices`` available devices.

    Keeps ``plan`` unchanged when capacity suffices. Otherwise preserves the
    tensor-parallel ("model") degree if it divides ``devices`` — falling
    back to ``gcd(devices, tp)`` so the degraded degree still divides every
    weight dim the original degree did — folds any extra data axes ("pod")
    into a single "data" axis, and shrinks that axis to fit, never growing
    it beyond the original total data-parallel degree.
    """
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if devices >= plan.num_devices:
        return plan

    tp = math.gcd(devices, plan.axis_size(MODEL_AXIS))
    dp = min(devices // tp, max(plan.data_degree, 1))

    shape: List[int] = []
    axes: List[str] = []
    if any(a in plan.axes for a in DATA_AXES) or MODEL_AXIS not in plan.axes:
        shape.append(dp)
        axes.append("data")
    if MODEL_AXIS in plan.axes:
        shape.append(tp)
        axes.append(MODEL_AXIS)
    return MeshPlan(tuple(shape), tuple(axes))


def degradation_path(plan: MeshPlan,
                     device_budgets: Sequence[int]) -> List[MeshPlan]:
    """The ladder of plans a run walks as capacity drops.

    Returns ``[plan] + [replan(b, plan) for b in device_budgets]`` — index 0
    is the healthy mesh, later entries the degraded fallbacks. Budgets are
    expected (not required) to be decreasing.
    """
    return [plan] + [replan(b, plan) for b in device_budgets]


def first_fit(plans: Sequence[MeshPlan], devices: int) -> Optional[MeshPlan]:
    """Walk a degradation ladder and return the first plan that fits the
    surviving device count (ladder order == preference order — the serving
    engine calls this on device loss to pick its degraded mesh). ``None``
    when even the smallest plan needs more devices than remain."""
    for p in plans:
        if p.num_devices <= devices:
            return p
    return None
