"""Fault-tolerant training: straggler detection + checkpointed restart.

``RestartableLoop`` wraps a step function with the checkpoint/restart
contract the system tests demand: state is saved every
``checkpoint_every`` completed steps through ``CheckpointManager``, any
exception raised inside a step (data fetch, injected fault, real XLA error)
triggers a restore of the latest checkpoint, and — because the data pipeline
is a pure function of the step index (``data/pipeline.py``) — replaying the
steps since that checkpoint reproduces the pre-failure state *bit-exactly*.

``StepWatchdog`` is the straggler half of the fault story: it tracks the
running mean step time and flags any step slower than
``slow_step_factor``x the mean (flagged steps are excluded from the mean so
one straggler doesn't mask the next).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs for the fault-tolerance substrate."""

    checkpoint_every: int = 100   # steps between checkpoints (0 = never)
    slow_step_factor: float = 3.0  # straggler threshold vs mean step time
    warmup_steps: int = 5          # observations before the watchdog arms
    max_restarts: int = 16         # hard stop against crash loops


class StepWatchdog:
    """Flags steps slower than ``slow_step_factor`` x the running mean."""

    def __init__(self, config: FaultConfig):
        self.config = config
        self._count = 0
        self._total = 0.0

    def observe(self, duration: float) -> Optional[str]:
        """Record one step duration; returns "straggler" if it's anomalous
        (after warmup), else None. Stragglers don't pollute the mean."""
        if self._count >= max(self.config.warmup_steps, 1):
            mean = self._total / self._count
            if mean > 0 and duration > self.config.slow_step_factor * mean:
                return "straggler"
        self._count += 1
        self._total += duration
        return None


class RestartableLoop:
    """Checkpointed step loop with exact resume after failures.

    Args:
      manager:   ``CheckpointManager`` for save/restore.
      config:    ``FaultConfig``.
      make_state: () -> fresh state pytree (also the restore template).
      step_fn:   (state, batch) -> (new_state, metrics dict).
      data_fn:   (step index) -> batch; must be deterministic in the step so
                 replay after a restore is bit-exact.
      state_to_tree / tree_to_state: optional projections when only part of
                 the state is checkpointable (e.g. params+opt but not jitted
                 closures). Defaults checkpoint the whole state.
    """

    def __init__(self, manager, config: FaultConfig,
                 make_state: Callable[[], Any],
                 step_fn: Callable[[Any, Any], Tuple[Any, Dict]],
                 data_fn: Callable[[int], Any],
                 state_to_tree: Optional[Callable[[Any], Any]] = None,
                 tree_to_state: Optional[Callable[[Any, Any], Any]] = None):
        self.manager = manager
        self.config = config
        self.make_state = make_state
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.state_to_tree = state_to_tree or (lambda s: s)
        self.tree_to_state = tree_to_state or (lambda tree, state: tree)

    # ------------------------------------------------------------------
    def _restore_or_init(self, events) -> Tuple[int, Any]:
        state = self.make_state()
        step = self.manager.latest_step()
        if step is None:
            return 0, state
        tree = self.manager.restore(self.state_to_tree(state), step=step)
        events.append((step, "restored"))
        return step, self.tree_to_state(tree, state)

    def _save(self, step: int, state: Any, events) -> None:
        self.manager.save(step, self.state_to_tree(state),
                          extra={"step": step})
        events.append((step, "checkpoint"))

    # ------------------------------------------------------------------
    def run(self, num_steps: int,
            fail_injector: Optional[Callable[[int], None]] = None) -> Dict:
        """Run to ``num_steps`` completed steps, restarting on any step
        fault. ``fail_injector(step)`` (tests) may raise to simulate one."""
        events: list = []
        loss_by_step: Dict[int, float] = {}
        restarts = 0
        watchdog = StepWatchdog(self.config)
        every = self.config.checkpoint_every

        step, state = self._restore_or_init(events)
        while step < num_steps:
            try:
                t0 = time.monotonic()
                batch = self.data_fn(step)
                if fail_injector is not None:
                    fail_injector(step)
                state, metrics = self.step_fn(state, batch)
                # float() blocks on async dispatch, so it must precede the
                # watchdog observation or jitted steps time as ~0s and
                # stragglers are never flagged; keyed by step so replayed
                # steps after a restore overwrite instead of duplicating
                if metrics and "loss" in metrics:
                    loss_by_step[step] = float(metrics["loss"])
                if watchdog.observe(time.monotonic() - t0) == "straggler":
                    events.append((step, "straggler"))
                step += 1
                if every and step % every == 0:
                    self._save(step, state, events)
            except Exception as e:  # noqa: BLE001 — any step fault restarts
                restarts += 1
                if restarts > self.config.max_restarts:
                    raise
                events.append((step, f"failure:{type(e).__name__}"))
                step, state = self._restore_or_init(events)

        if every and step % every != 0:
            self._save(step, state, events)  # final state always durable
        return {"state": state, "restarts": restarts,
                "losses": [loss_by_step[s] for s in sorted(loss_by_step)],
                "events": events}
