"""PartitionSpec rules for every architecture family in ``configs/archs.py``.

The single entry point is ``param_spec(cfg, path, ndim, shape)``: given a
"/"-joined pytree path (as produced by ``_path_str``) it returns the
PartitionSpec for that leaf on the production mesh axes:

  * column-parallel (shard the OUTPUT dim on "model"): ``wq``/``wk``/``wv``/
    ``wqkv``, MLP ``wg``/``wi``, RWKV time-mix projections, Mamba ``in_proj``,
    the ViT ``patch_embed``;
  * row-parallel (shard the INPUT dim on "model"): attention/MLP ``wo``,
    RWKV channel-mix ``cm_wv``, Mamba ``out_proj`` — the matmul partner of a
    column-parallel layer, so activations stay sharded between the two;
  * expert-parallel MoE banks: the expert axis on "model" (EP == TP degree),
    with routers replicated so every shard routes identically;
  * embeddings vocab-sharded on "model"; ``unembed``/``head`` output-sharded;
  * norms / biases / scalars replicated.

Specs are structural intents: ``_validate`` drops any spec entry whose mesh
axis does not divide the dim (or is absent from the mesh), so the same rules
serve the 16x16 production mesh and tiny test meshes. Tree-level builders
(``params_shardings`` / ``batch_shardings`` / ``cache_shardings`` /
``replicated``) wrap the rules into NamedSharding pytrees for the dry-run
and the launchers.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.elastic import DATA_AXES

# Weight names sharded on the output (last) dim — "column parallel".
_COLUMN = frozenset({
    "wq", "wk", "wv", "wqkv",         # attention input projections
    "wg", "wi",                        # (GLU-)MLP up projections
    "wr", "ww", "cm_wk",               # RWKV time-mix / channel-mix up
    "in_proj",                         # Mamba2 fused input projection
    "patch_embed",                     # ViT patchifier
})
# Weight names sharded on the input (second-to-last) dim — "row parallel".
_ROW = frozenset({"wo", "cm_wv", "out_proj"})
# Output heads sharded over the class/vocab (last) dim.
_VOCAB_OUT = frozenset({"unembed", "head"})


# ---------------------------------------------------------------------------
# Path utilities
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    """jax keypath -> "layers/attn/wq"-style string (dict keys, sequence
    indices, and namedtuple field names all flatten to plain segments)."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Per-leaf rules
# ---------------------------------------------------------------------------
def param_spec(cfg: ModelConfig, path: str, ndim: int,
               shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf (before mesh validation)."""
    parts = path.split("/")
    name = parts[-1]
    none = [None] * ndim
    if ndim == 0:
        return P()

    # MoE: expert banks are stacked [layers?, experts, d_in, d_out] — shard
    # the expert axis (EP over the TP mesh axis); shared-expert MLPs fall
    # through to the dense column/row rules; routers stay replicated so all
    # shards compute identical routing decisions.
    if "moe" in parts and "shared" not in parts:
        if name == "router":
            return P(*none)
        if name in ("wg", "wi", "wo") and ndim >= 3:
            spec = list(none)
            spec[ndim - 3] = "model"
            return P(*spec)

    if name == "embed":
        return P("model", *none[1:])  # vocab-sharded; d_model replicated
    if name in _VOCAB_OUT:
        spec = list(none)
        spec[-1] = "model"
        return P(*spec)

    # RWKV time-mix wk/wv sharding is a perf lever that changes the WKV
    # state layout; keep them replicated unless the config opts in.
    if cfg.family == "ssm" and name in ("wk", "wv") and not cfg.shard_rwkv_kv:
        return P(*none)

    if name in _COLUMN and ndim >= 2:
        spec = list(none)
        spec[-1] = "model"
        return P(*spec)
    if name in _ROW and ndim >= 2:
        spec = list(none)
        spec[-2] = "model"
        return P(*spec)

    # norms, biases, gates, positional tables, recurrent mixing vectors, ...
    return P(*none)


def _validate(spec: P, shape: Tuple[int, ...], mesh, path: str) -> P:
    """Drop spec entries whose mesh axes don't divide the dim (or don't
    exist on this mesh). Leaves the spec length == len(shape)."""
    out = []
    for dim, ax in zip(shape, tuple(spec)):
        if ax is None:
            out.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in mesh.shape for a in names):
            out.append(None)
            continue
        size = 1
        for a in names:
            size *= mesh.shape[a]
        out.append(ax if size >= 1 and dim % size == 0 else None)
    out += [None] * (len(shape) - len(out))
    return P(*out)


# ---------------------------------------------------------------------------
# Tree-level builders (NamedSharding pytrees for jit in_shardings)
# ---------------------------------------------------------------------------
def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _data_axes(mesh) -> Optional[Any]:
    """The mesh axes that carry the batch dim ("pod"+"data" merged)."""
    axes = tuple(a for a in DATA_AXES if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def params_shardings(cfg: ModelConfig, mesh, spec_tree: Any) -> Any:
    """NamedSharding per leaf of a param (or optimizer-moment) tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(spec_tree)
    shs = []
    for path, leaf in flat:
        ps = _path_str(path)
        spec = param_spec(cfg, ps, leaf.ndim, leaf.shape)
        shs.append(NamedSharding(mesh, _validate(spec, leaf.shape, mesh, ps)))
    return jax.tree_util.tree_unflatten(treedef, shs)


def batch_shardings(mesh, spec_tree: Any) -> Any:
    """Shard the leading (batch) dim of every input leaf over the data axes;
    tiny batches that don't divide fall back to replicated via _validate."""
    dax = _data_axes(mesh)

    def one(leaf):
        if dax is None or leaf.ndim == 0:
            return replicated(mesh)
        spec = P(dax, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, _validate(spec, leaf.shape, mesh, "batch"))

    return jax.tree.map(one, spec_tree)


# Cache-leaf rules keyed by field name: (batch_offset, model_offset), both
# counted FROM THE END of the shape so any number of leading layer/stage
# stacking axes is tolerated. batch -> data axes, heads/channels -> "model".
_CACHE_RULES = {
    "k": (4, 2),          # [..., B, S, KV, Dh]
    "v": (4, 2),
    "attn_mass": (2, None),   # [..., B, S]
    "wkv": (4, 3),        # RWKV state [..., B, H, Dh, Dh]
    "h": (4, 3),          # Mamba state [..., B, H, Dh, State]
    "conv": (3, 1),       # Mamba conv buffer [..., B, W-1, Inner]
    "shift_tm": (2, None),    # RWKV token-shift [..., B, D]
    "shift_cm": (2, None),
}


def cache_shardings(cfg: ModelConfig, mesh, cache_spec: Any) -> Any:
    """Shardings for serve-state trees (KV caches / recurrent states).

    Batch dims go on the data axes, head/channel dims on "model"; scalars
    (cache lengths) and unrecognized leaves replicate."""
    dax = _data_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_spec)
    shs = []
    for path, leaf in flat:
        name = _path_str(path).split("/")[-1]
        rule = _CACHE_RULES.get(name)
        if rule is None or leaf.ndim < rule[0]:
            shs.append(replicated(mesh))
            continue
        b_off, m_off = rule
        spec = [None] * leaf.ndim
        if dax is not None:
            spec[leaf.ndim - b_off] = dax
        if m_off is not None:
            spec[leaf.ndim - m_off] = "model"
        shs.append(NamedSharding(
            mesh, _validate(P(*spec), leaf.shape, mesh, name)))
    return jax.tree_util.tree_unflatten(treedef, shs)
