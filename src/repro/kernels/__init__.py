"""Pallas TPU kernels for the perf-critical compute layers.

  sbmm            — the paper's Sparse Block-wise Matrix Multiplication
  token_drop      — fused TDM gather + weighted-fuse (TDHM analog)
  token_package   — soft-pruning TDM: weighted scatter-reduce into a
                    persistent package token (SPViT-style), raw weights
                    normalized in-kernel
  flash_attention — online-softmax attention (prefill/training)

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, backend selection), ref.py (pure-jnp oracle). All validated in
interpret mode on CPU; compiled natively on TPU backends.
"""
