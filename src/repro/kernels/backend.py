"""Backend selection shared by every Pallas kernel wrapper.

The kernels run in two modes:

* ``interpret=True``  — Pallas interpreter; works on any backend (CPU CI).
* ``interpret=False`` — compiled Pallas; TPU backends only.

Every kernel entry point takes ``interpret: bool | None = None`` and
resolves ``None`` through :func:`default_interpret`: compiled on a real TPU
backend, interpreted elsewhere. The ``REPRO_KERNEL_INTERPRET`` environment
variable overrides auto-detection in either direction (``1``/``true``/
``interpret`` forces the interpreter, ``0``/``false``/``compiled`` forces
compiled Pallas, ``auto``/unset keeps detection).

Resolution scope: the top-level kernel entry points (``sbmm``,
``token_drop``, ``flash_attention``) resolve OUTSIDE their jits, so for
direct calls the resolved value is a static jit argument and flipping the
env var between calls re-dispatches. Kernel calls nested inside an outer
jitted program (``PackedVitSegments`` segments, ``ModelRunner`` steps)
resolve at *trace* time and the mode is baked into that trace — set the
env var before the first engine step (in practice: at process launch);
flipping it mid-engine does not retrace already-compiled steps.
"""
from __future__ import annotations

import os

import jax

ENV_VAR = "REPRO_KERNEL_INTERPRET"

_TRUE = ("1", "true", "yes", "on", "interpret")
_FALSE = ("0", "false", "no", "off", "compiled")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Interpret on non-TPU backends unless the env var says otherwise."""
    env = os.environ.get(ENV_VAR, "auto").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    if env not in ("", "auto"):
        raise ValueError(
            f"{ENV_VAR}={env!r}: expected one of {_TRUE + _FALSE} or 'auto'")
    return not on_tpu()


def resolve_interpret(interpret: "bool | None") -> bool:
    """``None`` -> auto-detected default; concrete bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)
