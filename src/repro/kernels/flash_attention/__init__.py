from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_fp16)
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention", "flash_attention_fp16", "attention_ref"]
