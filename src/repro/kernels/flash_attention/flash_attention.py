"""Flash attention Pallas kernel (prefill / training path).

Online-softmax attention tiled for VMEM: grid = (heads, Nq/TQ); each cell
streams Nk/TK key/value tiles, keeping running (max, denom, accumulator) in
fp32. Causal masking skips nothing structurally (the grid is rectangular)
but fully-masked tiles contribute zero — the hillclimbed variant bounds the
kv loop per q tile instead (see ops.py ``causal_bounded``).

MXU alignment: TQ/TK default 128; Dh is the lane dimension (64/128 for all
assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, tq: int,
                  tk: int, nk: int, scale: float, q_offset: int,
                  bounded: bool, kv_valid: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # [TQ, Dh]
    dh = q.shape[-1]

    q_pos = q_offset + qi * tq + jax.lax.iota(jnp.int32, tq)

    def body(ki, carry):
        o, m, l = carry
        k = k_ref[0, pl.dslice(ki * tk, tk)].astype(jnp.float32)  # [TK, Dh]
        v = v_ref[0, pl.dslice(ki * tk, tk)].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # [TQ, TK]
        k_pos = ki * tk + jax.lax.iota(jnp.int32, tk)
        mask = k_pos[None, :] < kv_valid  # mask tile padding
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        o_new = o * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return o_new, m_new, l_new

    o0 = jnp.zeros((tq, dh), jnp.float32)
    m0 = jnp.full((tq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((tq,), jnp.float32)

    if causal and bounded:
        # hillclimb: only iterate kv tiles that intersect the causal cone of
        # this q tile — halves compute for training shapes.
        last = (q_offset + (qi + 1) * tq + tk - 1) // tk
        upper = jnp.minimum(nk, last)
    else:
        upper = nk
    o, m, l = jax.lax.fori_loop(0, upper, body, (o0, m0, l0))
    o_ref[0] = (o / jnp.maximum(l[:, None], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, q_offset: int = 0,
                           tq: int = 128, tk: int = 128,
                           scale: float | None = None,
                           bounded: bool = True,
                           kv_valid: int | None = None,
                           interpret: "bool | None" = None) -> jax.Array:
    """q: [H, Nq, Dh]; k, v: [H, Nk, Dh] (same head count — GQA expansion is
    handled in ops.py). Nq % tq == 0 and Nk % tk == 0 (ops.py pads;
    ``kv_valid`` masks key padding). ``interpret=None`` auto-detects the
    backend (kernels.backend)."""
    interpret = resolve_interpret(interpret)
    H, Nq, Dh = q.shape
    _, Nk, _ = k.shape
    tq = min(tq, Nq)
    tk = min(tk, Nk)
    assert Nq % tq == 0 and Nk % tk == 0
    if scale is None:
        scale = Dh ** -0.5
    nk = Nk // tk
    if kv_valid is None:
        kv_valid = Nk
    kernel = functools.partial(
        _flash_kernel, causal=causal, tq=tq, tk=tk, nk=nk, scale=scale,
        q_offset=q_offset, bounded=bounded, kv_valid=kv_valid)
    return pl.pallas_call(
        kernel,
        grid=(H, Nq // tq),
        in_specs=[
            pl.BlockSpec((1, tq, Dh), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, Nk, Dh), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, Nk, Dh), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, Dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Nq, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
