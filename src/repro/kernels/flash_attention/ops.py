"""Jit'd wrapper: batching, GQA expansion, padding, backend selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "q_offset", "tq",
                                             "tk", "bounded", "interpret"))
def _flash_attention_jit(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool, q_offset: int,
                         tq: int, tk: int, bounded: bool,
                         interpret: bool) -> jax.Array:
    B, Nq, Hq, Dh = q.shape
    _, Nk, KV, _ = k.shape
    per = Hq // KV
    if per > 1:
        k = jnp.repeat(k, per, axis=2)
        v = jnp.repeat(v, per, axis=2)

    tq_eff = min(tq, Nq)
    tk_eff = min(tk, Nk)
    q_pad = (-Nq) % tq_eff
    k_pad = (-Nk) % tk_eff
    qh = jnp.moveaxis(q, 2, 1)  # [B, H, Nq, Dh]
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    if q_pad:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, k_pad), (0, 0)))

    run = functools.partial(
        flash_attention_pallas, causal=causal, q_offset=q_offset,
        tq=tq_eff, tk=tk_eff, bounded=bounded, kv_valid=Nk,
        interpret=interpret)
    out = jax.vmap(run)(qh, kh, vh)
    out = out[:, :, :Nq]
    return jnp.moveaxis(out, 1, 2)  # [B, Nq, Hq, Dh]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, q_offset: int = 0,
                    tq: int = 128, tk: int = 128, bounded: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B, Nq, Hq, Dh]; k, v: [B, Nk, KV, Dh]. GQA handled by repeating
    KV heads (the kernel sees matched head counts). ``interpret=None``
    auto-detects the backend (kernels.backend; ``REPRO_KERNEL_INTERPRET``
    overrides) — resolved outside the jit so it is a static argument."""
    return _flash_attention_jit(q, k, v, causal, q_offset, tq, tk, bounded,
                                resolve_interpret(interpret))


def flash_attention_fp16(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = True, q_offset: int = 0,
                         tq: int = 128, tk: int = 128, bounded: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    """Half-precision variant of :func:`flash_attention` for the quantized
    serving path: operands are quantized to float16 before the kernel (the
    whole precision loss — the kernel's softmax statistics and output
    accumulation stay fp32 in-register), output returned as fp32. The cast
    here IS the quantizer, so the jnp oracle for this variant is exactly
    ``flash_attention_jnp`` on the same fp16-cast operands."""
    out = flash_attention(q.astype(jnp.float16), k.astype(jnp.float16),
                          v.astype(jnp.float16), causal=causal,
                          q_offset=q_offset, tq=tq, tk=tk, bounded=bounded,
                          interpret=interpret)
    return out.astype(jnp.float32)
