"""Pure-jnp oracle for the flash attention kernel: naive full-matrix
softmax attention in fp32."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, q_offset: int = 0,
                  scale: float | None = None) -> jnp.ndarray:
    """q: [H, Nq, Dh]; k, v: [H, Nk, Dh]."""
    H, Nq, Dh = q.shape
    Nk = k.shape[1]
    if scale is None:
        scale = Dh ** -0.5
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        q_pos = q_offset + jnp.arange(Nq)
        mask = q_pos[:, None] >= jnp.arange(Nk)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
