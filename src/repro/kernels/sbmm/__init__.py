from repro.kernels.sbmm.ops import sbmm, sbmm_raw
from repro.kernels.sbmm.ref import sbmm_ref

__all__ = ["sbmm", "sbmm_raw", "sbmm_ref"]
