from repro.kernels.sbmm.ops import sbmm, sbmm_quant_raw, sbmm_raw
from repro.kernels.sbmm.quant import sbmm_quant_pallas, sbmm_quant_ref
from repro.kernels.sbmm.ref import sbmm_ref

__all__ = ["sbmm", "sbmm_raw", "sbmm_ref",
           "sbmm_quant_raw", "sbmm_quant_pallas", "sbmm_quant_ref"]
