"""Jit'd public wrapper for the SBMM kernel: padding, permutation handling,
and backend selection (real Pallas on TPU, interpret mode elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PackedWeight
from repro.core.quant import QuantizedPackedWeight
from repro.kernels.backend import resolve_interpret
from repro.kernels.sbmm.quant import sbmm_quant_pallas
from repro.kernels.sbmm.sbmm import sbmm_pallas


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def _sbmm_raw_jit(x: jax.Array, blocks: jax.Array, header: jax.Array,
                  tm: int, interpret: bool) -> jax.Array:
    C, S, b, _ = blocks.shape
    M, K = x.shape
    k_pad = (-K) % b
    m_pad = (-M) % tm
    if k_pad or m_pad:
        x = jnp.pad(x, ((0, m_pad), (0, k_pad)))
    y = sbmm_pallas(x, blocks, header, tm=tm, interpret=interpret)
    return y[:M]


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def _sbmm_quant_raw_jit(x: jax.Array, blocks: jax.Array, header: jax.Array,
                        scales: jax.Array, tm: int,
                        interpret: bool) -> jax.Array:
    C, S, b, _ = blocks.shape
    M, K = x.shape
    k_pad = (-K) % b
    m_pad = (-M) % tm
    if k_pad or m_pad:
        x = jnp.pad(x, ((0, m_pad), (0, k_pad)))
    y = sbmm_quant_pallas(x, blocks, header, scales, tm=tm,
                          interpret=interpret)
    return y[:M]


def sbmm_quant_raw(x: jax.Array, blocks: jax.Array, header: jax.Array,
                   scales: jax.Array, tm: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """Pad rows/cols and run the dequant-in-kernel variant. Backend
    auto-detection matches :func:`sbmm_raw` (resolved outside the jit)."""
    return _sbmm_quant_raw_jit(x, blocks, header, scales, tm,
                               resolve_interpret(interpret))


def sbmm_raw(x: jax.Array, blocks: jax.Array, header: jax.Array,
             tm: int = 128, interpret: bool | None = None) -> jax.Array:
    """Pad rows/cols and run the kernel. x: [M, K_logical].

    ``interpret=None`` auto-detects (compiled on TPU, interpreter on CPU
    CI; ``REPRO_KERNEL_INTERPRET`` overrides) — resolved here, outside the
    jit, so the resolved value is a static argument."""
    return _sbmm_raw_jit(x, blocks, header, tm, resolve_interpret(interpret))


def sbmm(x: jax.Array, packed: "PackedWeight | QuantizedPackedWeight",
         tm: int = 128, interpret: bool | None = None) -> jax.Array:
    """Full SBMM: y = x @ W_masked, undoing the load-balancing column
    permutation so callers see logical column order. A
    :class:`QuantizedPackedWeight` dispatches the dequant-in-kernel
    variant (int8 blocks, scales prefetched); an fp16-blocks PackedWeight
    rides the standard kernel (fp32 accumulation either way).

    x: [..., M1_any, K]; returns [..., M1_any, M2]."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if isinstance(packed, QuantizedPackedWeight):
        y = sbmm_quant_raw(x2, packed.blocks, packed.header, packed.scales,
                           tm=tm, interpret=interpret)
    else:
        y = sbmm_raw(x2, packed.blocks, packed.header, tm=tm,
                     interpret=interpret)
    b = packed.block_size
    m2 = packed.shape[1]
    # slot pc holds logical column perm[pc] -> scatter back
    C = packed.n_cols
    inv = np.empty(C, dtype=np.int64)
    inv[np.asarray(packed.col_perm)] = np.arange(C)
    y_blocks = y.reshape(x2.shape[0], C, b)
    y_logical = y_blocks[:, jnp.asarray(inv), :].reshape(x2.shape[0], C * b)
    return y_logical[:, :m2].reshape(lead + (m2,))
