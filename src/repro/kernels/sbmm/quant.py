"""Dequant-in-kernel SBMM — int8 gathered blocks × float activations.

Same grid/BlockSpec structure as the fp32 kernel (``sbmm.py``): one
(row-strip, block-column) cell per grid step, header-driven gather of
activation sub-tiles, fp32 accumulation. The difference is the weight
stream: blocks arrive as int8 and are dequantized in registers right
before the MXU — ``w = q.astype(f32) * scale`` — with the scales riding
scalar prefetch next to the header (the same PrefetchScalarGridSpec
pattern ``kernels.token_package`` uses for its per-row metadata). Per-block
scales multiply the whole b×b block; per-output-channel scales ([C, S, b])
broadcast over the block's output columns.

``sbmm_quant_ref`` is the jnp dequant oracle, written to mirror the
kernel's per-column accumulation order exactly so interpret-mode runs
bit-match it (tests assert ``array_equal``, not atol).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _sbmm_quant_kernel(header_ref, scales_ref, x_ref, blocks_ref, y_ref, *,
                       block_size: int, max_kept: int, tm: int,
                       per_channel: bool):
    """One (row-strip, block-column) grid cell with in-register dequant.

    header_ref : [n_cols, max_kept] int32 (scalar prefetch)
    scales_ref : [n_cols, max_kept] or [n_cols, max_kept, b] f32 (prefetch)
    x_ref      : [TM, K]   activation strip (VMEM)
    blocks_ref : [1, max_kept, b, b] int8 gathered blocks for this column
    y_ref      : [TM, b]   output tile
    """
    j = pl.program_id(1)
    b = block_size

    def body(s, acc):
        idx = header_ref[j, s]
        safe = jnp.maximum(idx, 0)
        x_blk = x_ref[:, pl.dslice(safe * b, b)]           # [TM, b] gather
        w_q = blocks_ref[0, s].astype(jnp.float32)         # [b, b]
        if per_channel:
            w_blk = w_q * scales_ref[j, s, :][None, :]     # per out-column
        else:
            w_blk = w_q * scales_ref[j, s]
        contrib = jnp.dot(x_blk.astype(jnp.float32), w_blk,
                          preferred_element_type=jnp.float32)
        return acc + jnp.where(idx >= 0, contrib, 0.0)

    acc = jax.lax.fori_loop(
        0, max_kept, body, jnp.zeros((tm, b), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


def sbmm_quant_pallas(x: jax.Array, blocks: jax.Array, header: jax.Array,
                      scales: jax.Array, *, tm: int = 128,
                      interpret: "bool | None" = None) -> jax.Array:
    """x: [M, K]; blocks: [C, S, b, b] int8; header: [C, S] int32;
    scales: [C, S] or [C, S, b] f32. Returns y: [M, C·b] in x.dtype.

    ``M`` must be a multiple of ``tm`` (ops.py pads). Header AND scales go
    through scalar prefetch (``num_scalar_prefetch=2``), so the dequant
    constant is resident before the column's blocks stream in."""
    interpret = resolve_interpret(interpret)
    M, K = x.shape
    C, S, b, _ = blocks.shape
    assert M % tm == 0, (M, tm)
    per_channel = scales.ndim == 3

    grid = (M // tm, C)
    kernel = functools.partial(_sbmm_quant_kernel, block_size=b, max_kept=S,
                               tm=tm, per_channel=per_channel)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, K), lambda i, j, hdr, scl: (i, 0)),
                pl.BlockSpec((1, S, b, b),
                             lambda i, j, hdr, scl: (j, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((tm, b), lambda i, j, hdr, scl: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, C * b), x.dtype),
        interpret=interpret,
    )(header, scales, x, blocks)


def sbmm_quant_ref(x: jnp.ndarray, blocks: jnp.ndarray, header: jnp.ndarray,
                   scales: jnp.ndarray) -> jnp.ndarray:
    """jnp dequant oracle, accumulation-order-matched to the kernel: per
    block-column, walk kept slots in header order, dequantize the block,
    matmul the gathered activation sub-tile in f32, and sum in slot order —
    bit-identical to an interpret-mode kernel run."""
    M, K = x.shape
    C, S, b, _ = blocks.shape
    hdr = np.asarray(header)
    scl = np.asarray(scales, np.float32)
    per_channel = scl.ndim == 3
    x32 = jnp.asarray(x, jnp.float32)
    cols = []
    for c in range(C):
        acc = jnp.zeros((M, b), jnp.float32)
        for s in range(S):
            r = int(hdr[c, s])
            if r < 0:
                continue  # adds exactly 0.0 in the kernel — bit-neutral
            w_q = jnp.asarray(blocks[c, s], jnp.float32)
            w = w_q * (scl[c, s][None, :] if per_channel else scl[c, s])
            acc = acc + jnp.dot(x32[:, r * b:(r + 1) * b], w,
                                preferred_element_type=jnp.float32)
        cols.append(acc)
    return jnp.concatenate(cols, axis=1).astype(x.dtype)
