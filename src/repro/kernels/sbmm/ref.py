"""Pure-jnp oracle for the SBMM kernel: reconstruct the masked dense weight
from the packed representation and matmul."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def sbmm_ref(x: jnp.ndarray, blocks: jnp.ndarray, header: jnp.ndarray
             ) -> jnp.ndarray:
    """x: [M, K]; blocks: [C, S, b, b]; header: [C, S]. y: [M, C·b].

    Direct (slow) reference: scatter blocks into a dense [K, C·b] weight,
    then one dense matmul in fp32."""
    M, K = x.shape
    C, S, b, _ = blocks.shape
    w = np.zeros((K, C * b), dtype=np.float32)
    hdr = np.asarray(header)
    blk = np.asarray(blocks, np.float32)
    for c in range(C):
        for s in range(S):
            r = int(hdr[c, s])
            if r < 0:
                continue
            w[r * b:(r + 1) * b, c * b:(c + 1) * b] = blk[c, s]
    y = jnp.asarray(np.asarray(x, np.float32) @ w)
    return y.astype(x.dtype)
