"""SBMM — Sparse Block-wise Matrix Multiplication Pallas kernel.

TPU-native realization of the paper's MPCA/SBMM (Algorithm 2): a dense
activation matrix multiplies a block-compressed weight. The weight is stored
column-major as gathered blocks with a per-column header of surviving
row-block indices (core/packing.py — the direct analog of the FPGA's CB
header format).

Mapping onto TPU:
  * grid = (M/TM, n_block_cols) — rows of the activation strip play the
    role of the p_t PE rows; block-columns play the p_c lanes (the offline
    column balancing in packing.py equalizes work across grid columns).
  * the activation strip [TM, K] is VMEM-resident (the GFB analog); the
    per-column gathered blocks [max_kept, b, b] stream through VMEM (the CB
    analog); the header rides in scalar memory (prefetched — SMEM analog).
  * each header entry drives a dynamic-slice gather of a [TM, b] activation
    sub-tile feeding the MXU — the hardware "fetch by header index" step.
  * accumulation is fp32 in registers; @pl.when skips padding entries
    (idx < 0), which is how load imbalance manifests as *skipped work*
    rather than wasted MACs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _sbmm_kernel(header_ref, x_ref, blocks_ref, y_ref, *, block_size: int,
                 max_kept: int, tm: int):
    """One (row-strip, block-column) grid cell.

    header_ref : [n_cols, max_kept] int32 (scalar prefetch)
    x_ref      : [TM, K]   activation strip (VMEM)
    blocks_ref : [1, max_kept, b, b] gathered weight blocks for this column
    y_ref      : [TM, b]   output tile
    """
    j = pl.program_id(1)
    b = block_size

    def body(s, acc):
        idx = header_ref[j, s]
        safe = jnp.maximum(idx, 0)
        x_blk = x_ref[:, pl.dslice(safe * b, b)]          # [TM, b] gather
        w_blk = blocks_ref[0, s]                           # [b, b]
        contrib = jnp.dot(x_blk, w_blk,
                          preferred_element_type=jnp.float32)
        return acc + jnp.where(idx >= 0, contrib, 0.0)

    acc = jax.lax.fori_loop(
        0, max_kept, body, jnp.zeros((tm, b), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


def sbmm_pallas(x: jax.Array, blocks: jax.Array, header: jax.Array,
                *, tm: int = 128,
                interpret: "bool | None" = None) -> jax.Array:
    """x: [M, K] (K padded to n_row_blocks·b); blocks: [C, S, b, b];
    header: [C, S] int32 (-1 padding). Returns y: [M, C·b].

    ``M`` must be a multiple of ``tm`` (ops.py pads). ``interpret=None``
    auto-detects the backend (kernels.backend)."""
    interpret = resolve_interpret(interpret)
    M, K = x.shape
    C, S, b, _ = blocks.shape
    assert M % tm == 0, (M, tm)

    grid = (M // tm, C)
    kernel = functools.partial(_sbmm_kernel, block_size=b, max_kept=S, tm=tm)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, K), lambda i, j, hdr: (i, 0)),
                pl.BlockSpec((1, S, b, b), lambda i, j, hdr: (j, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((tm, b), lambda i, j, hdr: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, C * b), x.dtype),
        interpret=interpret,
    )(header, x, blocks)
