from repro.kernels.token_drop.ops import token_drop
from repro.kernels.token_drop.ref import token_drop_ref

__all__ = ["token_drop", "token_drop_ref"]
