"""Jit'd wrapper for the token-drop kernel: computes top-k + drop weights
(the bitonic-sort analog runs as native XLA top_k) and invokes the fused
gather+reduce kernel. Batched via vmap."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.token_drop.token_drop import token_drop_pallas


@functools.partial(jax.jit, static_argnames=("r_t", "has_cls", "td",
                                             "interpret"))
def _token_drop_jit(z: jax.Array, scores: jax.Array, r_t: float,
                    has_cls: bool, td: int, interpret: bool) -> jax.Array:
    B, N, D = z.shape
    n_body = N - 1 if has_cls else N
    k = max(1, math.ceil(n_body * r_t))

    body = z[:, 1:] if has_cls else z
    s_body = scores[:, 1:] if has_cls else scores

    _, keep_idx = jax.lax.top_k(s_body, k)  # [B, k]
    keep_mask = jnp.zeros((B, n_body), bool)
    keep_mask = jnp.put_along_axis(keep_mask, keep_idx, True, axis=1,
                                   inplace=False)
    w = jnp.where(keep_mask, 0.0, s_body.astype(jnp.float32))
    w = w / (w.sum(axis=1, keepdims=True) + 1e-9)

    d_pad = (-D) % td
    if d_pad:
        body = jnp.pad(body, ((0, 0), (0, 0), (0, d_pad)))

    run = functools.partial(token_drop_pallas, td=td, interpret=interpret)
    out = jax.vmap(run)(body, keep_idx.astype(jnp.int32), w)
    out = out[..., :D]
    if has_cls:
        out = jnp.concatenate([z[:, :1], out], axis=1)
    return out


def token_drop(z: jax.Array, scores: jax.Array, r_t: float,
               has_cls: bool = True, td: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Batched TDM via the Pallas kernel.

    z: [B, N, D]; scores: [B, N]. Returns [B, N_kept, D] with
    N_kept = (1 if cls) + k + 1 (fused). ``interpret=None`` auto-detects
    the backend (kernels.backend; ``REPRO_KERNEL_INTERPRET`` overrides) —
    resolved outside the jit so the choice is a static argument."""
    return _token_drop_jit(z, scores, r_t, has_cls, td,
                           resolve_interpret(interpret))
