"""Pure-jnp oracle for the token-drop kernel."""
from __future__ import annotations

import jax.numpy as jnp


def token_drop_ref(z: jnp.ndarray, keep_idx: jnp.ndarray,
                   drop_weights: jnp.ndarray) -> jnp.ndarray:
    """z: [N, D]; keep_idx: [k]; drop_weights: [N] normalized. -> [k+1, D]."""
    kept = z[keep_idx]
    fused = (drop_weights.astype(jnp.float32)[None, :]
             @ z.astype(jnp.float32)).astype(z.dtype)
    return jnp.concatenate([kept, fused], axis=0)
