"""Token-drop Pallas kernel — the TDHM (Token Dropping Hardware Module)
adapted to TPU.

The FPGA TDHM sorts token scores with a bitonic network, then routes tokens
through index-shuffle networks into a new token buffer, fusing the non-top-k
tokens into one weighted-average token. On TPU the sort/top-k is native
(jax.lax.top_k, done outside), and the interesting fusion is the *single
VMEM-resident pass* that (a) gathers the kept rows and (b) reduces the
dropped rows into the fused token — one HBM read of Z instead of three
(gather + mask + reduce) in the unfused jnp path.

grid = (D / TD,): each cell owns a [N, TD] column slice of the token matrix.
  * kept rows: k dynamic-slice row gathers driven by prefetched indices
    (the index-shuffle network analog)
  * fused row: one [1, N] × [N, TD] matmul with the normalized drop weights
    (the weighted-aggregation tree analog).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _token_drop_kernel(keep_idx_ref, z_ref, w_ref, out_ref, *, k: int):
    """keep_idx_ref: [k] int32 (scalar prefetch)
    z_ref  : [N, TD] column slice of tokens
    w_ref  : [1, N] normalized drop weights (0 at kept rows)
    out_ref: [k + 1, TD] — kept rows then the fused token."""

    def gather_row(r, _):
        idx = keep_idx_ref[r]
        row = z_ref[pl.dslice(idx, 1), :]
        pl.store(out_ref, (pl.dslice(r, 1), slice(None)),
                 row.astype(out_ref.dtype))
        return 0

    jax.lax.fori_loop(0, k, gather_row, 0)
    fused = jnp.dot(w_ref[...], z_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)  # [1, TD]
    pl.store(out_ref, (pl.dslice(k, 1), slice(None)),
             fused.astype(out_ref.dtype))


def token_drop_pallas(z: jax.Array, keep_idx: jax.Array,
                      drop_weights: jax.Array, *, td: int = 128,
                      interpret: "bool | None" = None) -> jax.Array:
    """z: [N, D]; keep_idx: [k] int32; drop_weights: [N] (normalized, zero at
    kept rows). Returns [k + 1, D]: kept tokens followed by the fused token.
    ``D`` must be a multiple of ``td`` (ops.py pads). ``interpret=None``
    auto-detects the backend (kernels.backend)."""
    interpret = resolve_interpret(interpret)
    N, D = z.shape
    (k,) = keep_idx.shape
    assert D % td == 0, (D, td)
    kernel = functools.partial(_token_drop_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(D // td,),
            in_specs=[
                pl.BlockSpec((N, td), lambda j, idx: (0, j)),
                pl.BlockSpec((1, N), lambda j, idx: (0, 0)),
            ],
            out_specs=pl.BlockSpec((k + 1, td), lambda j, idx: (0, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((k + 1, D), z.dtype),
        interpret=interpret,
    )(keep_idx, z, drop_weights.reshape(1, N))
