from repro.kernels.token_package.ops import token_package
from repro.kernels.token_package.ref import token_package_ref
from repro.kernels.token_package.token_package import token_package_pallas

__all__ = ["token_package", "token_package_ref", "token_package_pallas"]
