"""Jit'd wrapper for the token-package kernel: the full soft-pruning TDM
step — top-k selection with the package row pinned, raw drop weights plus
the carried mass, then the fused gather + normalized scatter-reduce kernel.
Batched via vmap. Mirrors ``core.token_pruning.tdm_soft``'s selection math
exactly so the two agree wherever the kernel matmul matches the einsum."""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.token_package.token_package import token_package_pallas


@functools.partial(jax.jit, static_argnames=("k", "has_cls", "has_pkg",
                                             "td", "interpret"))
def _token_package_jit(z: jax.Array, scores: jax.Array,
                       pkg_mass: Optional[jax.Array], k: int, has_cls: bool,
                       has_pkg: bool, td: int, interpret: bool
                       ) -> Tuple[jax.Array, jax.Array]:
    B, N, D = z.shape
    n_body = N - 1 if has_cls else N

    body = z[:, 1:] if has_cls else z
    s_body = scores[:, 1:] if has_cls else scores

    if has_pkg:
        # pin the package (last body row) out of the top-k
        is_pkg = jnp.arange(n_body)[None, :] == n_body - 1
        sel = jnp.where(is_pkg, -jnp.inf, s_body)
    else:
        sel = s_body
    _, keep_idx = jax.lax.top_k(sel, k)  # [B, k]
    keep_mask = jnp.zeros((B, n_body), bool)
    keep_mask = jnp.put_along_axis(keep_mask, keep_idx, True, axis=1,
                                   inplace=False)
    w = jnp.where(keep_mask, 0.0, s_body.astype(jnp.float32))
    if has_pkg:
        w = jnp.where(is_pkg, pkg_mass.astype(jnp.float32)[:, None], w)
    new_mass = w.sum(axis=1)

    d_pad = (-D) % td
    if d_pad:
        body = jnp.pad(body, ((0, 0), (0, 0), (0, d_pad)))

    run = functools.partial(token_package_pallas, td=td, interpret=interpret)
    out = jax.vmap(run)(body, keep_idx.astype(jnp.int32), w)
    out = out[..., :D]
    if has_cls:
        out = jnp.concatenate([z[:, :1], out], axis=1)
    return out, new_mass


def token_package(z: jax.Array, scores: jax.Array,
                  r_t: "float | None" = None, has_cls: bool = True,
                  k: "int | None" = None,
                  pkg_mass: Optional[jax.Array] = None, td: int = 128,
                  interpret: "bool | None" = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Batched soft-pruning TDM via the Pallas kernel.

    z: [B, N, D]; scores: [B, N]; ``pkg_mass`` [B] is the accumulated
    package mass when the last body row is a package from a previous soft
    TDM (``None`` for the first). Returns ``(out [B, N_kept, D], new_mass
    [B])`` with N_kept = (1 if cls) + k + 1 (package). Same ``k`` clamp
    rule as ``tdm_soft``: with a package present, ``k <= N_body - 1``.
    ``interpret=None`` auto-detects the backend (kernels.backend;
    ``REPRO_KERNEL_INTERPRET`` overrides) — resolved outside the jit so
    the choice is a static argument."""
    n_body = z.shape[1] - 1 if has_cls else z.shape[1]
    if k is None:
        k = max(1, math.ceil(n_body * r_t))
        if pkg_mass is not None:
            k = min(k, n_body - 1)
    if pkg_mass is not None and k > n_body - 1:
        raise ValueError(f"token_package with a package row keeps the "
                         f"package plus k={k} of {n_body - 1} real body "
                         f"tokens — k must be <= {n_body - 1}")
    return _token_package_jit(z, scores, pkg_mass, k, has_cls,
                              pkg_mass is not None, td,
                              resolve_interpret(interpret))
