"""Pure-jnp oracle for the token-package kernel."""
from __future__ import annotations

import jax.numpy as jnp


def token_package_ref(z: jnp.ndarray, keep_idx: jnp.ndarray,
                      weights: jnp.ndarray) -> jnp.ndarray:
    """z: [N, D]; keep_idx: [k]; weights: [N] RAW (un-normalized; zero at
    kept rows). -> [k+1, D]: kept rows then
    ``(weights · z) / (Σ weights + 1e-9)``."""
    kept = z[keep_idx]
    w = weights.astype(jnp.float32)
    acc = w[None, :] @ z.astype(jnp.float32)
    package = (acc / (jnp.sum(w) + 1e-9)).astype(z.dtype)
    return jnp.concatenate([kept, package], axis=0)
