"""Token-package Pallas kernel — the soft-pruning (SPViT-style) sibling of
``token_drop``.

Where the TDHM fuses dropped tokens with pre-normalized weights, the soft
TDM carries a persistent *package token* whose accumulated score mass must
re-enter the aggregation at its raw scale. So this kernel is a weighted
scatter-reduce over UN-normalized weights, normalized in-VMEM:

    package = (w · Z) / (Σ w + eps)

with ``w`` holding raw dropped-token scores, the carried package mass at
the package row, and exactly 0 at kept rows — one [1, N] × [N, TD] matmul
plus a row-sum per column tile, fused with the k kept-row gathers in a
single VMEM-resident pass over Z (one HBM read instead of gather + mask +
reduce + divide in the unfused jnp path).

grid = (D / TD,): each cell owns a [N, TD] column slice of the token
matrix, same layout as ``token_drop``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _token_package_kernel(keep_idx_ref, z_ref, w_ref, out_ref, *, k: int):
    """keep_idx_ref: [k] int32 (scalar prefetch)
    z_ref  : [N, TD] column slice of tokens
    w_ref  : [1, N] RAW weights (dropped scores + package mass; 0 at kept)
    out_ref: [k + 1, TD] — kept rows then the normalized package token."""

    def gather_row(r, _):
        idx = keep_idx_ref[r]
        row = z_ref[pl.dslice(idx, 1), :]
        pl.store(out_ref, (pl.dslice(r, 1), slice(None)),
                 row.astype(out_ref.dtype))
        return 0

    jax.lax.fori_loop(0, k, gather_row, 0)
    w = w_ref[...].astype(jnp.float32)
    acc = jnp.dot(w, z_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)  # [1, TD]
    package = acc / (jnp.sum(w) + 1e-9)
    pl.store(out_ref, (pl.dslice(k, 1), slice(None)),
             package.astype(out_ref.dtype))


def token_package_pallas(z: jax.Array, keep_idx: jax.Array,
                         weights: jax.Array, *, td: int = 128,
                         interpret: "bool | None" = None) -> jax.Array:
    """z: [N, D]; keep_idx: [k] int32; weights: [N] RAW (un-normalized —
    dropped scores plus the carried package mass; zero at kept rows).
    Returns [k + 1, D]: kept tokens followed by the package token
    ``(weights · z) / (Σ weights + 1e-9)``. ``D`` must be a multiple of
    ``td`` (ops.py pads). ``interpret=None`` auto-detects the backend
    (kernels.backend)."""
    interpret = resolve_interpret(interpret)
    N, D = z.shape
    (k,) = keep_idx.shape
    assert D % td == 0, (D, td)
    kernel = functools.partial(_token_package_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(D // td,),
            in_specs=[
                pl.BlockSpec((N, td), lambda j, idx: (0, j)),
                pl.BlockSpec((1, N), lambda j, idx: (0, 0)),
            ],
            out_specs=pl.BlockSpec((k + 1, td), lambda j, idx: (0, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((k + 1, D), z.dtype),
        interpret=interpret,
    )(keep_idx, z, weights.reshape(1, N))
