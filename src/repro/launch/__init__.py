"""Launchers: mesh construction, the multi-pod dry-run, roofline analysis,
and the train/serve drivers.

NOTE: do not import repro.launch.dryrun from other modules — importing it
sets XLA_FLAGS for 512 host devices before jax initializes.
"""
from repro.launch import mesh  # noqa: F401  (safe: functions only)

__all__ = ["mesh"]
