"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) cell and each production mesh
(single-pod 16×16, multi-pod 2×16×16), this driver:

  1. builds ShapeDtypeStruct stand-ins for params / optimizer state / batch /
     caches (zero allocation — ``jax.eval_shape`` everywhere),
  2. assigns shardings from dist/sharding.py rules,
  3. ``jax.jit(step).lower(...).compile()`` — a failure here (sharding
     mismatch, OOM at compile, unsupported collective) is a bug in the
     framework, not the harness,
  4. records ``memory_analysis()`` / ``cost_analysis()`` / parsed collective
     bytes into a JSON cell file that EXPERIMENTS.md §Dry-run / §Roofline and
     the §Perf hillclimbs read.

NOTE the XLA_FLAGS assignment below MUST precede any jax import — jax locks
the device count at first init (it is the first executable statement of the
module; only the docstring and __future__ import sit above it). Tests
override REPRO_DRYRUN_DEVICES to run tiny meshes quickly.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES_BY_NAME, get_config, grid_cells
from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import elastic as ELASTIC
from repro.dist import sharding as SH
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh, make_mesh
from repro.models import model as M
from repro.models import steps as ST
from repro.optim.adamw import AdamW


# ---------------------------------------------------------------------------
def _spec_tree_params(cfg: ModelConfig, serve: bool = False):
    spec = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    if serve and cfg.serve_param_dtype != cfg.param_dtype:
        dt = jnp.dtype(cfg.serve_param_dtype)
        spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dt if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), spec)
    return spec


def _bytes_of_spec_tree(tree, shardings, n_dev) -> int:
    """Per-device bytes of a sharded spec tree (analytic; used when the CPU
    backend's memory_analysis is unavailable)."""
    flat = jax.tree_util.tree_flatten(tree)[0]
    shs = jax.tree_util.tree_flatten(shardings)[0]
    total = 0
    for leaf, sh in zip(flat, shs):
        n = 1
        for d in leaf.shape:
            n *= d
        frac = 1
        try:
            spec = sh.spec
            mesh = sh.mesh
            for ax in spec:
                if ax is None:
                    continue
                if isinstance(ax, tuple):
                    for a in ax:
                        frac *= mesh.shape[a]
                else:
                    frac *= mesh.shape[ax]
        except Exception:
            pass
        total += n * jnp.dtype(leaf.dtype).itemsize // max(frac, 1)
    return total


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               unroll: bool = False) -> Tuple[Any, Tuple, Dict]:
    """Returns (fn, (args specs), in_shardings tuple) for the cell."""
    params_spec = _spec_tree_params(cfg, serve=shape.kind != "train")
    params_sh = SH.params_shardings(cfg, mesh, params_spec)

    if shape.kind == "train":
        opt = AdamW()
        opt_spec = jax.eval_shape(opt.init, params_spec)
        opt_sh = type(opt_spec)(step=SH.replicated(mesh),
                                mu=params_sh, nu=params_sh)
        batch_spec = ST.input_specs(cfg, shape)
        batch_sh = SH.batch_shardings(mesh, batch_spec)

        step = ST.make_train_step(cfg, opt, with_pruning=False,
                                  unroll=unroll)

        def fn(params, opt_state, batch):
            new_p, _, new_o, metrics = step(params, opt_state, batch)
            return new_p, new_o, metrics

        return (fn, (params_spec, opt_spec, batch_spec),
                (params_sh, opt_sh, batch_sh))

    if shape.kind == "prefill":
        batch_spec = ST.input_specs(cfg, shape)
        batch_sh = SH.batch_shardings(mesh, batch_spec)
        cache_spec = jax.eval_shape(
            lambda: ST.init_caches(cfg, shape.global_batch, shape.seq_len))
        cache_sh = SH.cache_shardings(cfg, mesh, cache_spec)
        prefill = ST.make_prefill(cfg, unroll=unroll)
        return (prefill, (params_spec, batch_spec, cache_spec),
                (params_sh, batch_sh, cache_sh))

    # decode
    tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = SH.batch_shardings(mesh, tok_spec)
    cache_spec = ST.serve_state_specs(cfg, shape)
    cache_sh = SH.cache_shardings(cfg, mesh, cache_spec)
    decode = ST.make_decode_step(cfg, unroll=unroll)
    if cfg.family == "vlm":
        vis_spec = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.num_vision_tokens,
             cfg.vision_d_model or cfg.d_model), jnp.bfloat16)
        vis_sh = SH.batch_shardings(mesh, vis_spec)
        fn = lambda p, t, c, v: decode(p, t, c, v)
        return (fn, (params_spec, tok_spec, cache_spec, vis_spec),
                (params_sh, tok_sh, cache_sh, vis_sh))
    fn = lambda p, t, c: decode(p, t, c)
    return (fn, (params_spec, tok_spec, cache_spec),
            (params_sh, tok_sh, cache_sh))


# ---------------------------------------------------------------------------
# Cost probes: exact FLOP/byte/collective counts via two-point layer
# extrapolation. ``cost_analysis`` counts while-loop bodies once, so we
# compile *unrolled* reduced-depth variants (k and 2k repeating units),
# fit cost(u) = a + b·u, and extrapolate to the full unit count.
# ---------------------------------------------------------------------------
def _unit_counts(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.num_layers // cfg.cross_attn_period
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_layer_period
    return cfg.num_layers


def _with_units(cfg: ModelConfig, units: int) -> ModelConfig:
    fam = cfg.family
    if fam == "vlm":
        return cfg.replace(num_layers=units * cfg.cross_attn_period)
    if fam == "hybrid":
        full_rem = cfg.num_layers % cfg.attn_layer_period
        return cfg.replace(num_layers=units * cfg.attn_layer_period + full_rem)
    if fam == "audio":
        return cfg.replace(num_layers=units, encoder_layers=units)
    return cfg.replace(num_layers=units)


def _probe_costs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict:
    """Compile unrolled 1-unit and 2-unit variants; return extrapolated
    (flops, bytes, collective_bytes) at the full unit count."""
    pts = []
    for u in (1, 2):
        c_small = _with_units(cfg, u)
        fn, specs, shardings = build_cell(c_small, shape, mesh, unroll=True)
        with mesh:
            compiled = jax.jit(fn, in_shardings=shardings).lower(
                *specs).compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        cost = dict(cost)
        coll = RL.parse_collectives(compiled.as_text(),
                                    default_trip_count=1)
        pts.append({
            "units": u,
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.total_bytes),
            "coll_by_kind": dict(coll.bytes_by_kind),
        })
    U = _unit_counts(cfg)
    (p1, p2) = pts

    def extrap(k):
        b = (p2[k] - p1[k]) / (p2["units"] - p1["units"])
        a = p1[k] - b * p1["units"]
        return a + b * U

    coll_by_kind = {}
    for kind in set(p1["coll_by_kind"]) | set(p2["coll_by_kind"]):
        c1 = p1["coll_by_kind"].get(kind, 0.0)
        c2 = p2["coll_by_kind"].get(kind, 0.0)
        b = c2 - c1
        a = c1 - b
        coll_by_kind[kind] = max(0.0, a + b * U)
    return {
        "flops": max(0.0, extrap("flops")),
        "bytes": max(0.0, extrap("bytes")),
        "collective_bytes": max(0.0, extrap("coll")),
        "collectives_by_kind": coll_by_kind,
        "probe_points": pts,
        "units_full": U,
    }


def run_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, mesh_name: str,
             out_dir: Optional[str] = None) -> Dict:
    t0 = time.time()
    result: Dict[str, Any] = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "chips": mesh.devices.size, "status": "ok",
    }
    try:
        fn, specs, shardings = build_cell(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(fn, in_shardings=shardings)
            lowered = jitted.lower(*specs)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

        mem = compiled.memory_analysis()
        mem_stats = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_stats[k] = int(v)
        if "argument_size_in_bytes" not in mem_stats:
            mem_stats["argument_size_in_bytes"] = sum(
                _bytes_of_spec_tree(s, sh, mesh.devices.size)
                for s, sh in zip(specs, shardings))
        # exact costs via unrolled two-point probes (the full compile above
        # is the pass/fail + memory proof; scans hide per-layer cost)
        probe = _probe_costs(cfg, shape, mesh)
        t_probe = time.time()
        rep = RL.analyze(cfg, shape, mesh_name, mesh.devices.size,
                         probe["flops"], probe["bytes"],
                         probe["collective_bytes"],
                         probe["collectives_by_kind"], mem_stats)
        result.update(
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            probe_s=round(t_probe - t_compile, 2),
            memory=mem_stats,
            probe=probe,
            roofline=dataclasses.asdict(rep),
        )
    except Exception as e:  # noqa: BLE001
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["wall_s"] = round(time.time() - t0, 2)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{cfg.name}__{shape.name}__{mesh_name}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both", "tiny"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", lambda: make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       lambda: make_production_mesh(multi_pod=True)))
    if args.mesh == "tiny":  # test path: REPRO_DRYRUN_DEVICES=4..8
        # elastic: shrink the reference 2x2x2 plan to the forced device
        # count (data axes absorb the loss, TP degree is preserved)
        plan = ELASTIC.replan(
            jax.device_count(),
            ELASTIC.MeshPlan((2, 2, 2), ("pod", "data", "model")))
        name = "tiny_" + "x".join(str(s) for s in plan.shape)
        meshes.append((name, lambda: make_mesh(plan.shape, plan.axes)))

    cells = grid_cells(args.arch)
    if args.shape:
        cells = [(c, s) for c, s in cells if s.name == args.shape]

    failures = 0
    for mesh_name, mk in meshes:
        mesh = mk()
        for cfg, shape in cells:
            fname = os.path.join(
                args.out, f"{cfg.name}__{shape.name}__{mesh_name}.json")
            if args.skip_done and os.path.exists(fname):
                with open(fname) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"[skip] {cfg.name} {shape.name} {mesh_name}")
                        continue
            r = run_cell(cfg, shape, mesh, mesh_name, args.out)
            ok = r["status"] == "ok"
            failures += (not ok)
            dom = r.get("roofline", {}).get("dominant", "-")
            print(f"[{'ok' if ok else 'FAIL'}] {cfg.name} {shape.name} "
                  f"{mesh_name} wall={r['wall_s']}s dominant={dom}"
                  + ("" if ok else f" :: {r['error']}"))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
