"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[list] = None) -> Mesh:
    """16×16 per pod (256 chips); 2×16×16 across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if devices is not None:
        import numpy as np
        return Mesh(np.asarray(devices).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests / elastic replans / degraded runs)."""
    return jax.make_mesh(shape, axes)


def required_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
