"""§Perf hillclimb driver: re-run one cell's cost probes under a named
config variant and report the three roofline terms vs the baseline.

    PYTHONPATH=src python -m repro.launch.perf --arch command-r-plus-104b \\
        --shape train_4k --variant remat_dots fuse_qkv

Each variant is one hypothesis→change→measure iteration; results land in
experiments/perf/<arch>__<shape>__<variant>.json and the comparison table
is assembled into EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

import argparse
import dataclasses
import json
import time
from typing import Dict

import jax

from repro.configs import SHAPES_BY_NAME, get_config
from repro.configs.base import ModelConfig
from repro.launch import roofline as RL
from repro.launch.dryrun import _probe_costs, build_cell
from repro.launch.mesh import make_production_mesh, make_mesh


# variant name -> ModelConfig overrides
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    "serve_bf16": {"serve_param_dtype": "bfloat16"},
    "fuse_qkv": {"fuse_qkv": True},
    "remat_dots": {"remat_policy": "dots"},
    "remat_none": {"remat_policy": "none"},
    "loss_chunk_512": {"loss_chunk": 512},
    "loss_chunk_4096": {"loss_chunk": 4096},
    "remat_dots+fuse_qkv": {"remat_policy": "dots", "fuse_qkv": True},
    "serve_bf16+fuse_qkv": {"serve_param_dtype": "bfloat16",
                            "fuse_qkv": True},
    "pad_experts": {"moe_expert_pad_to": 16},
    "pad_experts+fuse_qkv": {"moe_expert_pad_to": 16, "fuse_qkv": True},
    "microbatch_4": {"microbatches": 4},
    "microbatch_8": {"microbatches": 8},
    "microbatch_8+remat_dots": {"microbatches": 8, "remat_policy": "dots"},
    "rwkv_shard_kv": {"shard_rwkv_kv": True},
    "rwkv_shard_kv+serve_bf16": {"shard_rwkv_kv": True,
                                 "serve_param_dtype": "bfloat16"},
    "moe_cf_1": {"moe_expert_pad_to": 16, "moe_capacity_factor": 1.0},
    "rwkv_chunked_32": {"rwkv_chunk": 32},
    # modeled: swap the jnp chunked attention for the validated Pallas
    # flash kernel. XLA-CPU cannot compile Pallas TPU kernels, so the
    # memory term is corrected analytically: the fp32 score/probability
    # tiles (s, p + their backward recomputation) that the jnp path writes
    # to HBM stay in VMEM inside the kernel. attention_tile_bytes() below
    # documents the subtraction; everything else is the measured probe.
    "pallas_flash_modeled": {},
    "pallas_flash+microbatch8+fuseqkv": {"microbatches": 8,
                                         "fuse_qkv": True},
}

MODELED_FLASH = {"pallas_flash_modeled", "pallas_flash+microbatch8+fuseqkv"}


def attention_tile_bytes(cfg, shape, chips: int) -> float:
    """Per-device bytes of the fp32 attention s/p tiles that the jnp
    chunked path materializes in HBM and the Pallas kernel keeps in VMEM.

    passes: fwd writes+reads s, then p (2 tensors x write+read = 4);
    training backward under full remat recomputes both and forms ds/dp
    (another 4); inference = 2 effective passes (p consumed fused)."""
    if cfg.family in ("ssm",):
        return 0.0
    B = shape.global_batch
    if shape.kind == "train":
        Nq = Nk = shape.seq_len
        passes = 8
    elif shape.kind == "prefill":
        Nq = Nk = shape.seq_len
        passes = 2
    else:  # decode: single q row — tiles negligible but counted
        Nq, Nk = 1, shape.seq_len
        passes = 2
    data = model = 16 if chips >= 256 else 2
    B_loc = max(B // data, 1)
    H_loc = (cfg.num_heads // model if cfg.num_heads % model == 0
             else cfg.num_heads)
    n_attn = cfg.num_layers + cfg.encoder_layers
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // max(cfg.attn_layer_period, 1)
    return float(passes * B_loc * H_loc * Nq * Nk * 4 * n_attn)


def run_variant(arch: str, shape_name: str, variant: str, mesh,
                mesh_name: str, out_dir: str,
                full_mem: bool = False) -> Dict:
    cfg = get_config(arch).replace(**VARIANTS[variant])
    shape = SHAPES_BY_NAME[shape_name]
    t0 = time.time()
    mem_stats = {}
    if full_mem:
        fn, specs, shardings = build_cell(cfg, shape, mesh)
        with mesh:
            compiled = jax.jit(fn, in_shardings=shardings).lower(
                *specs).compile()
        mem = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "temp_size_in_bytes",
                  "output_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_stats[k] = int(v)
    probe = _probe_costs(cfg, shape, mesh)
    if variant in MODELED_FLASH:
        tiles = attention_tile_bytes(cfg, shape, mesh.devices.size)
        probe["bytes"] = max(probe["bytes"] - tiles, 0.0)
        probe["tile_bytes_subtracted"] = tiles
    rep = RL.analyze(cfg, shape, mesh_name, mesh.devices.size,
                     probe["flops"], probe["bytes"],
                     probe["collective_bytes"],
                     probe["collectives_by_kind"], mem_stats)
    result = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": mesh_name, "wall_s": round(time.time() - t0, 1),
        "probe": probe, "roofline": dataclasses.asdict(rep),
        "memory": mem_stats,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir,
                           f"{arch}__{shape_name}__{variant}.json"), "w") as f:
        json.dump(result, f, indent=1, default=str)
    r = rep
    print(f"[{variant}] compute={r.compute_s*1e3:.3f}ms "
          f"memory={r.memory_s*1e3:.3f}ms coll={r.collective_s*1e3:.3f}ms "
          f"dominant={r.dominant} useful={r.useful_ratio:.2f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", nargs="+", default=["baseline"])
    ap.add_argument("--mesh", default="single", choices=["single", "tiny"])
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--full-mem", action="store_true",
                    help="also run the full compile for memory_analysis")
    args = ap.parse_args()
    if args.mesh == "single":
        mesh = make_production_mesh()
        mesh_name = "single_pod_16x16"
    else:
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        mesh_name = "tiny_2x2x2"
    for v in args.variant:
        run_variant(args.arch, args.shape, v, mesh, mesh_name, args.out,
                    full_mem=args.full_mem)


if __name__ == "__main__":
    main()
