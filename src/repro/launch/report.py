"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
cell JSONs, and §Perf from the perf-variant JSONs.

    PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

from repro.configs import SHAPES_BY_NAME, get_config
from repro.core.perf_model import TPU_HBM_BW
from repro.launch.roofline import analytic_memory_bytes

DRY = "experiments/dryrun"
PERF = "experiments/perf"
MD = "EXPERIMENTS.md"


def load(dirname):
    out = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f} GiB"


def dryrun_table(cells) -> str:
    lines = [
        "| arch | shape | mesh | status | compile [s] | args/chip | temps/chip |",
        "|---|---|---|---|---|---|---|",
    ]
    ok = err = 0
    for c in cells:
        mem = c.get("memory", {})
        status = c.get("status")
        ok += status == "ok"
        err += status != "ok"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh'].replace('_',' ')} | "
            f"{status} | {c.get('compile_s','-')} | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes', 0))} |")
    lines.append("")
    lines.append(f"**{ok} ok / {err} failed** across "
                 f"{len({c['mesh'] for c in cells})} mesh(es).")
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| arch | shape | compute [ms] | mem lo..hi [ms] | collective [ms] | "
        "dominant | useful | peak mem/chip | top collective | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "ok" or "roofline" not in c:
            continue
        if "single" not in c["mesh"]:
            continue  # roofline table is single-pod per the spec
        r = c["roofline"]
        cfg = get_config(r["arch"])
        shp = SHAPES_BY_NAME[r["shape"]]
        mem_lo = analytic_memory_bytes(cfg, shp, r["chips"]) / TPU_HBM_BW
        colls = r.get("collectives", {})
        top = max(colls.items(), key=lambda kv: kv[1])[0] if any(
            colls.values()) else "-"
        note = _bottleneck_note(r, mem_lo)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.3f} | "
            f"{mem_lo*1e3:.2f}..{r['memory_s']*1e3:.0f} | "
            f"{r['collective_s']*1e3:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{fmt_bytes(r['peak_mem_bytes'])} | {top} | {note} |")
    return "\n".join(lines)


def _bottleneck_note(r, mem_lo) -> str:
    """One sentence on what moves the dominant term down."""
    comp, coll = r["compute_s"], r["collective_s"]
    if r["dominant"] == "memory":
        if mem_lo < comp:
            return ("fusion-bound upper: on TPU fusion pushes toward the "
                    "analytic floor, turning this compute-bound")
        if "train" in r["shape"] or "prefill" in r["shape"]:
            return "flash-kernel VMEM tiles + bf16 master weights cut traffic"
        return "bf16 serve weights + KV pruning halve the weight/cache reads"
    if r["dominant"] == "collective":
        return "fuse QKV + overlap reduce-scatter with backprop"
    return "increase per-chip batch or sequence to amortize weight reads"


def perf_table(cells) -> str:
    if not cells:
        return "_(run repro.launch.perf to populate)_"
    by_cell = defaultdict(list)
    for c in cells:
        by_cell[(c["arch"], c["shape"])].append(c)
    out = []
    for (arch, shape), vs in sorted(by_cell.items()):
        out.append(f"\n### {arch} × {shape}\n")
        out.append("| variant | compute [ms] | memory [ms] | collective [ms]"
                   " | dominant | vs baseline dominant |")
        out.append("|---|---|---|---|---|---|")
        base = next((v for v in vs if v["variant"] == "baseline"), None)
        for v in sorted(vs, key=lambda x: x["variant"] != "baseline"):
            r = v["roofline"]
            delta = ""
            if base and v is not base:
                b = base["roofline"]
                dom = b["dominant"] + "_s"
                if b[dom] > 0:
                    delta = f"{(r[dom]/b[dom]-1)*100:+.1f}%"
            out.append(
                f"| {v['variant']} | {r['compute_s']*1e3:.3f} | "
                f"{r['memory_s']*1e3:.3f} | {r['collective_s']*1e3:.3f} | "
                f"{r['dominant']} | {delta} |")
    return "\n".join(out)


def main():
    dry = load(DRY)
    perf = load(PERF)
    with open(MD) as f:
        md = f.read()
    md = md.replace("RESULTS_DRYRUN_PLACEHOLDER", dryrun_table(dry)) \
           .replace("RESULTS_ROOFLINE_PLACEHOLDER", roofline_table(dry)) \
           .replace("RESULTS_PERF_PLACEHOLDER", perf_table(perf))
    with open(MD, "w") as f:
        f.write(md)
    print(f"rendered {len(dry)} dry-run cells, {len(perf)} perf variants")


if __name__ == "__main__":
    main()
