"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs       / (chips · 197e12)         [bf16 peak]
    memory     = HLO_bytes       / (chips · 819e9)          [HBM]
    collective = collective_bytes / (chips · links · 50e9)  [ICI]

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are parsed from the optimized HLO text: we sum the *result shape*
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, multiplying ops that live inside while-loop bodies
(scan layers) by the loop trip count when it is recoverable from the HLO,
else by the model's layer count (documented approximation).

Also reported: MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per
token·step, and MODEL_FLOPS / HLO_FLOPs — the useful-compute ratio that
catches remat and masked-attention waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.perf_model import TPU_PEAK_FLOPS, TPU_HBM_BW, TPU_ICI_BW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'f32[128,1024]' or a tuple
    '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, default_trip_count: int = 1
                      ) -> CollectiveStats:
    """Sum collective result bytes over the optimized module.

    Computation-aware: ops inside a computation whose name suggests a loop
    body are multiplied by ``default_trip_count`` (the caller passes the
    scan length, i.e. the layer count)."""
    bytes_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    current_comp = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{$", stripped)
        if stripped.endswith("{") and ("(" in stripped):
            cm = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if cm:
                current_comp = cm.group(1)
        for kind in _COLLECTIVES:
            # matches: %x = TYPE[SHAPE] all-reduce(...), or all-reduce-start
            if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
                lhs = stripped.split(f" {kind}")[0]
                nbytes = _shape_bytes(lhs)
                mult = 1
                if any(t in current_comp for t in ("body", "while", "scan",
                                                   "loop")):
                    mult = default_trip_count
                bytes_by[kind] += nbytes * mult
                count_by[kind] += mult
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    peak_mem_bytes: int
    collectives: Dict[str, int]

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s*1e3:.3f} | {self.memory_s*1e3:.3f} | "
                f"{self.collective_s*1e3:.3f} | {self.dominant} | "
                f"{self.useful_ratio:.2f} | {self.peak_mem_bytes/2**30:.2f} |")


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_params·D_tokens for train; 2·N·D for a forward-only cell.
    MoE counts active params only."""
    n = cfg.param_count(active_only=cfg.family == "moe")
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def ssm_scan_correction(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Tuple[float, float]:
    """Analytic (flops, bytes) for the recurrent time scans that cannot be
    unrolled in the cost probes (mamba2 / rwkv6 state updates run S
    sequential steps; the probe counts exactly one). Returns the missing
    (S-1)/S portion. Decode shapes run one step — no correction."""
    if cfg.family not in ("hybrid", "ssm") or shape.kind == "decode":
        return 0.0, 0.0
    B = shape.global_batch
    S = shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd for training
    if cfg.family == "hybrid":
        inner = cfg.ssm_expand * cfg.d_model
        H = inner // 64
        per_step_flops = 5.0 * B * H * 64 * cfg.ssm_state
        per_step_bytes = 4.0 * B * (inner + 2 * cfg.ssm_state + H) * 2
        L = cfg.num_layers
    else:  # rwkv6
        H = cfg.num_heads
        dh = cfg.d_model // H
        per_step_flops = 5.0 * B * H * dh * dh
        per_step_bytes = 4.0 * B * 4 * cfg.d_model * 2
        L = cfg.num_layers
    extra = (S - 1) * L * mult
    return per_step_flops * extra, per_step_bytes * extra


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                          flash_kernel: bool = False) -> float:
    """Per-device HBM bytes under PERFECT fusion — the lower bound that
    brackets the measured (XLA-CPU, fusion-naive) upper bound. On TPU the
    achieved traffic sits near this bound; §Perf reports both.

    Components: weight+optimizer traffic, one read+write per major
    activation (bf16), attention s/p tiles (dropped when ``flash_kernel`` —
    the Pallas kernel keeps them in VMEM), KV-cache traffic for decode,
    loss-chunk logits for train."""
    data = model = 16 if chips >= 256 else 2
    B = shape.global_batch
    S = shape.seq_len
    D, FF, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    L = cfg.num_layers + cfg.encoder_layers
    params_local = cfg.param_count() / (model if chips >= 256 else 1)

    if shape.kind == "train":
        B_loc = max(B // data, 1)
        toks = B_loc * S
        act = (6 * toks * D + 3 * toks * FF) * 2          # bf16 fwd
        act *= 2.5                                         # bwd + remat
        opt = 7 * params_local * 4                         # p, g, m, v traffic
        tiles = 0.0
        if cfg.family not in ("ssm",) and not flash_kernel:
            H_loc = (cfg.num_heads // model
                     if cfg.num_heads % model == 0 else cfg.num_heads)
            tiles = 8 * B_loc * H_loc * S * S * 4
        loss = 2 * toks * (V / model) * 4                  # chunked logits
        return act * L + opt + tiles * L + loss
    if shape.kind == "prefill":
        B_loc = max(B // data, 1)
        toks = B_loc * S
        act = (6 * toks * D + 3 * toks * FF) * 2
        tiles = 0.0
        if cfg.family not in ("ssm",) and not flash_kernel:
            H_loc = (cfg.num_heads // model
                     if cfg.num_heads % model == 0 else cfg.num_heads)
            tiles = 2 * B_loc * H_loc * S * S * 4
        cache_w = 2 * toks * cfg.num_kv_heads * cfg.head_dim * 2 / \
            max(model if cfg.num_kv_heads % model == 0 else 1, 1)
        return (act + tiles + cache_w) * L + 2 * params_local
    # decode: weights once + cache read once + tiny activations
    B_loc = max(B // data, 1)
    pbytes = 2 if cfg.serve_param_dtype == "bfloat16" else 4
    cache = (2 * B_loc * S * cfg.num_kv_heads * cfg.head_dim * 2
             / max(model if cfg.num_kv_heads % model == 0 else 1, 1)) * L
    if cfg.family == "ssm":
        cache = 0.0
    if cfg.family == "hybrid":
        cache *= (cfg.num_layers // max(cfg.attn_layer_period, 1)) / max(L, 1)
    return params_local * pbytes + cache + B_loc * 20 * D * 2 * L


def analyze(cfg: ModelConfig, shape: ShapeConfig, mesh_name: str, chips: int,
            flops: float, hbytes: float, collective_bytes: float,
            collectives_by_kind: Dict[str, float], memory_stats: Dict,
            ici_links: int = 4) -> RooflineReport:
    """``flops`` / ``hbytes`` / ``collective_bytes`` are PER-DEVICE numbers
    (XLA's cost_analysis reports the partitioned per-device program; the
    calibration test in tests/test_roofline.py pins this convention). The
    roofline terms therefore divide by single-chip peaks; ``useful_ratio``
    rescales model flops by the chip count."""
    ssm_flops, ssm_bytes = ssm_scan_correction(cfg, shape)
    flops = flops + ssm_flops / chips
    hbytes = hbytes + ssm_bytes / chips

    compute_s = flops / TPU_PEAK_FLOPS
    memory_s = hbytes / TPU_HBM_BW
    collective_s = collective_bytes / (ici_links * TPU_ICI_BW)
    dominant = max([("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)], key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    total_hlo_flops = flops * chips
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=total_hlo_flops, hlo_bytes=hbytes * chips,
        collective_bytes=float(collective_bytes) * chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf,
        useful_ratio=(mf / total_hlo_flops) if total_hlo_flops else 0.0,
        peak_mem_bytes=int(memory_stats.get("temp_size_in_bytes", 0)
                           + memory_stats.get("argument_size_in_bytes", 0)),
        collectives={k: int(v) for k, v in collectives_by_kind.items()},
    )
