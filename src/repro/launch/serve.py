"""Serving launcher: batched requests through the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \\
        --requests 8 --max-new 16 --kv-prune 0.5

Demonstrates the beyond-paper dynamic KV-cache pruning (the paper's token
scoring adapted to decode) on a runnable reduced model.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving import EngineConfig, Request, ServeEngine


def serve(arch: str, num_requests: int = 8, prompt_len: int = 16,
          max_new: int = 16, kv_prune: float = 1.0, reduced: bool = True,
          max_batch: int = 4, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ec = EngineConfig(
        max_batch=max_batch,
        max_len=prompt_len + max_new + 8,
        kv_prune_interval=4 if kv_prune < 1.0 else 0,
        kv_prune_keep=kv_prune)
    engine = ServeEngine(cfg, params, ec)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(num_requests)]
    t0 = time.time()
    out = engine.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in out.values())
    return {"outputs": out, "seconds": dt,
            "tokens_per_s": total_tokens / dt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-prune", type=float, default=1.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()
    out = serve(args.arch, args.requests, args.prompt_len, args.max_new,
                args.kv_prune, args.reduced)
    print(f"served {args.requests} requests in {out['seconds']:.2f}s "
          f"({out['tokens_per_s']:.1f} tok/s)")
    for uid, toks in sorted(out["outputs"].items()):
        print(f"  req {uid}: {toks[:8]}{'...' if len(toks) > 8 else ''}")


if __name__ == "__main__":
    main()
