"""Serving launcher: batched requests through the layered serving API
(Scheduler / KVCacheManager / ModelRunner composed by ServeEngine).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \\
        --requests 8 --max-new 16 --kv-prune 0.5

``--continuous`` serves through the slot-based continuous-batching path
(admission prefills only the admitted prompt via per-slot cache writes);
``--no-slot-prefill`` forces the PR-2 whole-batch re-prefill for A/B runs.
``--elastic-drop N`` additionally simulates losing half the devices after
``N`` engine steps, exercising the degradation_path replan + re-shard
(meaningful with >1 device, e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Demonstrates the beyond-paper dynamic KV-cache pruning (the paper's token
scoring adapted to decode) on a runnable reduced model.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.obs import MetricsRegistry, Tracer
from repro.serving import ElasticContext, EngineConfig, Request, ServeEngine


def simulated_loss_context(params, drop_after: int,
                           directory: str) -> ElasticContext:
    """ElasticContext that reports full capacity for ``drop_after`` probes,
    then half the devices forever after (the checkpoint holding ``params``
    is written into ``directory``)."""
    from repro.checkpoint import CheckpointManager
    from repro.dist.elastic import MeshPlan

    ndev = jax.device_count()
    manager = CheckpointManager(directory, keep=1)
    manager.save(0, params)
    degraded = max(ndev // 2, 1)
    probes = {"n": 0}

    def device_count() -> int:
        probes["n"] += 1
        return ndev if probes["n"] <= drop_after else degraded

    return ElasticContext(
        manager=manager,
        plan=MeshPlan((ndev, 1), ("data", "model")),
        budgets=[degraded, 1],
        device_count=device_count)


def serve(arch: str, num_requests: int = 8, prompt_len: int = 16,
          max_new: int = 16, kv_prune: float = 1.0, reduced: bool = True,
          max_batch: int = 4, seed: int = 0, continuous: bool = False,
          elastic_drop: int = 0, per_slot_prefill: bool = True,
          policy: str = "fifo", pipeline_depth: int = 1,
          trace_out: str = "", metrics_out: str = ""):
    if elastic_drop and not continuous:
        raise ValueError("--elastic-drop requires --continuous: only the "
                         "slot path probes device_count() between steps")
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ec = EngineConfig(
        max_batch=max_batch,
        max_len=prompt_len + 2 * max_new + 8,
        kv_prune_interval=4 if kv_prune < 1.0 else 0,
        kv_prune_keep=kv_prune,
        per_slot_prefill=per_slot_prefill,
        pipeline_depth=pipeline_depth)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(num_requests)]
    tracer = Tracer() if trace_out else None
    with tempfile.TemporaryDirectory(prefix="elastic_") as ckpt_dir:
        elastic = (simulated_loss_context(params, elastic_drop, ckpt_dir)
                   if elastic_drop else None)
        engine = ServeEngine(cfg, params, ec, elastic=elastic,
                             policy=policy, tracer=tracer)
        t0 = time.time()
        out = engine.serve(reqs, continuous=continuous)
        dt = time.time() - t0
    if trace_out:
        tracer.write_chrome_trace(trace_out)
    if metrics_out:
        engine.export_metrics(MetricsRegistry()).write_json(metrics_out)
    total_tokens = sum(len(v) for v in out.values())
    return {"outputs": out, "seconds": dt,
            "tokens_per_s": total_tokens / dt,
            "events": list(engine.events),
            "stats": engine.stats()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-prune", type=float, default=1.0)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the slot-based continuous path")
    ap.add_argument("--no-slot-prefill", action="store_true",
                    help="force PR-2 whole-batch re-prefill on admission")
    ap.add_argument("--elastic-drop", type=int, default=0, metavar="N",
                    help="simulate losing half the devices after N steps")
    ap.add_argument("--policy", default="fifo",
                    help="admission policy: fifo | shortest_prompt_first "
                         "| prune_pressure_aware (shared with the vision "
                         "path)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="StepPipeline depth for the continuous path: 1 "
                         "= synchronous stepping (the reference path), 2 "
                         "= stage step N+1 while the device executes "
                         "step N (bit-exact)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Chrome trace_event JSON (Perfetto-"
                         "loadable) of the run's plan/stage/dispatch/"
                         "complete spans to PATH at exit")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the engine's metrics-registry snapshot "
                         "(JSON) to PATH at exit")
    ap.add_argument("--json", action="store_true",
                    help="print a machine-readable result line")
    args = ap.parse_args()
    out = serve(args.arch, args.requests, args.prompt_len, args.max_new,
                args.kv_prune, args.reduced, max_batch=args.max_batch,
                continuous=args.continuous, elastic_drop=args.elastic_drop,
                per_slot_prefill=not args.no_slot_prefill,
                policy=args.policy, pipeline_depth=args.pipeline_depth,
                trace_out=args.trace_out, metrics_out=args.metrics_out)
    if args.json:
        print(json.dumps({
            "outputs": {str(k): v for k, v in out["outputs"].items()},
            "tokens_per_s": out["tokens_per_s"],
            "events": out["events"],
            "stats": out["stats"]}))
        return
    st = out["stats"]
    print(f"served {args.requests} requests in {out['seconds']:.2f}s "
          f"({out['tokens_per_s']:.1f} tok/s)")
    print(f"  admissions: {st['admissions']}, prefilled "
          f"{st['prefill_tokens_per_admission']:.1f} tok/admission, "
          f"{st['jit_compile_count']} jit compiles, "
          f"{st['prune_events']} KV prunes")
    for uid, toks in sorted(out["outputs"].items()):
        print(f"  req {uid}: {toks[:8]}{'...' if len(toks) > 8 else ''}")
    for ev in out["events"]:
        if ev[0] == "degrade":
            print(f"  degraded to mesh {ev[1]}")


if __name__ == "__main__":
    main()
