"""Trace replay launcher: drive either serving engine from a traffic trace.

    # synthesize a bursty vision trace, replay it, print the SLO report
    PYTHONPATH=src python -m repro.launch.serve_trace --engine vision \\
        --process bursty --requests 16 --rate 2000 --deadline-ms 0.1

    # replay a saved trace file with admission control on
    PYTHONPATH=src python -m repro.launch.serve_trace \\
        --trace examples/traces/bursty_vision.jsonl --admission-limit-ms 0.05

The launcher composes the three traffic pieces end to end: a
:class:`~repro.traffic.workload.Trace` (loaded from ``--trace`` JSONL or
synthesized from the arrival/mix knobs and ``--save-trace``-able for
replay elsewhere), the :class:`~repro.traffic.harness.TrafficHarness`
(virtual-clock replay with per-request lifecycle accounting), and —
when ``--admission-limit-ms`` is set — the cost-model
:class:`~repro.traffic.admission.AdmissionController` installed on the
engine's Scheduler (degrade-then-reject when ``--quality`` enables the
QualityController). All reported timestamps are virtual: deterministic
for a given (trace, config), identical at any ``--pipeline-depth``.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.models import model as M
from repro.obs import MetricsRegistry, Tracer
from repro.serving import (EngineConfig, ServeEngine, VisionEngine,
                           VisionEngineConfig)
from repro.traffic import (ARRIVAL_PROCESSES, LMDriver, TraceSpec,
                           TrafficHarness, VisionDriver, load_trace,
                           make_trace, save_trace, trace_fingerprint)


def build_driver(engine_kind: str, arch: str, slots: int, seed: int,
                 pipeline_depth: int, quality: str, keep_floor: float,
                 per_token_ms: float):
    """Construct the engine for ``engine_kind`` and wrap it in its
    harness driver."""
    key = jax.random.PRNGKey(seed)
    if engine_kind == "vision":
        from repro.core import packed_runner as PR
        from repro.models import pruning_glue as PG
        cfg = get_config(arch or "deit-small").reduced()
        params = M.init_params(cfg, key)
        scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
        masked = PG.apply_pruning(cfg, params, scores)
        packed = PR.pack_model(cfg, params, scores)
        vc = VisionEngineConfig(max_batch=slots, planner="full",
                                pipeline_depth=pipeline_depth,
                                quality=quality, keep_floor=keep_floor)
        return VisionDriver(VisionEngine(cfg, masked, packed, vc))
    cfg = get_config(arch or "stablelm-1.6b").reduced()
    params = M.init_params(cfg, key)
    ec = EngineConfig(max_batch=slots, max_len=256,
                      pipeline_depth=pipeline_depth)
    return LMDriver(ServeEngine(cfg, params, ec),
                    per_token_ms=per_token_ms)


def default_spec(engine_kind: str, args) -> TraceSpec:
    deadlines = (args.deadline_ms,) if args.deadline_ms else (None,)
    if engine_kind == "vision":
        return TraceSpec(n=args.requests, rate_rps=args.rate,
                         process=args.process, kind="vision",
                         sizes=(16, 9, 4), deadlines_ms=deadlines)
    return TraceSpec(n=args.requests, rate_rps=args.rate,
                     process=args.process, kind="lm",
                     prompt_sizes=(8, 16), max_new_tokens=args.max_new,
                     deadlines_ms=deadlines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("vision", "lm"), default="vision")
    ap.add_argument("--arch", default="",
                    help="config name (default: deit-small for vision, "
                         "stablelm-1.6b for lm)")
    ap.add_argument("--trace", default="",
                    help="replay this JSONL trace (its kind selects "
                         "nothing — pass a matching --engine)")
    ap.add_argument("--save-trace", default="",
                    help="write the (synthesized) trace to this path")
    ap.add_argument("--process", choices=ARRIVAL_PROCESSES,
                    default="bursty")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="offered load, requests per virtual second")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="virtual-clock SLO per request (0 = none)")
    ap.add_argument("--max-new", type=int, default=8,
                    help="lm traces: tokens generated per request")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipeline-depth", type=int, default=1)
    ap.add_argument("--quality", default="strict",
                    choices=("strict", "auto", "degrade"),
                    help="vision QualityController mode; non-strict "
                         "enables the admission controller's degrade arm")
    ap.add_argument("--keep-floor", type=float, default=0.4)
    ap.add_argument("--admission-limit-ms", type=float, default=0.0,
                    help="modeled-backlog budget for the admission "
                         "controller (0 = unbounded admission)")
    ap.add_argument("--per-token-ms", type=float, default=1.0,
                    help="lm virtual-clock price per dispatched token")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Chrome trace_event JSON (Perfetto-"
                         "loadable) of the replay's per-step and "
                         "per-request timelines — VIRTUAL-clock "
                         "timestamps, deterministic and identical at "
                         "every --pipeline-depth")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the replay's metrics-registry snapshot "
                         "(latency/ttfd histograms, admission and "
                         "scheduler counters) to PATH")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    if args.trace:
        trace = load_trace(args.trace)
        if trace.kind != args.engine:
            raise SystemExit(f"trace kind {trace.kind!r} needs "
                             f"--engine {trace.kind}")
    else:
        trace = make_trace(default_spec(args.engine, args), seed=args.seed)
    if args.save_trace:
        save_trace(args.save_trace, trace)

    driver = build_driver(args.engine, args.arch, args.slots, args.seed,
                          args.pipeline_depth, args.quality,
                          args.keep_floor, args.per_token_ms)
    tracer = Tracer() if args.trace_out else None
    metrics = MetricsRegistry() if args.metrics_out else None
    harness = TrafficHarness(
        driver, admission_limit_ms=args.admission_limit_ms or None,
        tracer=tracer, metrics=metrics)
    report = harness.run(trace)
    report["trace_fingerprint"] = trace_fingerprint(trace)
    if args.trace_out:
        tracer.write_chrome_trace(args.trace_out)
    if args.metrics_out:
        metrics.write_json(args.metrics_out)

    if args.json:
        print(json.dumps(report, default=str))
        return
    print(f"trace: {len(trace.requests)} {trace.kind} requests, "
          f"{trace.meta.get('spec', {}).get('process', '?')} arrivals, "
          f"offered {report['offered_rps']:.1f}/s "
          f"(fingerprint {report['trace_fingerprint'][:12]}...)")
    print(f"completed {report['completed']}/{report['offered']} "
          f"(rejected {report['rejected']}) in "
          f"{report['virtual_ms']:.3f} virtual ms -> "
          f"goodput {report['goodput_rps']:.1f}/s")
    print(f"latency p50/p95/p99 = {report['latency_p50_ms']:.3f}/"
          f"{report['latency_p95_ms']:.3f}/"
          f"{report['latency_p99_ms']:.3f} ms, "
          f"ttfd p50 = {report['ttfd_p50_ms']:.3f} ms")
    print(f"deadline miss rate {report['deadline_miss_rate']:.0%} "
          f"({report['deadline_missed']}/{report['deadline_total']}), "
          f"peak queue depth {report['peak_queue_depth']}")
    if "admission" in report:
        a = report["admission"]
        print(f"admission: limit={a['limit_ms']:.4f}ms accepts="
              f"{a['accepts']} degrades={a['degrades']} "
              f"rejects={a['rejects']}")


if __name__ == "__main__":
    main()
