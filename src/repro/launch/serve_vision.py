"""Vision serving launcher: image requests through the VisionEngine
(Scheduler + RaggedBatcher + PackedVitSegments).

    PYTHONPATH=src python -m repro.launch.serve_vision --requests 16 \\
        --slots 4 --mode balanced --policy prune_pressure_aware

Builds the reduced DeiT config, runs the paper's simultaneous pruning
offline (init scores -> hard masks -> SBMM packing), then serves a mixed
stream of image resolutions and per-request token keep rates through the
continuous-batching engine. ``--mode naive`` A/Bs the classic padded batch
against the load-balanced bucketing; ``--policy`` selects the admission
policy shared with the LM path (fifo / shortest_prompt_first /
prune_pressure_aware).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import VisionEngine, VisionEngineConfig, VisionRequest


def make_requests(cfg, num: int, arrival_spread: int, seed: int,
                  r_ts=None, size_weights=None):
    """Synthetic mixed request stream: three image resolutions (full,
    near-full, half side), per-request token keep rates, staggered
    arrivals. Shared by this launcher and benchmarks/vision_bench.py (the
    bench passes a size-skewed ``size_weights``)."""
    rng = np.random.default_rng(seed)
    side = cfg.image_size // cfg.patch_size
    sizes = sorted({max(1, side // 2) ** 2, max(1, side - 1) ** 2,
                    side ** 2})
    if r_ts is None:
        r_ts = [0.5, cfg.pruning.r_t, None]  # None = engine default
    if size_weights is None:
        p = None  # uniform
    else:
        p = np.asarray(size_weights[:len(sizes)], np.float64)
        p = p / p.sum()
    pdim = cfg.patch_size ** 2 * 3
    return [VisionRequest(
        uid=i,
        patches=rng.standard_normal(
            (int(rng.choice(sizes, p=p)), pdim)).astype(np.float32),
        r_t=r_ts[int(rng.integers(len(r_ts)))],
        arrival_step=int(rng.integers(0, arrival_spread + 1)))
        for i in range(num)]


def serve(arch: str = "deit-small", num_requests: int = 16, slots: int = 4,
          mode: str = "balanced", token_tile: int = 1,
          policy: str = "fifo", image_size: int = 0,
          arrival_spread: int = 4, seed: int = 0):
    cfg = get_config(arch).reduced()
    if image_size:
        cfg = cfg.replace(image_size=image_size)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    vc = VisionEngineConfig(max_batch=slots, mode=mode,
                            token_tile=token_tile)
    engine = VisionEngine.from_pruned(cfg, params, scores, vc=vc,
                                      policy=policy)
    reqs = make_requests(cfg, num_requests, arrival_spread, seed)
    t0 = time.time()
    out = engine.serve(reqs)
    dt = time.time() - t0
    return {"outputs": out, "seconds": dt,
            "images_per_s": len(out) / dt,
            "events": list(engine.events),
            "stats": engine.stats()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-small")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--mode", choices=("balanced", "naive"),
                    default="balanced")
    ap.add_argument("--token-tile", type=int, default=1,
                    help="token bucket quantization (1 = exact, bit-exact)")
    ap.add_argument("--policy", default="fifo",
                    help="admission policy: fifo | shortest_prompt_first "
                         "| prune_pressure_aware")
    ap.add_argument("--image-size", type=int, default=0,
                    help="override the reduced config's image size")
    ap.add_argument("--arrival-spread", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="print a machine-readable result line")
    args = ap.parse_args()
    out = serve(args.arch, args.requests, args.slots, args.mode,
                args.token_tile, args.policy, args.image_size,
                args.arrival_spread, args.seed)
    if args.json:
        print(json.dumps({
            "top1": {str(u): int(np.argmax(lg))
                     for u, lg in out["outputs"].items()},
            "images_per_s": out["images_per_s"],
            "stats": out["stats"],
        }))
    else:
        st = out["stats"]
        print(f"served {st['images_served']} images in "
              f"{out['seconds']:.2f}s ({out['images_per_s']:.1f} img/s, "
              f"policy={args.policy}, mode={args.mode})")
        print(f"steps={st['steps']} tiles={st['batcher_tiles']} "
              f"padding_waste={st['batcher_padding_waste']:.1%} "
              f"jit_compiles={st['jit_compile_count']} <= "
              f"buckets={st['bucket_count']}")
        for uid, logits in sorted(out["outputs"].items()):
            print(f"  uid {uid}: top-1 class {int(np.argmax(logits))}")


if __name__ == "__main__":
    main()
