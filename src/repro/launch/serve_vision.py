"""Vision serving launcher: image requests through the VisionEngine
(Scheduler + TilePlanner/RaggedBatcher + PackedVitSegments).

    PYTHONPATH=src python -m repro.launch.serve_vision --requests 16 \\
        --slots 4 --planner full --policy prune_pressure_aware

Builds the reduced DeiT config, runs the paper's simultaneous pruning
offline (init scores -> hard masks -> SBMM packing), then serves a mixed
stream of image resolutions and per-request token keep rates through the
continuous-batching engine. ``--planner`` selects the execution-planning
mode (``off`` = PR 4's identity bucketing, ``merge`` = cost-model bucket
merging, ``fuse`` = express-lane trajectory fusion, ``full`` = both);
``--deadline-ms`` attaches a latency SLO to every request —
deadline-aware tiling is active in every non-``off`` planner mode.
``--mode naive`` A/Bs the classic padded batch against the
load-balanced bucketing; ``--policy`` selects the admission policy shared
with the LM path (fifo / shortest_prompt_first / prune_pressure_aware);
``--quality`` / ``--keep-floor`` turn on the QualityController (graceful
quality degradation: keep rates tighten down a quantized grid under
queue/deadline pressure — ``strict``, the default, is bit-exact off).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.obs import MetricsRegistry, Tracer
from repro.serving import (PLANNER_MODES, VisionEngine, VisionEngineConfig,
                           VisionRequest)


def make_requests(cfg, num: int, arrival_spread: int, seed: int,
                  r_ts=None, size_weights=None, deadline_ms=None,
                  unique_sizes: bool = False):
    """Synthetic mixed request stream: three image resolutions (full,
    near-full, half side), per-request token keep rates, staggered
    arrivals. Shared by this launcher and benchmarks/vision_bench.py (the
    bench passes a size-skewed ``size_weights``; its singleton-heavy
    scenario passes ``unique_sizes`` to draw every patch count distinct so
    no two requests ever share a bucket)."""
    rng = np.random.default_rng(seed)
    side = cfg.image_size // cfg.patch_size
    sizes = sorted({max(1, side // 2) ** 2, max(1, side - 1) ** 2,
                    side ** 2})
    if r_ts is None:
        r_ts = [0.5, cfg.pruning.r_t, None]  # None = engine default
    if size_weights is None:
        p = None  # uniform
    else:
        p = np.asarray(size_weights[:len(sizes)], np.float64)
        p = p / p.sum()
    if unique_sizes:
        lo, hi = max(1, side ** 2 // 4), side ** 2
        pool = rng.permutation(np.arange(lo, hi + 1))
        counts = [int(pool[i % len(pool)]) for i in range(num)]
    else:
        counts = [int(rng.choice(sizes, p=p)) for _ in range(num)]
    pdim = cfg.patch_size ** 2 * 3
    return [VisionRequest(
        uid=i,
        patches=rng.standard_normal((counts[i], pdim)).astype(np.float32),
        r_t=r_ts[int(rng.integers(len(r_ts)))],
        arrival_step=int(rng.integers(0, arrival_spread + 1)),
        deadline_ms=deadline_ms)
        for i in range(num)]


def plan_stats_line(stats) -> str:
    """The end-of-run planner summary shared by the launcher and the
    bench: merges, fused lanes, deadline dispatches, and the modeled
    saving of the plan vs the identity plan."""
    return (f"planner={stats['plan_mode']} merges={stats['plan_merges']} "
            f"fused_lanes={stats['plan_lanes']} "
            f"(segments={stats['plan_fused_segments']}) "
            f"deadline_dispatches={stats['plan_deadline_urgent']} "
            f"splits={stats['plan_deadline_splits']} "
            f"modeled_saving={stats['plan_modeled_saving_ms']:.3f}ms "
            f"({'calibrated' if stats['plan_calibrated'] else 'uncalibrated'}"
            f" cost model)")


def serve(arch: str = "deit-small", num_requests: int = 16, slots: int = 4,
          mode: str = "balanced", token_tile: int = 1,
          policy: str = "fifo", image_size: int = 0,
          arrival_spread: int = 4, seed: int = 0,
          planner: str = "full", deadline_ms: float = 0.0,
          pipeline_depth: int = 1, quality: str = "strict",
          keep_floor: float = 0.4, precision: str = "fp32",
          trace_out: str = "", metrics_out: str = ""):
    cfg = get_config(arch).reduced()
    if image_size:
        cfg = cfg.replace(image_size=image_size)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    if mode == "naive":
        planner = "off"  # naive padding has no buckets to plan over
    vc = VisionEngineConfig(max_batch=slots, mode=mode,
                            token_tile=token_tile, planner=planner,
                            pipeline_depth=pipeline_depth,
                            quality=quality, keep_floor=keep_floor,
                            precision=precision)
    tracer = Tracer() if trace_out else None
    engine = VisionEngine.from_pruned(cfg, params, scores, vc=vc,
                                      policy=policy, tracer=tracer)
    reqs = make_requests(cfg, num_requests, arrival_spread, seed,
                         deadline_ms=deadline_ms or None)
    t0 = time.time()
    out = engine.serve(reqs)
    dt = time.time() - t0
    if trace_out:
        tracer.write_chrome_trace(trace_out)
    if metrics_out:
        engine.export_metrics(MetricsRegistry()).write_json(metrics_out)
    return {"outputs": out, "seconds": dt,
            "images_per_s": len(out) / dt,
            "events": list(engine.events),
            "stats": engine.stats(),
            "quantization": engine.quantization_report()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-small")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--mode", choices=("balanced", "naive"),
                    default="balanced")
    ap.add_argument("--planner", choices=PLANNER_MODES, default="full",
                    help="execution planning: off = identity bucketing, "
                         "merge = cost-model bucket merging, fuse = "
                         "express-lane trajectory fusion, full = both")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="attach a latency SLO (ms from admission) to "
                         "every request; 0 = no deadlines (deadline-aware "
                         "tiling is active in every non-off planner mode)")
    ap.add_argument("--token-tile", type=int, default=1,
                    help="token bucket quantization (1 = exact, bit-exact)")
    ap.add_argument("--policy", default="fifo",
                    help="admission policy: fifo | shortest_prompt_first "
                         "| prune_pressure_aware")
    ap.add_argument("--image-size", type=int, default=0,
                    help="override the reduced config's image size")
    ap.add_argument("--arrival-spread", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="StepPipeline depth: 1 = synchronous stepping "
                         "(the reference path), 2 = stage/plan step N+1 "
                         "while the device executes step N (bit-exact)")
    ap.add_argument("--quality", default="strict",
                    choices=("strict", "auto", "degrade"),
                    help="QualityController mode: strict = off (bit-exact "
                         "with the fixed-keep-rate path), auto = tighten "
                         "keep rates with queue/deadline pressure, "
                         "degrade = shed-load floor for every consenting "
                         "request")
    ap.add_argument("--keep-floor", type=float, default=0.4,
                    help="controller keep-rate floor: no request is ever "
                         "tightened below this, whatever the load")
    ap.add_argument("--precision", default="fp32",
                    choices=("fp32", "fp16", "int8"),
                    help="serving precision tier: fp32 = bit-exact "
                         "reference; fp16/int8 let the planner price each "
                         "request's trajectory at the tier and dispatch "
                         "the dequant-in-kernel variants when strictly "
                         "cheaper (quality=strict requests stay fp32)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Chrome trace_event JSON (Perfetto-"
                         "loadable) of the run's plan/stage/dispatch/"
                         "complete spans to PATH at exit")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the engine's metrics-registry snapshot "
                         "(JSON) to PATH at exit")
    ap.add_argument("--json", action="store_true",
                    help="print a machine-readable result line")
    args = ap.parse_args()
    out = serve(args.arch, args.requests, args.slots, args.mode,
                args.token_tile, args.policy, args.image_size,
                args.arrival_spread, args.seed, args.planner,
                args.deadline_ms, args.pipeline_depth, args.quality,
                args.keep_floor, precision=args.precision,
                trace_out=args.trace_out, metrics_out=args.metrics_out)
    if args.json:
        print(json.dumps({
            "top1": {str(u): int(np.argmax(lg))
                     for u, lg in out["outputs"].items()},
            "images_per_s": out["images_per_s"],
            "stats": out["stats"],
        }, default=str))
    else:
        st = out["stats"]
        print(f"served {st['images_served']} images in "
              f"{out['seconds']:.2f}s ({out['images_per_s']:.1f} img/s, "
              f"policy={args.policy}, mode={args.mode})")
        print(f"steps={st['steps']} tiles={st['batcher_tiles']} "
              f"padding_waste={st['batcher_padding_waste']:.1%} "
              f"jit_compiles={st['jit_compile_count']} <= "
              f"buckets+trajectories={st['compile_budget']}")
        print(plan_stats_line(st))
        q = out["quantization"]
        print(f"precision={st['precision']} "
              f"(granularity={q['granularity']}) "
              f"quant_error={q['quant_max_abs_error']:.5f} "
              f"packed_bytes={q['packed_bytes_fp32']} -> "
              f"{q['packed_bytes']} "
              f"dispatches=" + "/".join(
                  f"{p}:{st[f'dispatch_{p}']}"
                  for p in ("fp32", "fp16", "int8")) +
              f" dequant={st['dequant_dispatches']}")
        if st["quality_mode"] != "strict":
            print(f"quality={st['quality_mode']} "
                  f"floor={st['quality_keep_floor']} tightened="
                  f"{st['quality_tightened']}/{st['quality_decisions']} "
                  f"steps (deadline-driven: "
                  f"{st['quality_deadline_tightened']}) levels_used="
                  f"{st['quality_levels_used']}")
        for uid, logits in sorted(out["outputs"].items()):
            print(f"  uid {uid}: top-1 class {int(np.argmax(logits))}")


if __name__ == "__main__":
    main()
