"""Training launcher: end-to-end driver tying together configs, data,
sharding, the fault-tolerant loop, checkpointing, and (optionally) the
paper's simultaneous pruning.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b \\
        --reduced --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

On a real cluster the same driver runs un-``--reduced`` against the
production mesh; on this CPU container the reduced path is the runnable
end-to-end example (examples/train_lm.py wraps it).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, synthetic_lm_batch
from repro.dist.fault import FaultConfig, RestartableLoop
from repro.models import model as M
from repro.models import steps as ST
from repro.models import pruning_glue as PG
from repro.optim import AdamW


def make_state_factory(cfg, opt, with_scores: bool):
    def make_state():
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        scores = (PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
                  if with_scores else None)
        tr = {"params": params, "scores": scores} if with_scores else params
        return {"params": params, "scores": scores,
                "opt": opt.init(tr), "step": 0}
    return make_state


def train(arch: str, steps: int = 50, batch: int = 8, seq: int = 128,
          lr: float = 1e-3, ckpt_dir: str | None = None, reduced: bool = True,
          checkpoint_every: int = 20, prune: bool = False,
          log_every: int = 10, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if prune:
        pr = cfg.pruning
        cfg = cfg.replace(pruning=pr.__class__(
            block_size=16, r_b=0.5, r_t=1.0, lambda_reg=pr.lambda_reg))
    shape = ShapeConfig("custom", seq_len=seq, global_batch=batch,
                        kind="train")
    opt = AdamW(lr=lr)
    dc = DataConfig(seed=seed)

    if cfg.family == "vit":
        from repro.data import synthetic_vit_batch
        vstep = jax.jit(ST.make_vit_train_step(cfg, opt))

        def step_wrap(state, batch_np):
            b = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, metrics = vstep(state["params"], state["opt"], b)
            return ({"params": params, "scores": None, "opt": opt_state,
                     "step": state["step"] + 1}, metrics)

        data_fn = lambda step: synthetic_vit_batch(cfg, batch, dc, step)
    else:
        step_fn = ST.make_train_step(cfg, opt, with_pruning=prune)
        jstep = jax.jit(step_fn)

        def step_wrap(state, batch_np):
            b = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, scores, opt_state, metrics = jstep(
                state["params"], state["opt"], b, state["scores"])
            return ({"params": params, "scores": scores, "opt": opt_state,
                     "step": state["step"] + 1}, metrics)

        data_fn = partial(synthetic_lm_batch, cfg, shape, dc,
                          local_batch=batch)

    losses = []
    if ckpt_dir:
        loop = RestartableLoop(
            CheckpointManager(ckpt_dir, keep=2),
            FaultConfig(checkpoint_every=checkpoint_every),
            make_state=make_state_factory(cfg, opt, prune),
            step_fn=step_wrap,
            data_fn=lambda s: data_fn(step=s),
            state_to_tree=lambda s: {"params": s["params"],
                                     "opt": s["opt"]},
            tree_to_state=lambda t, s: {**s, **t})
        out = loop.run(steps)
        return out

    state = make_state_factory(cfg, opt, prune)()
    t0 = time.time()
    for i in range(steps):
        state, metrics = step_wrap(state, data_fn(step=i))
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    return {"losses": losses, "state": state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--prune", action="store_true",
                    help="enable the paper's block weight pruning")
    args = ap.parse_args()
    out = train(args.arch, args.steps, args.batch, args.seq, args.lr,
                args.ckpt, args.reduced, prune=args.prune)
    if "losses" in out:
        print(f"final loss: {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
