"""Model zoo: unified builder for all assigned architectures + the paper's ViT."""
from repro.models import layers, attention, moe, ssm, model, steps, pruning_glue

__all__ = ["layers", "attention", "moe", "ssm", "model", "steps",
           "pruning_glue"]
