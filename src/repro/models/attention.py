"""Attention: GQA with RoPE, optional qk-norm, chunked online-softmax
("flash-style") computation, KV caches, and cross-attention.

The chunked path is the memory-roofline-relevant implementation: it never
materializes the ``N×N`` score matrix (peak transient is
``[B, H, q_chunk, k_chunk]``), doubles as the pure-jnp oracle for the Pallas
``flash_attention`` kernel, and is what the dry-run lowers on the CPU host
platform (the Pallas kernel is selected on real TPU backends).
"""
from __future__ import annotations

import contextlib
import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, linear, rms_norm

NEG_INF = -1e30

# Cost-probe mode (see launch/dryrun.py): when True, the chunked attention
# uses Python loops with ≤4 chunks per axis so the lowered HLO has no while
# loops and XLA's cost_analysis counts every FLOP. Tracing is synchronous,
# so a module flag is safe.
_UNROLL = False


@contextlib.contextmanager
def unroll_mode(on: bool = True):
    global _UNROLL
    old = _UNROLL
    _UNROLL = on
    try:
        yield
    finally:
        _UNROLL = old


class KVCache(NamedTuple):
    k: jax.Array          # [B, S_max, KV, Dh]
    v: jax.Array          # [B, S_max, KV, Dh]
    length: jax.Array     # [B] int32 — tokens currently valid, PER SLOT
    # beyond-paper dynamic KV pruning: attention mass accumulated per slot
    attn_mass: jax.Array  # [B, S_max] float32


def init_kv_cache(batch: int, max_len: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
        attn_mass=jnp.zeros((batch, max_len), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (pure jnp, O(N) memory)
# ---------------------------------------------------------------------------
def _largest_divisor_leq(n: int, cap: int) -> int:
    for c in range(cap, 0, -1):
        if n % c == 0:
            return c
    return 1
def _attend_chunk(q, k, v, mask, scale):
    """q:[B,G,Hq,qc,Dh] k:[B,G,kc,Dh] v:[B,G,kc,Dh] mask:[qc,kc] or None."""
    s = jnp.einsum("bghqd,bgkd->bghqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bghqk,bgkd->bghqd", p, v.astype(jnp.float32))
    return o, m, l


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        q_offset: int | jax.Array = 0,
                        kv_len: Optional[jax.Array] = None,
                        kv_start: Optional[jax.Array] = None,
                        q_chunk: int = 512, k_chunk: int = 512,
                        scale: Optional[float] = None) -> jax.Array:
    """Grouped-query chunked attention.

    q: [B, Nq, Hq, Dh]; k, v: [B, Nk, KV, Dh]; Hq = G·KV groups.
    ``q_offset`` is the cache-slot index of q[0] (decode); scalar or per-row
    ``[B]`` (per-slot serving, where every row decodes at its own length).
    ``kv_len`` (scalar or ``[B]``) masks cache slots >= kv_len; ``kv_start``
    ([B] int32) masks slots < kv_start per batch row (left-padded prompts /
    compacted-cache garbage prefixes). Returns [B, Nq, Hq, Dh] in q.dtype.
    """
    B, Nq, Hq, Dh = q.shape
    _, Nk, KV, _ = k.shape
    G = KV
    per = Hq // KV
    if scale is None:
        scale = Dh ** -0.5

    Nq_orig = Nq
    if _UNROLL:  # cost-probe mode: ≤4 chunks per axis, loop-free HLO.
        # Pad (instead of searching divisors — prime N like the 1601 vision
        # tokens would otherwise degrade to chunk=1 and trace N bodies).
        q_chunk = max(math.ceil(Nq / 4), 1)
        k_chunk = max(math.ceil(Nk / 4), 1)
        q_pad = (-Nq) % q_chunk
        k_pad = (-Nk) % k_chunk
        if k_pad:
            if kv_len is None:
                kv_len = jnp.int32(Nk)
            k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
            Nk += k_pad
        if q_pad:
            q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
            Nq += q_pad
    else:
        q_chunk = _largest_divisor_leq(Nq, min(q_chunk, Nq))
        k_chunk = _largest_divisor_leq(Nk, min(k_chunk, Nk))
    nq, nk = Nq // q_chunk, Nk // k_chunk

    qr = q.reshape(B, nq, q_chunk, G, per, Dh).transpose(1, 0, 3, 4, 2, 5)
    # qr: [nq, B, G, per, qc, Dh]
    kr = k.reshape(B, nk, k_chunk, G, Dh).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, k_chunk, G, Dh).transpose(1, 0, 3, 2, 4)
    # kr/vr: [nk, B, G, kc, Dh]

    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def per_q_chunk(qi, qc_data):
        # q_pos: [qc] (scalar offset) or [B, qc] (per-row offsets)
        q_pos = q_pos_base[..., None] + qi * q_chunk + jnp.arange(q_chunk)

        def body(carry, kc_pack):
            o, m, l = carry
            ki, kc_data, vc_data = kc_pack
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            # mask broadcasts over [(B,) qc, kc]: any of q_offset / kv_len /
            # kv_start may be per-row [B] (per-slot serving) or scalar
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask = mask & (q_pos[..., :, None] >= k_pos)
            if kv_len is not None:
                lrow = k_pos < jnp.asarray(kv_len, jnp.int32)[..., None]
                mask = mask & (lrow if lrow.ndim == 1 else lrow[:, None, :])
            if kv_start is not None:
                srow = k_pos >= jnp.asarray(kv_start, jnp.int32)[..., None]
                mask = mask & (srow if srow.ndim == 1 else srow[:, None, :])
            if mask.ndim == 3:  # per-row: [B, qc, kc] -> [B, 1(g), 1(h), ...]
                mask = mask[:, None, None]
            s = jnp.einsum("bghqd,bgkd->bghqk", qc_data.astype(jnp.float32),
                           kc_data.astype(jnp.float32)) * scale
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            o = o * corr[..., None] + jnp.einsum(
                "bghqk,bgkd->bghqd", p, vc_data.astype(jnp.float32))
            return (o, m_new, l), None

        o0 = jnp.zeros((B, G, per, q_chunk, Dh), jnp.float32)
        m0 = jnp.full((B, G, per, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, per, q_chunk), jnp.float32)
        if _UNROLL:
            carry = (o0, m0, l0)
            for ki in range(nk):
                carry, _ = body(carry, (jnp.asarray(ki), kr[ki], vr[ki]))
            o, m, l = carry
        else:
            (o, m, l), _ = jax.lax.scan(
                body, (o0, m0, l0), (jnp.arange(nk), kr, vr))
        return o / jnp.maximum(l[..., None], 1e-30)

    if _UNROLL:
        out = jnp.stack([per_q_chunk(jnp.asarray(i), qr[i])
                         for i in range(nq)])
    else:
        out = jax.lax.map(lambda pack: per_q_chunk(pack[0], pack[1]),
                          (jnp.arange(nq), qr))
    # out: [nq, B, G, per, qc, Dh] -> [B, Nq, Hq, Dh]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Nq, Hq, Dh)
    if Nq != Nq_orig:
        out = out[:, :Nq_orig]
    return out.astype(q.dtype)


def attention_probs_row(q_row: jax.Array, k: jax.Array,
                        kv_len: Optional[jax.Array] = None,
                        kv_start: Optional[jax.Array] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """Softmax attention of ONE query row against all keys, per head —
    exactly what the TDM scoring needs (CLS row for ViT, last row for LM
    prefill) without materializing the full ``A`` matrix.

    q_row: [B, Hq, Dh]; k: [B, Nk, KV, Dh]. ``kv_len`` (scalar or per-row
    ``[B]``) masks cache slots >= kv_len; ``kv_start`` ([B]) masks cache
    slots < kv_start per batch row so left-padding accumulates zero
    attention mass. Returns probs [B, Hq, Nk].
    """
    B, Nk, KV, Dh = k.shape
    Hq = q_row.shape[1]
    per = Hq // KV
    if scale is None:
        scale = Dh ** -0.5
    qg = q_row.reshape(B, KV, per, Dh).astype(jnp.float32)
    s = jnp.einsum("bgpd,bkgd->bgpk", qg, k.astype(jnp.float32)) * scale
    pos = jnp.arange(Nk)
    if kv_len is not None:
        lrow = pos[None, :] < jnp.reshape(jnp.asarray(kv_len), (-1, 1))
        s = jnp.where(lrow[:, None, None, :], s, NEG_INF)  # [B|1, 1, 1, Nk]
    if kv_start is not None:
        row = pos[None, :] >= kv_start[:, None]  # [B, Nk]
        s = jnp.where(row[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p.reshape(B, Hq, Nk)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------
def attention_block(x: jax.Array, p, cfg, *, causal: bool,
                    cache: Optional[KVCache] = None,
                    positions: Optional[jax.Array] = None,
                    collect_scores: bool = False,
                    score_row: int = 0,
                    use_rope: bool = True,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    valid_start: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Optional[KVCache], Optional[jax.Array]]:
    """One attention sublayer. Returns (out, new_cache, tdm_scores).

    * training/prefill: ``cache is None`` or appended-to.
    * decode: x is [B, 1, D]; cache holds the past.
    * cross-attention: pass ``kv_override=(k, v)`` (already projected
      encoder keys/values) — used by whisper decoder + VLM image layers.
    * ``valid_start`` ([B] int32): first real position per batch row —
      earlier slots (left-padded prompts, compacted-cache garbage prefixes)
      are masked out of the attention and of the ``attn_mass`` accumulation
      that drives dynamic KV pruning. RoPE positions for masked rows count
      *real* tokens (cache slot − valid_start), so a row's rotary phases are
      independent of where its tokens sit in the cache buffer — per-slot
      prefill and left-padded batch prefill rope identically.

    ``cache.length`` is per-slot (``[B]``): each row reads/writes the cache
    at its own length, so one slot can be prefilled while others decode.
    """
    B, N, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    if "wqkv" in p and kv_override is None:
        qkv = linear(x, p["wqkv"])  # one matmul: fewer activation gathers
        q, k, v = jnp.split(qkv, [H * Dh, (H + KV) * Dh], axis=-1)
        q = q.reshape(B, N, H, Dh)
        k = k.reshape(B, N, KV, Dh)
        v = v.reshape(B, N, KV, Dh)
    else:
        q = linear(x, p["wq"], p.get("bq")).reshape(B, N, H, Dh)
        if kv_override is None:
            k = linear(x, p["wk"], p.get("bk")).reshape(B, N, KV, Dh)
            v = linear(x, p["wv"], p.get("bv")).reshape(B, N, KV, Dh)
        else:
            k, v = kv_override

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if kv_override is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    # per-slot write offsets: [B] cache-slot index of this call's first token
    slot_off = None
    if cache is not None and kv_override is None:
        slot_off = jnp.broadcast_to(jnp.asarray(cache.length, jnp.int32), (B,))
    if positions is None:
        if slot_off is not None:
            base = (slot_off - valid_start) if valid_start is not None \
                else slot_off  # rope counts real tokens, not buffer slots
            positions = base[:, None] + jnp.arange(N)
        else:
            positions = jnp.broadcast_to(jnp.arange(N), (B, N))
    if use_rope and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    tdm_scores = None
    if cache is not None and kv_override is None:
        # write new k/v at each row's own [length_b, length_b + N)
        put_row = lambda dst, src, start: jax.lax.dynamic_update_slice(
            dst, src, (start, 0, 0))
        k_all = jax.vmap(put_row)(cache.k, k.astype(cache.k.dtype), slot_off)
        v_all = jax.vmap(put_row)(cache.v, v.astype(cache.v.dtype), slot_off)
        new_len = slot_off + N
        out = flash_attention_jnp(
            q, k_all, v_all, causal=causal, q_offset=slot_off,
            kv_len=new_len, kv_start=valid_start,
            q_chunk=min(512, N), k_chunk=min(512, k_all.shape[1]))
        # accumulate attention mass for dynamic KV pruning (decode only)
        mass = cache.attn_mass
        if N == 1:
            probs = attention_probs_row(q[:, 0], k_all, kv_len=new_len,
                                        kv_start=valid_start)
            mass = mass + probs.mean(axis=1)
        new_cache = KVCache(k_all, v_all, new_len, mass)
    else:
        kv_len = None
        out = flash_attention_jnp(
            q, k, v, causal=causal and kv_override is None,
            kv_start=valid_start if kv_override is None else None,
            q_chunk=min(512, N), k_chunk=min(512, k.shape[1]))

    if collect_scores:
        probs = attention_probs_row(q[:, score_row], k, None)
        tdm_scores = probs.mean(axis=1)  # [B, Nk]

    out = out.reshape(B, N, H * Dh)
    out = linear(out, p["wo"], p.get("bo"))
    return out, new_cache, tdm_scores
