"""Shared neural-net layers (pure functions over param pytrees).

Conventions
-----------
* Params are nested dicts of ``jnp.ndarray``; per-layer params are *stacked*
  along a leading layer axis and consumed with ``jax.lax.scan`` so deep
  models compile one layer body (MaxText-style).
* All matmul weights are stored ``[in, out]``.
* Prunable weights get masked *before* the forward (see
  ``repro.models.pruning_glue``); layers themselves are pruning-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., N, H, Dh]; positions: broadcastable to [..., N]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., N, Dh/2]
    angles = angles[..., None, :]  # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------
def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def glu_mlp(x: jax.Array, p) -> jax.Array:
    """SwiGLU feed-forward: (silu(x·wg) ⊙ (x·wi)) · wo."""
    g = jax.nn.silu(linear(x, p["wg"]))
    u = linear(x, p["wi"])
    return linear(g * u, p["wo"])


def gelu_mlp(x: jax.Array, p) -> jax.Array:
    """Classic transformer FFN (ViT / whisper): gelu(x·wi + bi)·wo + bo."""
    h = jax.nn.gelu(linear(x, p["wi"], p.get("bi")), approximate=True)
    return linear(h, p["wo"], p.get("bo"))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jax.Array:
    scale = (2.0 / (in_dim + out_dim)) ** 0.5
    return scale * jax.random.normal(key, (in_dim, out_dim), dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return 0.02 * jax.random.normal(key, (vocab, dim), dtype)


def stack_init(key, n: int, fn, *args, **kw):
    """Stack ``n`` independent inits along a leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, *args, **kw))(keys)
