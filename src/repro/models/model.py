"""Unified model builder for every assigned architecture family.

Families and their layer plans:

  dense   — embed → scan[attn + SwiGLU] → norm → unembed
  moe     — embed → scan[attn + MoE-FFN(+shared)] → norm → unembed
  vlm     — embed → scan over stages[(period−1)·self + 1·cross(vision)] → ...
  audio   — frames → scan[enc self-attn] ; tokens → scan[dec self + cross]
  hybrid  — embed → scan over stages[period·mamba2] + shared-attn block → ...
  zamba2-style trailing mamba layers handled as a second scan
  ssm     — embed → scan[rwkv6 block] → norm → unembed
  vit     — patch embed → Python loop[encoder (+TDM at cfg layers)] → head

Per-layer params are stacked on a leading axis; deep stacks compile one scan
body. Forward signatures support three modes:
  "train"   — full sequence, no cache
  "prefill" — full sequence, returns serve caches
  "decode"  — one token per call against caches

The paper's static weight pruning is applied by masking the stacked weights
*before* the forward (``repro.models.pruning_glue``); the TDM (dynamic token
pruning) lives in the ViT loop and the LM prefill loop path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.core import token_pruning as TP


# ===========================================================================
# Parameter init
# ===========================================================================
def _attn_params(key, cfg: ModelConfig, dtype, kv_from: int | None = None):
    d = kv_from or cfg.d_model
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    if cfg.fuse_qkv and kv_from is None:
        p = {
            "wqkv": L.dense_init(ks[0], cfg.d_model, (H + 2 * KV) * Dh, dtype),
            "wo": L.dense_init(ks[3], H * Dh, cfg.d_model, dtype),
        }
    else:
        p = {
            "wq": L.dense_init(ks[0], cfg.d_model, H * Dh, dtype),
            "wk": L.dense_init(ks[1], d, KV * Dh, dtype),
            "wv": L.dense_init(ks[2], d, KV * Dh, dtype),
            "wo": L.dense_init(ks[3], H * Dh, cfg.d_model, dtype),
        }
    if cfg.use_bias:
        p.update(bq=jnp.zeros((H * Dh,), dtype), bk=jnp.zeros((KV * Dh,), dtype),
                 bv=jnp.zeros((KV * Dh,), dtype), bo=jnp.zeros((cfg.d_model,), dtype))
    if cfg.qk_norm:
        p.update(q_norm=jnp.ones((Dh,), dtype), k_norm=jnp.ones((Dh,), dtype))
    return p


def _mlp_params(key, cfg: ModelConfig, dtype, glu: bool = True,
                d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if glu:
        return {"wg": L.dense_init(ks[0], d, ff, dtype),
                "wi": L.dense_init(ks[1], d, ff, dtype),
                "wo": L.dense_init(ks[2], ff, d, dtype)}
    p = {"wi": L.dense_init(ks[0], d, ff, dtype),
         "wo": L.dense_init(ks[1], ff, d, dtype)}
    if cfg.use_bias:
        p.update(bi=jnp.zeros((ff,), dtype), bo=jnp.zeros((d,), dtype))
    return p


def _decoder_layer(key, cfg, dtype, glu=True):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": _attn_params(k1, cfg, dtype),
            "mlp": _mlp_params(k2, cfg, dtype, glu)}


def _stacked(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Build the full parameter pytree for ``cfg`` (fp32 by default).

    For the dry-run this is only ever called under ``jax.eval_shape``."""
    dtype = jnp.dtype(cfg.param_dtype)
    fam = cfg.family
    ks = jax.random.split(key, 8)
    if fam == "vit":
        return _init_vit(cfg, key, dtype)

    p: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(ks[1], cfg.d_model, cfg.vocab_size, dtype)

    if fam in ("dense",):
        p["layers"] = _stacked(ks[2], cfg.num_layers,
                               lambda k: _decoder_layer(k, cfg, dtype))
    elif fam == "moe":
        def layer(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": jnp.ones((cfg.d_model,), dtype),
                    "ln2": jnp.ones((cfg.d_model,), dtype),
                    "attn": _attn_params(k1, cfg, dtype),
                    "moe": MOE.init_moe_params(k2, cfg, dtype)}
        p["layers"] = _stacked(ks[2], cfg.num_layers, layer)
    elif fam == "vlm":
        period = cfg.cross_attn_period
        n_stages = cfg.num_layers // period
        n_self = period - 1

        def self_stage(k):
            return _stacked(k, n_self, lambda kk: _decoder_layer(kk, cfg, dtype))

        def cross_layer(k):
            k1, k2 = jax.random.split(k)
            lay = {"ln1": jnp.ones((cfg.d_model,), dtype),
                   "ln2": jnp.ones((cfg.d_model,), dtype),
                   "attn": _attn_params(k1, cfg, dtype,
                                        kv_from=cfg.vision_d_model or cfg.d_model),
                   "mlp": _mlp_params(k2, cfg, dtype, glu=True),
                   "gate": jnp.zeros((), dtype)}
            return lay

        p["stages"] = {
            "self": _stacked(ks[2], n_stages, self_stage),
            "cross": _stacked(ks[3], n_stages, cross_layer),
        }
    elif fam == "audio":
        p["enc_layers"] = _stacked(
            ks[2], cfg.encoder_layers,
            lambda k: _decoder_layer(k, cfg, dtype, glu=False))
        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": jnp.ones((cfg.d_model,), dtype),
                    "ln_x": jnp.ones((cfg.d_model,), dtype),
                    "ln2": jnp.ones((cfg.d_model,), dtype),
                    "attn": _attn_params(k1, cfg, dtype),
                    "xattn": _attn_params(k2, cfg, dtype),
                    "mlp": _mlp_params(k3, cfg, dtype, glu=False)}
        p["layers"] = _stacked(ks[3], cfg.num_layers, dec_layer)
        p["enc_ln_f"] = jnp.ones((cfg.d_model,), dtype)
        p["enc_pos"] = 0.02 * jax.random.normal(
            ks[4], (cfg.num_audio_frames, cfg.d_model), dtype)
    elif fam == "hybrid":
        period = cfg.attn_layer_period
        n_stages = cfg.num_layers // period
        rem = cfg.num_layers - n_stages * period

        def mamba_stage(k):
            return _stacked(k, period,
                            lambda kk: {"ln": jnp.ones((cfg.d_model,), dtype),
                                        "mamba": SSM.init_mamba_params(kk, cfg, dtype)})
        p["stages"] = _stacked(ks[2], n_stages, mamba_stage)
        p["shared_attn"] = {"ln1": jnp.ones((cfg.d_model,), dtype),
                            "ln2": jnp.ones((cfg.d_model,), dtype),
                            "attn": _attn_params(ks[3], cfg, dtype),
                            "mlp": _mlp_params(ks[4], cfg, dtype, glu=True)}
        if rem:
            p["tail"] = _stacked(
                ks[5], rem,
                lambda kk: {"ln": jnp.ones((cfg.d_model,), dtype),
                            "mamba": SSM.init_mamba_params(kk, cfg, dtype)})
    elif fam == "ssm":
        p["layers"] = _stacked(ks[2], cfg.num_layers,
                               lambda k: SSM.init_rwkv_params(k, cfg, dtype))
    else:
        raise ValueError(fam)
    return p


def _init_vit(cfg: ModelConfig, key, dtype) -> Dict:
    n_patches = (cfg.image_size // cfg.patch_size) ** 2
    patch_dim = cfg.patch_size ** 2 * 3
    ks = jax.random.split(key, cfg.num_layers + 4)
    return {
        "patch_embed": L.dense_init(ks[0], patch_dim, cfg.d_model, dtype),
        "patch_bias": jnp.zeros((cfg.d_model,), dtype),
        "cls": 0.02 * jax.random.normal(ks[1], (1, 1, cfg.d_model), dtype),
        "pos": 0.02 * jax.random.normal(ks[2], (n_patches + 1, cfg.d_model), dtype),
        "layers": [
            {"ln1_s": jnp.ones((cfg.d_model,), dtype),
             "ln1_b": jnp.zeros((cfg.d_model,), dtype),
             "ln2_s": jnp.ones((cfg.d_model,), dtype),
             "ln2_b": jnp.zeros((cfg.d_model,), dtype),
             "attn": _attn_params(ks[3 + i], cfg, dtype),
             "mlp": _mlp_params(jax.random.fold_in(ks[3 + i], 1), cfg, dtype,
                                glu=False)}
            for i in range(cfg.num_layers)
        ],
        "ln_f_s": jnp.ones((cfg.d_model,), dtype),
        "ln_f_b": jnp.zeros((cfg.d_model,), dtype),
        "head": L.dense_init(ks[-1], cfg.d_model, cfg.num_classes, dtype),
    }


# ===========================================================================
# Forward passes
# ===========================================================================
def _self_layer_fwd(x, lp, cfg, *, causal=True, cache=None, glu=True,
                    valid_start=None):
    h, new_cache, _ = A.attention_block(
        L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
        causal=causal, cache=cache, valid_start=valid_start)
    x = x + h
    mlp = L.glu_mlp if glu else L.gelu_mlp
    x = x + mlp(L.rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
    return x, new_cache


def _moe_layer_fwd(x, lp, cfg, cache=None, valid_start=None):
    h, new_cache, _ = A.attention_block(
        L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
        causal=True, cache=cache, valid_start=valid_start)
    x = x + h
    y, aux = MOE.moe_ffn(L.rms_norm(x, lp["ln2"], cfg.norm_eps), lp["moe"], cfg)
    return x + y, new_cache, aux


def _cross_layer_fwd(x, lp, cfg, vis_kv, cache_unused=None):
    """Gated cross-attention layer (k/v projected from vision tokens)."""
    B, Nv, _ = vis_kv.shape
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    k = L.linear(vis_kv, lp["attn"]["wk"]).reshape(B, Nv, KV, Dh)
    v = L.linear(vis_kv, lp["attn"]["wv"]).reshape(B, Nv, KV, Dh)
    h, _, _ = A.attention_block(
        L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
        causal=False, kv_override=(k, v), use_rope=False)
    x = x + jnp.tanh(lp["gate"]).astype(x.dtype) * h
    x = x + L.glu_mlp(L.rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
    return x


class Output(NamedTuple):
    logits: jax.Array
    caches: Any = None
    aux_loss: jax.Array | float = 0.0
    hidden: Optional[jax.Array] = None


def unembed_matrix(cfg: ModelConfig, params: Dict) -> jax.Array:
    w = params.get("unembed", None)
    return w if w is not None else params["embed"].T


def _remat(cfg, body):
    """Apply the configured activation-checkpoint policy to a scan body."""
    pol = cfg.remat_policy
    if pol == "none":
        return body
    if pol == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _unrolled_scan(fn, carry, xs):
    """Python-loop scan substitute: produces while-free HLO so
    ``cost_analysis`` counts every layer (the dry-run's cost probes)."""
    L = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = fn(carry, x_i)
        ys.append(y)
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


def forward_lm(cfg: ModelConfig, params: Dict, tokens: jax.Array,
               mode: str = "train", caches: Any = None,
               vision_embeds: Optional[jax.Array] = None,
               audio_frames: Optional[jax.Array] = None,
               remat: bool = True, logits_for: str = "all",
               unroll: bool = False,
               valid_start: Optional[jax.Array] = None) -> Output:
    """Language-model forward for all non-ViT families.

    ``logits_for``: "all" materializes [B, N, V] logits; "last" computes
    only the final position (prefill path — avoids a [B, S, V] tensor);
    "none" returns hidden states only (the chunked-loss training path).
    ``unroll``: replace layer/attention scans with Python loops so the HLO
    is while-free (the dry-run's exact cost probes).
    ``valid_start`` ([B] int32): per-row index of the first real token —
    earlier (left-padded) positions are masked out of every self-attention
    and out of the KV attn_mass accumulation. Only attention-backed
    families honor it; recurrent state (ssm/hybrid mamba) cannot mask
    already-absorbed pad tokens, so serve those families unpadded."""
    with A.unroll_mode(unroll):
        return _forward_lm_impl(cfg, params, tokens, mode, caches,
                                vision_embeds, audio_frames, remat,
                                logits_for, unroll, valid_start)


def _forward_lm_impl(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                     mode: str, caches: Any,
                     vision_embeds: Optional[jax.Array],
                     audio_frames: Optional[jax.Array],
                     remat: bool, logits_for: str,
                     unroll: bool,
                     valid_start: Optional[jax.Array] = None) -> Output:
    fam = cfg.family
    adt = jnp.dtype(cfg.dtype)
    scan = _unrolled_scan if unroll else jax.lax.scan
    x = params["embed"][tokens].astype(adt)
    aux_total = jnp.float32(0.0)
    new_caches = None
    want_cache = mode in ("prefill", "decode")

    if fam in ("dense", "moe"):
        def body(carry, xs):
            x = carry
            lp, cache = xs
            cache = _as_cache(cache)
            if fam == "dense":
                x, nc = _self_layer_fwd(x, lp, cfg, causal=True, cache=cache,
                                        valid_start=valid_start)
                return x, (nc if nc is not None else jnp.zeros((0,)),
                           jnp.float32(0.0))
            x, nc, aux = _moe_layer_fwd(x, lp, cfg, cache=cache,
                                        valid_start=valid_start)
            return x, (nc if nc is not None else jnp.zeros((0,)), aux)

        if mode == "train":
            caches_in = _none_caches(cfg.num_layers)
            fn = _remat(cfg, body) if remat else body
        else:
            caches_in = caches
            fn = body
        x, (new_caches, auxs) = scan(
            fn, x, (params["layers"], caches_in))
        aux_total = auxs.sum()

    elif fam == "vlm":
        assert vision_embeds is not None
        vis = vision_embeds.astype(adt)

        def stage(carry, xs):
            x = carry
            sp, cache = xs

            def inner(c2, xs2):
                lp, lc = xs2
                lc = _as_cache(lc)
                y, nc = _self_layer_fwd(c2, lp, cfg, causal=True, cache=lc,
                                        valid_start=valid_start)
                return y, nc if nc is not None else jnp.zeros((0,))
            x, ncs = scan(inner, x, (sp["self"], cache))
            x = _cross_layer_fwd(x, sp["cross"], cfg, vis)
            return x, ncs

        n_stages = cfg.num_layers // cfg.cross_attn_period
        n_self = cfg.cross_attn_period - 1
        if mode == "train":
            caches_in = _none_caches((n_stages, n_self))
            fn = _remat(cfg, stage) if remat else stage
        else:
            caches_in = caches
            fn = stage
        x, new_caches = scan(fn, x, (params["stages"], caches_in))

    elif fam == "audio":
        if mode == "decode" and isinstance(caches, tuple):
            caches, enc = caches  # encoder output cached at prefill
        else:
            assert audio_frames is not None
            pos_tab = params["enc_pos"]
            nf = audio_frames.shape[1]
            if nf <= pos_tab.shape[0]:
                pos = pos_tab[None, :nf]
            else:  # longer-than-table stub inputs: tile the table
                reps = -(-nf // pos_tab.shape[0])
                pos = jnp.tile(pos_tab, (reps, 1))[None, :nf]
            enc = audio_frames.astype(adt) + pos.astype(adt)

            def enc_body(carry, lp):
                y, _ = _self_layer_fwd(carry, lp, cfg, causal=False, glu=False)
                return y, None
            enc, _ = scan(enc_body, enc, params["enc_layers"])
            enc = L.rms_norm(enc, params["enc_ln_f"], cfg.norm_eps)

        B, Nf, _ = enc.shape
        KV, Dh = cfg.num_kv_heads, cfg.head_dim

        def dec_body(carry, xs):
            x = carry
            lp, cache = xs
            cache = _as_cache(cache)
            h, nc, _ = A.attention_block(
                L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
                causal=True, cache=cache, valid_start=valid_start)
            x = x + h
            k = L.linear(enc, lp["xattn"]["wk"]).reshape(B, Nf, KV, Dh)
            v = L.linear(enc, lp["xattn"]["wv"]).reshape(B, Nf, KV, Dh)
            h, _, _ = A.attention_block(
                L.rms_norm(x, lp["ln_x"], cfg.norm_eps), lp["xattn"], cfg,
                causal=False, kv_override=(k, v), use_rope=False)
            x = x + h
            x = x + L.gelu_mlp(L.rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
            return x, nc if nc is not None else jnp.zeros((0,))

        caches_in = caches if want_cache else _none_caches(cfg.num_layers)
        fn = dec_body if (want_cache or not remat) else _remat(cfg, dec_body)
        x, new_caches = scan(fn, x, (params["layers"], caches_in))
        if want_cache:
            new_caches = (new_caches, enc)

    elif fam == "hybrid":
        x, new_caches, aux_total = _forward_hybrid(cfg, params, x, mode,
                                                   caches, scan)

    elif fam == "ssm":
        def body(carry, xs):
            x = carry
            lp, st = xs
            x, new_st = SSM.rwkv_block(x, lp, cfg, st)
            return x, new_st

        states_in = caches if caches is not None else jax.vmap(
            lambda _: SSM.init_rwkv_state(x.shape[0], cfg, adt))(
                jnp.arange(cfg.num_layers))
        fn = body if mode != "train" else (_remat(cfg, body) if remat else body)
        x, new_caches = scan(fn, x, (params["layers"], states_in))

    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    if logits_for == "none":
        return Output(None, new_caches, aux_total, hidden=x)
    w_un = unembed_matrix(cfg, params)
    if logits_for == "last":
        logits = jnp.einsum("bd,dv->bv", x[:, -1], w_un.astype(adt))[:, None]
    else:
        logits = jnp.einsum("bnd,dv->bnv", x, w_un.astype(adt))
    return Output(logits.astype(jnp.float32), new_caches, aux_total, hidden=x)


def _forward_hybrid(cfg, params, x, mode, caches, scan=jax.lax.scan):
    """zamba2: stages of ``period`` mamba layers + one shared attn block."""
    adt = x.dtype
    period = cfg.attn_layer_period
    n_stages = cfg.num_layers // period
    rem = cfg.num_layers - n_stages * period
    B = x.shape[0]

    if caches is None:
        mamba_states = jax.vmap(
            lambda _: jax.vmap(lambda __: SSM.init_mamba_state(B, cfg, adt))(
                jnp.arange(period)))(jnp.arange(n_stages))
        tail_states = (jax.vmap(lambda _: SSM.init_mamba_state(B, cfg, adt))(
            jnp.arange(rem)) if rem else None)
        attn_caches = None  # train mode: no KV cache
    else:
        mamba_states, tail_states, attn_caches = caches

    sp_shared = params["shared_attn"]

    def stage(carry, xs):
        x = carry
        sp, states, acache = xs
        acache = _as_cache(acache)

        def inner(c2, xs2):
            lp, st = xs2
            y, new_st = SSM.mamba_block(
                L.rms_norm(c2, lp["ln"], cfg.norm_eps), lp["mamba"], cfg, st)
            return c2 + y, new_st
        x, new_states = scan(inner, x, (sp, states))
        x, new_acache = _self_layer_fwd(x, sp_shared, cfg, causal=True,
                                        cache=acache)
        return x, (new_states, new_acache)

    acaches_in = attn_caches if attn_caches is not None else _none_caches(n_stages)
    x, (new_mamba, new_attn) = scan(
        stage, x, (params["stages"], mamba_states, acaches_in))

    new_tail = None
    if rem:
        def tail_body(c2, xs2):
            lp, st = xs2
            y, new_st = SSM.mamba_block(
                L.rms_norm(c2, lp["ln"], cfg.norm_eps), lp["mamba"], cfg, st)
            return c2 + y, new_st
        x, new_tail = scan(tail_body, x, (params["tail"], tail_states))

    return x, (new_mamba, new_tail, new_attn), jnp.float32(0.0)


def _none_caches(shape):
    """Scan-compatible 'no cache' placeholder: scan xs need a leading axis,
    so 'no cache' is a zero-width marker array that ``_as_cache`` maps back
    to None inside the scan body (shapes are static, so this is free)."""
    if isinstance(shape, tuple):
        return jnp.zeros(shape + (0,))  # nested per-stage marker
    return jnp.zeros((shape, 0))


def _as_cache(c):
    if c is None:
        return None
    if isinstance(c, jnp.ndarray) and c.ndim >= 1 and c.shape[-1] == 0:
        return None
    return c


# ===========================================================================
# ViT forward (the paper's model) — Python loop, supports TDM
# ===========================================================================
def patchify(images: jax.Array, patch: int) -> jax.Array:
    """images: [B, H, W, 3] -> [B, N, patch*patch*3]."""
    B, H, W, C = images.shape
    ph, pw = H // patch, W // patch
    x = images.reshape(B, ph, patch, pw, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, ph * pw, patch * patch * C)
    return x


def forward_vit(cfg: ModelConfig, params: Dict, patches: jax.Array,
                use_tdm: Optional[bool] = None) -> Output:
    """patches: [B, N, P²·3] (pre-patchified; use ``patchify`` on images).

    Applies the TDM at ``cfg.pruning.tdm_layers`` when token pruning is
    enabled — token counts shrink statically layer by layer."""
    p = cfg.pruning
    if use_tdm is None:
        use_tdm = p.token_pruning_enabled
    adt = jnp.dtype(cfg.dtype)

    x = L.linear(patches.astype(adt), params["patch_embed"],
                 params["patch_bias"])
    B, N, D = x.shape
    cls = jnp.broadcast_to(params["cls"].astype(adt), (B, 1, D))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + params["pos"][None, : N + 1].astype(adt)

    for i, lp in enumerate(params["layers"]):
        has_tdm = use_tdm and (i in p.tdm_layers)
        h = L.layer_norm(x, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        h, _, tdm_scores = A.attention_block(
            h, lp["attn"], cfg, causal=False, use_rope=False,
            collect_scores=has_tdm, score_row=0)
        x = x + h
        if has_tdm:
            x, _ = TP.tdm(x, tdm_scores, p.r_t, has_cls=True)
        h = L.layer_norm(x, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["mlp"])

    x = L.layer_norm(x, params["ln_f_s"], params["ln_f_b"], cfg.norm_eps)
    logits = L.linear(x[:, 0], params["head"])
    return Output(logits.astype(jnp.float32))


# ===========================================================================
# Losses
# ===========================================================================
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 ignore: int = -1) -> jax.Array:
    """Mean token-level cross entropy; ``labels == ignore`` masked out."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels != ignore
    safe = jnp.where(valid, labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -(ll * valid).sum() / jnp.maximum(valid.sum(), 1)


def chunked_lm_xent(cfg: ModelConfig, params, hidden: jax.Array,
                    labels: jax.Array, chunk: int = 1024,
                    unroll: bool = False) -> jax.Array:
    """Next-token CE without materializing [B, S, V] logits: scan over
    sequence chunks, fusing unembed + log-softmax + gather per chunk.
    Memory peak per chunk: [B, chunk, V] — a 256× reduction at S=4k/V=256k
    relative to whole-sequence logits (§Perf memory-term lever)."""
    B, S, D = hidden.shape
    w_un = unembed_matrix(cfg, params)
    chunk = min(chunk, S)
    if S % chunk:
        pad = (-S) % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S = S + pad
    n = S // chunk
    hc = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h, lab = xs
        logits = jnp.einsum("bnd,dv->bnv", h, w_un.astype(h.dtype)
                            ).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = lab != -1
        safe = jnp.where(valid, lab, 0)
        ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (tot - (ll * valid).sum(), cnt + valid.sum()), None

    scan = _unrolled_scan if unroll else jax.lax.scan
    (tot, cnt), _ = scan(
        body, (jnp.float32(0.0), jnp.int32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)


def lm_loss(cfg: ModelConfig, params, batch, rng=None,
            unroll: bool = False) -> Tuple[jax.Array, Dict]:
    out = forward_lm(cfg, params, batch["tokens"], mode="train",
                     vision_embeds=batch.get("vision_embeds"),
                     audio_frames=batch.get("audio_frames"),
                     logits_for="none", unroll=unroll)
    labels = jnp.concatenate(
        [batch["tokens"][:, 1:],
         jnp.full_like(batch["tokens"][:, :1], -1)], axis=1)
    loss = chunked_lm_xent(cfg, params, out.hidden, labels,
                           chunk=cfg.loss_chunk, unroll=unroll)
    total = loss + 0.01 * out.aux_loss
    return total, {"ce": loss, "aux": out.aux_loss}
