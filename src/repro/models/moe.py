"""Mixture-of-Experts FFN with capacity-based grouped dispatch.

Grouped matmul (megablocks-style, GShard-capacity variant): tokens are
sorted by assigned expert and gathered into a dense ``[E, C, D]`` buffer so
expert FFNs run as one batched einsum — compute scales with *active* experts
only (the 6·N_active·D roofline), shapes stay static, and the whole thing
shards cleanly with experts on the "model" mesh axis (EP).

Block-wise weight pruning applies per-expert (the paper's MLP column/row
pruning generalizes expert-wise; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, linear


def init_moe_params(key, cfg, dtype=jnp.float32) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    E = cfg.moe_num_experts_padded  # weight banks padded for EP sharding
    ks = jax.random.split(key, 5)
    p = {
        # router logits cover only the REAL experts; padded bank rows idle
        "router": dense_init(ks[0], d, cfg.moe_num_experts, dtype),
        "wg": jax.vmap(lambda k: dense_init(k, d, ff, dtype))(
            jax.random.split(ks[1], E)),
        "wi": jax.vmap(lambda k: dense_init(k, d, ff, dtype))(
            jax.random.split(ks[2], E)),
        "wo": jax.vmap(lambda k: dense_init(k, ff, d, dtype))(
            jax.random.split(ks[3], E)),
    }
    shared_ff = cfg.moe_shared_d_ff or (cfg.d_ff * cfg.moe_num_shared)
    if shared_ff:
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": dense_init(sks[0], d, shared_ff, dtype),
            "wi": dense_init(sks[1], d, shared_ff, dtype),
            "wo": dense_init(sks[2], shared_ff, d, dtype),
        }
    return p


def moe_capacity(num_tokens: int, num_experts: int, top_k: int,
                 capacity_factor: float = 1.25) -> int:
    c = math.ceil(num_tokens * top_k / num_experts * capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def moe_ffn(x: jax.Array, p: Dict, cfg,
            capacity_factor: float | None = None) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D]. Returns (y, aux_loss).

    Dispatch: flatten tokens, route top-k, sort (token, slot) pairs by
    expert, place each into its expert's capacity buffer (overflow dropped —
    standard GShard semantics), run the grouped FFN, scatter-add back.
    """
    B, S, D = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    E_pad = cfg.moe_num_experts_padded
    T = B * S
    xf = x.reshape(T, D)
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "moe_capacity_factor", 1.25)

    logits = linear(xf, p["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # [T, K]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)                      # mean router prob per expert
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * K))                            # fraction routed per expert
    aux = E * jnp.sum(me * ce)

    C = moe_capacity(T, E, K, capacity_factor)

    flat_expert = expert_idx.reshape(-1)          # [T*K]
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)

    # position of each (token, k) within its expert group
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # rank within group = index - start offset of that expert
    counts = jnp.zeros((E,), jnp.int32).at[sorted_expert].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * K) - starts[sorted_expert]
    valid = rank < C

    # gather tokens into [E_pad, C, D] (padding experts receive nothing —
    # router logits only cover the E real experts)
    buf = jnp.zeros((E_pad, C, D), xf.dtype)
    buf = buf.at[sorted_expert, jnp.where(valid, rank, 0)].add(
        jnp.where(valid[:, None], xf[sorted_token], 0.0))

    # grouped expert FFN: [E, C, D] x [E, D, F] -> [E, C, F]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(buf.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(buf.dtype))
    y_e = jnp.einsum("ecf,efd->ecd", g * u, p["wo"].astype(buf.dtype))

    # scatter back with gate weighting
    gathered = y_e[sorted_expert, jnp.where(valid, rank, 0)]
    gathered = jnp.where(valid[:, None], gathered, 0.0)
    yf = jnp.zeros((T, D), xf.dtype).at[sorted_token].add(
        gathered * sorted_gate[:, None].astype(xf.dtype))

    if "shared" in p:
        sh = p["shared"]
        g = jax.nn.silu(linear(xf, sh["wg"]))
        u = linear(xf, sh["wi"])
        yf = yf + linear(g * u, sh["wo"])

    return yf.reshape(B, S, D), aux
