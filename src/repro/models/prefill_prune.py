"""Token-pruned LM prefill — the paper's TDM adapted to causal prompts.

For a decoder-only LM, prefill is encoder-like from the viewpoint of the
*last* position: intermediate prompt tokens that receive little attention
from the scoring row contribute little to the next-token prediction. The
TDM therefore drops inattentive prompt tokens at ``cfg.pruning.tdm_layers``
using the LAST-token attention row (the CLS analog), fusing the dropped
remainder into one carrier token, exactly as the paper fuses inattentive
image patches.

RoPE positions are preserved through the drops (tokens keep their original
absolute positions; the fused token inherits the last dropped position), so
the retained computation is identical to the dense path restricted to kept
tokens.

Python-loop (shape changes per TDM layer preclude scan) over per-layer
slices of the stacked params. Dense / qk-norm GQA families supported —
SSM/hybrid are excluded (recurrence, DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import token_pruning as TP
from repro.models import attention as A
from repro.models import layers as L
from repro.models import model as M


def pruned_prefill_logits(cfg: ModelConfig, params: Dict,
                          tokens: jax.Array) -> Tuple[jax.Array, int]:
    """Last-position logits with TDM active during prefill.

    Returns (logits [B, vocab], n_tokens_final). Supported: family=="dense"
    (plain or qk-norm GQA)."""
    assert cfg.family == "dense", "prefill TDM: dense LMs only"
    p = cfg.pruning
    adt = jnp.dtype(cfg.dtype)
    B, N = tokens.shape
    x = params["embed"][tokens].astype(adt)
    positions = jnp.broadcast_to(jnp.arange(N), (B, N))

    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        has_tdm = p.token_pruning_enabled and i in p.tdm_layers
        h, _, scores = A.attention_block(
            L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
            causal=True, positions=positions,
            collect_scores=has_tdm, score_row=-1)
        x = x + h
        x = x + L.glu_mlp(L.rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
        if has_tdm:
            x, positions = _tdm_causal(x, positions, scores, p.r_t)

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    w_un = M.unembed_matrix(cfg, params)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], w_un.astype(adt))
    return logits.astype(jnp.float32), x.shape[1]


def _tdm_causal(x: jax.Array, positions: jax.Array, scores: jax.Array,
                r_t: float) -> Tuple[jax.Array, jax.Array]:
    """TDM for causal prompts: ALWAYS keep the last token (the predictor),
    drop/fuse among the rest, preserve temporal order and RoPE positions."""
    B, N, D = x.shape
    body = x[:, :-1]
    body_pos = positions[:, :-1]
    s_body = scores[:, :-1]
    k = max(1, math.ceil((N - 1) * r_t))

    top_vals, top_idx = jax.lax.top_k(s_body, k)
    top_idx = jnp.sort(top_idx, axis=-1)  # temporal order for causality
    kept = jnp.take_along_axis(body, top_idx[..., None], axis=1)
    kept_pos = jnp.take_along_axis(body_pos, top_idx, axis=1)

    keep_mask = jnp.zeros((B, N - 1), bool)
    keep_mask = jnp.put_along_axis(keep_mask, top_idx, True, axis=1,
                                   inplace=False)
    w = jnp.where(keep_mask, 0.0, s_body.astype(jnp.float32))
    w = w / (w.sum(axis=1, keepdims=True) + 1e-9)
    fused = jnp.einsum("bn,bnd->bd", w.astype(x.dtype), body)
    # the fused token sits just before the predictor, at the last kept+1 pos
    fused_pos = jnp.max(jnp.where(keep_mask, body_pos, 0), axis=1) + 0

    x_out = jnp.concatenate([kept, fused[:, None], x[:, -1:]], axis=1)
    pos_out = jnp.concatenate(
        [kept_pos, fused_pos[:, None], positions[:, -1:]], axis=1)
    return x_out, pos_out
