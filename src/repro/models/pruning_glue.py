"""Glue between the paper's pruning (core/) and the model zoo: identify
prunable weights in a param pytree, create score parameters, and produce
masked params for the forward pass (STE-differentiable w.r.t. scores).

Prunable groups (DESIGN.md §Arch-applicability):
  * attention projections  wq/wk/wv (block scores)  + wo (block scores)
  * MLP / expert FFN       wi,wg (column score vector), wo (row score vector)
  * everything else (embeddings, norms, router, conv, SSM gathers) is dense.

Stacked layer axes are handled with vmap: a weight [L, M1, M2] owns scores
[L, m, n] and top-k is per (layer, matrix), as in the paper.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import block_pruning as BP

# param-tree key -> pruning kind
_ATTN_KEYS = {"wq": "block", "wk": "block", "wv": "block", "wo": "block"}
_MLP_COL = {"wi", "wg", "cm_wk"}
_MLP_ROW = {"wo", "cm_wv"}


def _is_attn_ctx(path) -> bool:
    keys = [getattr(p, "key", None) for p in path]
    return any(k in ("attn", "xattn", "shared_attn") for k in keys)


def _is_mlp_ctx(path) -> bool:
    keys = [getattr(p, "key", None) for p in path]
    return any(k in ("mlp", "moe", "shared") for k in keys) or any(
        k in ("cm_wk", "cm_wv") for k in keys)


def _leaf_key(path) -> str:
    return getattr(path[-1], "key", "")


def prunable_kind(path, leaf) -> str | None:
    """Return "block" | "col" | "row" | None for a param leaf."""
    if leaf.ndim < 2:
        return None
    k = _leaf_key(path)
    if _is_attn_ctx(path) and k in _ATTN_KEYS:
        return "block"
    if _is_mlp_ctx(path):
        if k in _MLP_COL:
            return "col"
        if k in _MLP_ROW:
            return "row"
    return None


def init_scores(cfg: ModelConfig, params: Dict, key: jax.Array) -> Dict:
    """Score pytree: same structure as params but only at prunable leaves
    (other positions hold None, pruned from the pytree)."""
    b = cfg.pruning.block_size
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = {}
    for i, (path, leaf) in enumerate(flat):
        kind = prunable_kind(path, leaf)
        if kind is None:
            continue
        k = jax.random.fold_in(key, i)
        if leaf.ndim == 2:
            s = BP.init_scores_for(leaf, b, kind, k)
        else:
            # stacked [L, ..., M1, M2]: vmap the init over leading axes
            lead = leaf.shape[:-2]
            w2 = leaf.reshape((-1,) + leaf.shape[-2:])
            ks = jax.random.split(k, w2.shape[0])
            s = jnp.stack([BP.init_scores_for(w2[j], b, kind, ks[j])
                           for j in range(w2.shape[0])])
            s = s.reshape(lead + s.shape[1:])
        out[_path_str(path)] = s
    return out


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def apply_pruning(cfg: ModelConfig, params: Dict, scores: Dict,
                  r_b: float | None = None) -> Dict:
    """Masked params for the forward pass (STE-differentiable in scores)."""
    p = cfg.pruning
    if r_b is None:
        r_b = p.r_b
    if r_b >= 1.0 or not scores:
        return params
    b = p.block_size

    def mask_one(w, s, kind):
        if kind == "block":
            return BP.masked_weight(w, s, r_b, b)
        axis = 1 if kind == "col" else 0
        return BP.masked_weight_vector(w, s, r_b, axis)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    new_leaves = []
    for path, leaf in flat:
        kind = prunable_kind(path, leaf)
        ps = _path_str(path)
        if kind is None or ps not in scores:
            new_leaves.append(leaf)
            continue
        if not ((kind == "block" and not p.prune_msa)
                or (kind in ("col", "row") and not p.prune_mlp)):
            s = scores[ps]
            if leaf.ndim == 2:
                leaf = mask_one(leaf, s, kind)
            else:
                lead = leaf.shape[:-2]
                w2 = leaf.reshape((-1,) + leaf.shape[-2:])
                s2 = s.reshape((-1,) + s.shape[len(lead):])
                fn = lambda ww, ss: mask_one(ww, ss, kind)
                leaf = jax.vmap(fn)(w2, s2).reshape(leaf.shape)
        new_leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def regularizer(scores: Dict) -> jax.Array:
    """Eq. 8: Σ σ(S) over all score tensors (λ applied by caller)."""
    return BP.sparsity_regularizer(scores)


def hard_masks(cfg: ModelConfig, params: Dict, scores: Dict) -> Dict:
    """Non-STE binary block masks for packing / size accounting."""
    p = cfg.pruning
    b = p.block_size
    out = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        kind = prunable_kind(path, leaf)
        ps = _path_str(path)
        if kind is None or ps not in scores:
            continue
        s = scores[ps]
        if kind == "block":
            if leaf.ndim == 2:
                out[ps] = BP.hard_block_mask(s, p.r_b, leaf.shape, b)
            else:
                w2 = leaf.reshape((-1,) + leaf.shape[-2:])
                s2 = s.reshape((-1,) + s.shape[-2:])
                out[ps] = jnp.stack([
                    BP.hard_block_mask(s2[j], p.r_b, w2[j].shape, b)
                    for j in range(w2.shape[0])]).reshape(
                        leaf.shape[:-2] + s.shape[-2:])
    return out
