"""State-space / recurrent token mixers: Mamba2 (zamba2 hybrid) and RWKV6.

Both are attention-free and sub-quadratic: training runs a time scan carrying
recurrent state; decode is a single O(1)-per-token state update, which is why
these archs (and only these) run the ``long_500k`` shape.

The paper's token pruning is inapplicable here (dropping a token mid-sequence
corrupts the recurrent state — DESIGN.md §Arch-applicability); static block
weight pruning applies to every projection below.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, linear, rms_norm


# ===========================================================================
# Mamba2 (SSD, scalar-identity A per head)
# ===========================================================================
class MambaState(NamedTuple):
    h: jax.Array     # [B, H, Dh, State]
    conv: jax.Array  # [B, ConvW-1, D_inner] rolling conv buffer


def mamba_head_dim() -> int:
    return 64


def init_mamba_params(key, cfg, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    state = cfg.ssm_state
    H = inner // mamba_head_dim()
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * inner + 2 * state + H, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.ssm_conv_width, inner), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)),
        "dt_bias": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "norm": jnp.ones((inner,), dtype),
        "out_proj": dense_init(ks[2], inner, d, dtype),
    }


def init_mamba_state(batch: int, cfg, dtype=jnp.float32) -> MambaState:
    inner = cfg.ssm_expand * cfg.d_model
    H = inner // mamba_head_dim()
    return MambaState(
        h=jnp.zeros((batch, H, mamba_head_dim(), cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, inner), dtype),
    )


def _mamba_split(x, p, cfg):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    state = cfg.ssm_state
    H = inner // mamba_head_dim()
    zxbcdt = linear(x, p["in_proj"])
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + state, 2 * inner + 2 * state],
        axis=-1)
    return z, xs, Bm, Cm, dt, inner, state, H


def mamba_block(x: jax.Array, p: Dict, cfg,
                state: Optional[MambaState] = None
                ) -> Tuple[jax.Array, Optional[MambaState]]:
    """x: [B, S, D]. Full-sequence scan (training / prefill).

    If ``state`` is given it is consumed as the initial state and the final
    state is returned (chunked prefill / decode continuation)."""
    B, S, D = x.shape
    z, xs, Bm, Cm, dt, inner, n_state, H = _mamba_split(x, p, cfg)
    dh = mamba_head_dim()

    if state is None:
        state = init_mamba_state(B, cfg, x.dtype)

    # causal depthwise conv over the x-branch with carried buffer
    conv_in = jnp.concatenate([state.conv.astype(xs.dtype), xs], axis=1)
    W = cfg.ssm_conv_width
    xs_conv = sum(conv_in[:, i:i + S, :] * p["conv_w"][i].astype(xs.dtype)
                  for i in range(W))
    xs_conv = jax.nn.silu(xs_conv)
    new_conv = conv_in[:, S:S + W - 1, :] if S >= W - 1 else conv_in[:, -(W - 1):, :]

    xh = xs_conv.reshape(B, S, H, dh)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [H]
    decay = jnp.exp(dt_sp * A)                                   # [B,S,H]

    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(h, t):
        xt, dt_t, dec_t, b_t, c_t = t
        # h: [B,H,dh,state]
        upd = (dt_t[..., None, None] * xt.astype(jnp.float32)[..., None]
               * b_t[:, None, None, :])
        h = h * dec_t[..., None, None] + upd
        y = jnp.einsum("bhds,bs->bhd", h, c_t)
        return h, y

    xs_t = jnp.moveaxis(xh, 1, 0)        # [S,B,H,dh]
    dt_t = jnp.moveaxis(dt_sp, 1, 0)     # [S,B,H]
    dec_t = jnp.moveaxis(decay, 1, 0)
    b_t = jnp.moveaxis(Bf, 1, 0)         # [S,B,state]
    c_t = jnp.moveaxis(Cf, 1, 0)
    h_final, ys = jax.lax.scan(step, state.h, (xs_t, dt_t, dec_t, b_t, c_t))
    y = jnp.moveaxis(ys, 0, 1)           # [B,S,H,dh]
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"])
    return out, MambaState(h_final, new_conv.astype(state.conv.dtype))


# ===========================================================================
# RWKV6 ("Finch": data-dependent decay)
# ===========================================================================
class RWKVState(NamedTuple):
    wkv: jax.Array      # [B, H, Dh, Dh]
    shift_tm: jax.Array  # [B, D] last token (time-mix shift)
    shift_cm: jax.Array  # [B, D] last token (channel-mix shift)


def rwkv_head_dim(cfg) -> int:
    return cfg.d_model // cfg.num_heads


def init_rwkv_params(key, cfg, dtype=jnp.float32) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 10)
    return {
        "mix_r": 0.5 * jnp.ones((d,), dtype),
        "mix_k": 0.5 * jnp.ones((d,), dtype),
        "mix_v": 0.5 * jnp.ones((d,), dtype),
        "mix_w": 0.5 * jnp.ones((d,), dtype),
        "mix_g": 0.5 * jnp.ones((d,), dtype),
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "ww": dense_init(ks[4], d, d, dtype),  # data-dependent decay proj
        "w_bias": -6.0 * jnp.ones((d,), dtype),
        "u": 0.1 * jax.random.normal(ks[5], (cfg.num_heads, rwkv_head_dim(cfg)), dtype),
        "wo": dense_init(ks[6], d, d, dtype),
        "ln_x": jnp.ones((d,), dtype),
        # channel mix
        "cm_mix_k": 0.5 * jnp.ones((d,), dtype),
        "cm_wk": dense_init(ks[7], d, ff, dtype),
        "cm_wv": dense_init(ks[8], ff, d, dtype),
        # pre-norms for the two sublayers
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
    }


def init_rwkv_state(batch: int, cfg, dtype=jnp.float32) -> RWKVState:
    H = cfg.num_heads
    dh = rwkv_head_dim(cfg)
    return RWKVState(
        wkv=jnp.zeros((batch, H, dh, dh), jnp.float32),
        shift_tm=jnp.zeros((batch, cfg.d_model), dtype),
        shift_cm=jnp.zeros((batch, cfg.d_model), dtype),
    )


def _token_shift(x, last):
    """x: [B,S,D]; last: [B,D] (previous token). Returns shifted x."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def rwkv_time_mix(x: jax.Array, p: Dict, cfg, state: RWKVState,
                  chunk: int = 0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``chunk=0``: sequential per-token scan (the oracle). ``chunk=C>0``:
    flash-linear-attention chunking — the WKV state stays register/VMEM
    resident for C steps and is materialized once per chunk instead of per
    token (the §Perf C2 lever: state HBM traffic ÷ C, at the cost of an
    intra-chunk [C×C] attention-like term)."""
    B, S, D = x.shape
    H = cfg.num_heads
    dh = rwkv_head_dim(cfg)
    xp = _token_shift(x, state.shift_tm)

    def mixed(mix):
        m = p[mix].astype(x.dtype)
        return x * m + xp * (1 - m)

    r = linear(mixed("mix_r"), p["wr"]).reshape(B, S, H, dh)
    k = linear(mixed("mix_k"), p["wk"]).reshape(B, S, H, dh)
    v = linear(mixed("mix_v"), p["wv"]).reshape(B, S, H, dh)
    g = jax.nn.silu(linear(mixed("mix_g"), p["wg"]))
    # data-dependent decay (Finch): w in (0,1), per channel per step
    w_raw = linear(mixed("mix_w"), p["ww"]) + p["w_bias"].astype(x.dtype)
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32))).reshape(B, S, H, dh)

    u = p["u"].astype(jnp.float32)  # [H, dh]

    if chunk and S % chunk == 0 and S > chunk:
        y, s_final = _wkv_chunked(r, k, v, w, u, state.wkv, chunk)
    else:
        y, s_final = _wkv_sequential(r, k, v, w, u, state.wkv)
    y = y.reshape(B, S, D).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    out = linear(y, p["wo"])
    return out, s_final, x[:, -1, :]


def _wkv_sequential(r, k, v, w, u, s0):
    B, S, H, dh = r.shape

    def step(s, t):
        r_t, k_t, v_t, w_t = t  # [B,H,dh] each
        kf = k_t.astype(jnp.float32)
        vf = v_t.astype(jnp.float32)
        kv = kf[..., :, None] * vf[..., None, :]          # [B,H,dh,dh]
        y = jnp.einsum("bhd,bhde->bhe",
                       r_t.astype(jnp.float32), s + u[None, :, :, None] * kv)
        s = s * w_t.astype(jnp.float32)[..., None] + kv
        return s, y

    rt = jnp.moveaxis(r, 1, 0)
    kt = jnp.moveaxis(k, 1, 0)
    vt = jnp.moveaxis(v, 1, 0)
    wt = jnp.moveaxis(w, 1, 0)
    s_final, ys = jax.lax.scan(step, s0, (rt, kt, vt, wt))
    return jnp.moveaxis(ys, 0, 1), s_final


def _wkv_chunked(r, k, v, w, u, s0, C: int):
    """Flash-linear-attention chunking of the RWKV6 recurrence.

    With S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ and y_t = r_t·(S_{t-1} + u⊙k_t v_tᵀ):
      P_t   = Π_{u<t} w_u                  (exclusive cumprod inside a chunk)
      y_t   = (r_t⊙P_t)·S_chunk0                            [inter]
            + Σ_{s<t} (r_t⊙P_t)·(k_s/P_{s+1}) v_sᵀ          [intra, causal]
            + (r_t⊙u⊙k_t)·v_tᵀ                              [bonus]
      S_end = P_C ⊙ (S_chunk0 + Σ_s (k_s/P_{s+1}) v_sᵀ)

    fp32 throughout; chunk sizes ≤ 64 keep k/P well conditioned for the
    near-1 decays RWKV6 trains to."""
    B, S, H, dh = r.shape
    n = S // C
    rf = r.astype(jnp.float32).reshape(B, n, C, H, dh)
    kf = k.astype(jnp.float32).reshape(B, n, C, H, dh)
    vf = v.astype(jnp.float32).reshape(B, n, C, H, dh)
    wf = w.astype(jnp.float32).reshape(B, n, C, H, dh)

    # move chunk axis first for scan
    rf, kf, vf, wf = (jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))

    def chunk_step(s, t):
        rc, kc, vc, wc = t  # [B, C, H, dh]
        P_excl = jnp.concatenate(
            [jnp.ones_like(wc[:, :1]), jnp.cumprod(wc, axis=1)[:, :-1]],
            axis=1)                                     # P_t = prod_{u<t} w_u
        P_incl = P_excl * wc                            # prod_{u<=t}
        r_dec = rc * P_excl                             # [B,C,H,dh]
        k_gro = kc / jnp.maximum(P_incl, 1e-20)         # k_s / P_{s+1}

        # inter-chunk: r_dec · S0
        y_inter = jnp.einsum("bchd,bhde->bche", r_dec, s)
        # intra-chunk causal linear attention
        A = jnp.einsum("bchd,bshd->bhcs", r_dec, k_gro)  # [B,H,C,C]
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)    # strictly lower
        A = jnp.where(mask[None, None], A, 0.0)
        y_intra = jnp.einsum("bhcs,bshe->bche", A, vc)
        # bonus (current token): (r_t ⊙ u ⊙ k_t summed over d) · v_t
        y_bonus = (rc * u[None, None] * kc).sum(-1)[..., None] * vc

        y = y_inter + y_intra + y_bonus
        # carry state
        kv_sum = jnp.einsum("bshd,bshe->bhde", k_gro, vc)
        Pc = P_incl[:, -1]                               # [B,H,dh]
        s_new = Pc[..., None] * (s + kv_sum)
        return s_new, y

    s_final, ys = jax.lax.scan(chunk_step, s0, (rf, kf, vf, wf))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dh)
    return y, s_final


def rwkv_channel_mix(x: jax.Array, p: Dict, cfg, state: RWKVState
                     ) -> Tuple[jax.Array, jax.Array]:
    xp = _token_shift(x, state.shift_cm)
    m = p["cm_mix_k"].astype(x.dtype)
    xk = x * m + xp * (1 - m)
    h = jnp.square(jax.nn.relu(linear(xk, p["cm_wk"])))
    return linear(h, p["cm_wv"]), x[:, -1, :]


def rwkv_block(x: jax.Array, p: Dict, cfg,
               state: Optional[RWKVState] = None
               ) -> Tuple[jax.Array, RWKVState]:
    """One RWKV6 layer (time-mix + channel-mix, pre-LN residuals are applied
    by the caller). Returns (y_tm + y_cm combined residual stream, state)."""
    B = x.shape[0]
    if state is None:
        state = init_rwkv_state(B, cfg, x.dtype)
    y_tm, wkv, last_tm = rwkv_time_mix(
        rms_norm(x, p["ln1"], cfg.norm_eps), p, cfg, state,
        chunk=getattr(cfg, "rwkv_chunk", 0))
    x2 = x + y_tm
    y_cm, last_cm = rwkv_channel_mix(
        rms_norm(x2, p["ln2"], cfg.norm_eps), p, cfg, state)
    out = x2 + y_cm
    return out, RWKVState(wkv, last_tm, last_cm)
