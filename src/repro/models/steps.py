"""Step functions: train_step / prefill / decode for every family, plus the
cache constructors and ShapeDtypeStruct input specs used by the dry-run.

These are the functions that get ``jax.jit(...).lower().compile()``'d against
the production mesh — they are the unit of the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as A
from repro.models import ssm as SSM
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.optim.adamw import AdamW, AdamWState


# ===========================================================================
# Cache constructors
# ===========================================================================
def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Any:
    """Stacked (scan-ready) serve caches for ``cfg``."""
    fam = cfg.family
    kv, dh = cfg.num_kv_heads, cfg.head_dim

    def kv_stack(n):
        return jax.vmap(lambda _: A.init_kv_cache(batch, max_len, kv, dh,
                                                  dtype))(jnp.arange(n))

    if fam in ("dense", "moe"):
        return kv_stack(cfg.num_layers)
    if fam == "vlm":
        n_stages = cfg.num_layers // cfg.cross_attn_period
        n_self = cfg.cross_attn_period - 1
        return jax.vmap(lambda _: kv_stack(n_self))(jnp.arange(n_stages))
    if fam == "audio":
        # decoder self caches; encoder output is attached at prefill
        return kv_stack(cfg.num_layers)
    if fam == "hybrid":
        period = cfg.attn_layer_period
        n_stages = cfg.num_layers // period
        rem = cfg.num_layers - n_stages * period
        mamba = jax.vmap(lambda _: jax.vmap(
            lambda __: SSM.init_mamba_state(batch, cfg, dtype))(
                jnp.arange(period)))(jnp.arange(n_stages))
        tail = (jax.vmap(lambda _: SSM.init_mamba_state(batch, cfg, dtype))(
            jnp.arange(rem)) if rem else None)
        attn = kv_stack(n_stages)
        return (mamba, tail, attn)
    if fam == "ssm":
        return jax.vmap(lambda _: SSM.init_rwkv_state(batch, cfg, dtype))(
            jnp.arange(cfg.num_layers))
    raise ValueError(fam)


def set_cache_length(cfg: ModelConfig, caches: Any, length) -> Any:
    """Mark ``length`` tokens of every KV cache as valid (used to build the
    decode-shape dry-run state: 'a KV cache of seq_len')."""
    def fix(c):
        if isinstance(c, A.KVCache):
            return c._replace(length=jnp.broadcast_to(
                jnp.asarray(length, jnp.int32), c.length.shape))
        return c
    is_leaf = lambda x: isinstance(x, A.KVCache)
    return jax.tree.map(fix, caches, is_leaf=is_leaf)


# ===========================================================================
# Input batch specs (ShapeDtypeStruct stand-ins — no allocation)
# ===========================================================================
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one grid cell, as ShapeDtypeStructs.

    train/prefill: full token batch; decode: one new token + cache handled
    separately (see ``serve_state_specs``). Modality frontends are STUBS:
    vision/audio entries are precomputed embeddings."""
    B = shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        batch = {"tokens": sd((B, 1), i32)}
    else:
        batch = {"tokens": sd((B, S), i32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = sd(
            (B, cfg.num_vision_tokens, cfg.vision_d_model or cfg.d_model),
            jnp.bfloat16)
    if cfg.family == "audio" and shape.kind != "decode":
        # encoder consumes seq_len frames; decoder consumes tokens
        batch["audio_frames"] = sd((B, S, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = sd((B, max(S // 8, 8)), i32)  # text shorter than audio
    return batch


def serve_state_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """Cache pytree spec for decode shapes (KV cache of seq_len tokens)."""
    B, S = shape.global_batch, shape.seq_len
    specs = jax.eval_shape(
        lambda: init_caches(cfg, B, S, jnp.bfloat16))
    if cfg.family == "audio":
        enc = jax.ShapeDtypeStruct((B, min(S, 4 * cfg.num_audio_frames),
                                    cfg.d_model), jnp.bfloat16)
        specs = (specs, enc)
    return specs


# ===========================================================================
# Train step
# ===========================================================================
def make_train_step(cfg: ModelConfig, optimizer: Optional[AdamW] = None,
                    with_pruning: Optional[bool] = None,
                    unroll: bool = False):
    """Returns ``step(params, opt_state, batch, scores=None) ->
    (params, opt_state, metrics)``. When the paper's weight pruning is
    enabled, ``scores`` are trained jointly (simultaneous pruning)."""
    opt = optimizer or AdamW()
    p = cfg.pruning
    use_prune = p.weight_pruning_enabled if with_pruning is None else with_pruning

    def loss_fn(trainables, batch):
        wrapped = isinstance(trainables, dict) and "params" in trainables
        params = trainables["params"] if wrapped else trainables
        scores = trainables.get("scores") if wrapped else None
        if use_prune and scores:
            params = PG.apply_pruning(cfg, params, scores)
        total, parts = M.lm_loss(cfg, params, batch, unroll=unroll)
        if use_prune and scores:
            total = total + p.lambda_reg * PG.regularizer(scores)
        return total, parts

    def step(params, opt_state, batch, scores=None):
        """opt_state must be opt.init(params) when scores is None, else
        opt.init({"params": params, "scores": scores}).

        With cfg.microbatches > 1 the batch splits along dim 0 and gradients
        accumulate over a scan — activation memory scales 1/M (the §Perf
        memory lever for the >HBM train cells)."""
        trainables = {"params": params, "scores": scores} if scores else params
        M_ = cfg.microbatches
        if M_ > 1:
            def split(x):
                B = x.shape[0]
                return x.reshape(M_, B // M_, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                (loss, parts), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(trainables, mb)
                g_acc = jax.tree.map(lambda a, b: a + b / M_, g_acc, g)
                return (g_acc, loss_acc + loss / M_), parts

            zero = jax.tree.map(jnp.zeros_like, trainables)
            (grads, loss), parts_stack = jax.lax.scan(
                acc_body, (zero, jnp.float32(0.0)), micro)
            parts = jax.tree.map(lambda x: x.mean(), parts_stack)
        else:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                trainables, batch)
        new_tr, new_opt = opt.update(grads, opt_state, trainables)
        metrics = {"loss": loss, **parts}
        if scores:
            return (new_tr["params"], new_tr["scores"], new_opt, metrics)
        return (new_tr, None, new_opt, metrics)

    return step


# ===========================================================================
# Serve steps
# ===========================================================================
def make_prefill(cfg: ModelConfig, unroll: bool = False):
    """``batch`` may carry "valid_start" ([B] int32): first real token per
    row — left-padded prompt positions are masked out of attention."""
    def prefill(params, batch, caches):
        out = M.forward_lm(cfg, params, batch["tokens"], mode="prefill",
                           caches=caches,
                           vision_embeds=batch.get("vision_embeds"),
                           audio_frames=batch.get("audio_frames"),
                           logits_for="last", unroll=unroll,
                           valid_start=batch.get("valid_start"))
        next_tok = jnp.argmax(out.logits[:, -1], axis=-1)
        return next_tok, out.caches
    return prefill


# Families whose serve state is pure KV cache — left-padding can be masked
# exactly via valid_start. Recurrent families (ssm, hybrid mamba states)
# absorb pad tokens into state, so they serve without the masking.
MASKABLE_FAMILIES = ("dense", "moe", "vlm", "audio")

# Families whose serve state is purely stacked KV caches — a single slot can
# be prefilled in isolation and scattered into the live batch. Recurrent
# state (ssm/hybrid) and encoder-coupled caches (audio/vlm) need the full
# batch present, so their engines fall back to whole-batch re-prefill.
SLOT_PREFILL_FAMILIES = ("dense", "moe")


def _blank_row_caches(caches: Any) -> Any:
    """A zeroed B=1 copy of a stacked serve-cache pytree (KVCache leaves
    only). Batch axes follow the KVCache layout: k/v [..., B, S, KV, Dh],
    attn_mass [..., B, S], length [..., B]."""
    def one(c):
        if not isinstance(c, A.KVCache):
            raise TypeError(
                "per-slot prefill needs a pure KV-cache tree; got leaf "
                f"{type(c).__name__} (recurrent/encoder state — use the "
                "whole-batch prefill path)")
        row1 = lambda a, ax: jnp.zeros(
            a.shape[:ax] + (1,) + a.shape[ax + 1:], a.dtype)
        return A.KVCache(row1(c.k, c.k.ndim - 4), row1(c.v, c.v.ndim - 4),
                         row1(c.length, c.length.ndim - 1),
                         row1(c.attn_mass, c.attn_mass.ndim - 2))
    is_kv = lambda x: isinstance(x, A.KVCache)
    return jax.tree.map(one, caches, is_leaf=is_kv)


def _scatter_row_caches(live: Any, row: Any, slot) -> Any:
    """Write the B=1 cache pytree ``row`` into batch row ``slot`` of
    ``live`` (slot may be traced — one jit compile covers every slot)."""
    def put(dst, src, batch_axis):
        starts = [jnp.int32(0)] * dst.ndim
        starts[batch_axis] = jnp.asarray(slot, jnp.int32)
        return jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), tuple(starts))

    def one(dst, src):
        return A.KVCache(put(dst.k, src.k, dst.k.ndim - 4),
                         put(dst.v, src.v, dst.v.ndim - 4),
                         put(dst.length, src.length, dst.length.ndim - 1),
                         put(dst.attn_mass, src.attn_mass,
                             dst.attn_mass.ndim - 2))
    is_kv = lambda x: isinstance(x, A.KVCache)
    return jax.tree.map(one, live, row, is_leaf=is_kv)


def make_prefill_slot(cfg: ModelConfig, unroll: bool = False):
    """Prefill ONE admitted prompt into one slot of the live batched cache.

    Returns ``prefill_slot(params, batch, caches, slot) ->
    (next_token [1], caches)``: ``batch["tokens"]`` is a single
    (bucket-padded) prompt row ``[1, Lb]`` with ``batch["valid_start"]``
    ``[1]`` marking its left padding. The prompt runs through a B=1 prefill
    against a blank cache row, which is then scattered into batch row
    ``slot`` of ``caches`` — admission costs one prompt's FLOPs instead of
    a whole-batch re-prefill, and ``slot`` stays traced so jit compiles
    once per bucketed prefix length, not per slot.
    """
    if cfg.family not in SLOT_PREFILL_FAMILIES:
        raise ValueError(
            f"per-slot prefill unsupported for family '{cfg.family}' "
            f"(supported: {SLOT_PREFILL_FAMILIES}); serve this family "
            "through the whole-batch prefill path")

    def prefill_slot(params, batch, caches, slot):
        row = _blank_row_caches(caches)
        out = M.forward_lm(cfg, params, batch["tokens"], mode="prefill",
                           caches=row, logits_for="last", unroll=unroll,
                           valid_start=batch.get("valid_start"))
        next_tok = jnp.argmax(out.logits[:, -1], axis=-1)  # [1]
        return next_tok, _scatter_row_caches(caches, out.caches, slot)
    return prefill_slot


def make_decode_step(cfg: ModelConfig, unroll: bool = False):
    """One token in, one token out, caches updated in place."""
    def decode(params, token, caches, vision_embeds=None, valid_start=None):
        out = M.forward_lm(cfg, params, token, mode="decode", caches=caches,
                           vision_embeds=vision_embeds, unroll=unroll,
                           valid_start=valid_start)
        next_tok = jnp.argmax(out.logits[:, -1], axis=-1)
        return next_tok, out.caches
    return decode


def make_vit_train_step(cfg: ModelConfig, optimizer: Optional[AdamW] = None):
    """ViT classification training (no distillation; see core/simultaneous
    for the paper's Algorithm 1)."""
    opt = optimizer or AdamW(lr=1e-3)

    def loss_fn(params, batch):
        out = M.forward_vit(cfg, params, batch["patches"])
        loss = M.softmax_xent(out.logits, batch["labels"])
        return loss

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step
