"""Observability layer: span tracing, metrics registry, bounded events.

``repro.obs`` is dependency-free (stdlib only) and imported by the
serving stack, the traffic harness, and the benches:

* :mod:`repro.obs.trace` — span tracer emitting Chrome ``trace_event``
  JSON (Perfetto-loadable) + a JSONL span log; wall-clock spans from the
  engines/pipeline, virtual-clock spans from the traffic harness. The
  shared :data:`NULL_TRACER` keeps the disabled hot path at one
  attribute check.
* :mod:`repro.obs.metrics` — process-wide counters/gauges/fixed-bucket
  histograms absorbing the serving layers' ``stats()`` dicts into one
  snapshot (the schema-v4 bench envelope's ``metrics`` block).
* :mod:`repro.obs.events` — the bounded :class:`EventLog` ring behind
  the Scheduler's unified event stream (absolute indexing + ``drain``).
"""
from repro.obs.events import EventLog
from repro.obs.metrics import (DEFAULT_MS_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, log_buckets,
                               registry, reset_registry)
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer,
                             validate_chrome_trace)

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "validate_chrome_trace",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "log_buckets", "registry", "reset_registry",
           "DEFAULT_MS_BUCKETS", "EventLog"]
