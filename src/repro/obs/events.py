"""Bounded event ring buffer behind the Scheduler's unified event stream.

The Scheduler's ``events`` list used to grow without bound — fine for a
bench run, a leak for a long-lived serving process. :class:`EventLog`
keeps the most recent ``capacity`` entries in a ring while preserving the
two consumption patterns the stack already relies on:

* **Absolute indexing.** ``len(log)`` is the TOTAL number of events ever
  appended (not the buffered count), and slices take *absolute* sequence
  indices — so the traffic harness's incremental scan
  (``mark = len(events); ...; events[mark:]``) keeps working verbatim,
  even after eviction (evicted entries are silently absent from the
  slice; by construction the harness never asks for them, it marks every
  tick).
* **Iteration = buffered entries.** ``list(log)`` and filtered
  comparisons (``[e for e in sched.events if e[0] == "admit"]``) see the
  retained window — the full stream until the ring wraps.

``drain()`` returns-and-clears the buffered entries (the total count
keeps advancing, so outstanding absolute marks stay valid): the
consume-once API for exporters that mirror the stream elsewhere.
"""
from __future__ import annotations

from collections import deque
from itertools import islice
from typing import Any, Deque, Iterator, List

__all__ = ["EventLog"]


class EventLog:
    """Ring buffer with absolute (total-appended) indexing."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf: Deque[Any] = deque(maxlen=capacity)
        self._total = 0

    def append(self, event: Any) -> None:
        self._buf.append(event)
        self._total += 1

    # -- sizes --------------------------------------------------------------
    def __len__(self) -> int:
        """Total events ever appended (the absolute sequence length) —
        NOT the buffered count; see :attr:`buffered`."""
        return self._total

    @property
    def buffered(self) -> int:
        return len(self._buf)

    @property
    def dropped(self) -> int:
        """Events evicted by the ring so far."""
        return self._total - len(self._buf)

    def __bool__(self) -> bool:
        return self._total > 0

    # -- access -------------------------------------------------------------
    def __iter__(self) -> Iterator[Any]:
        return iter(self._buf)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            start, stop, step = idx.indices(self._total)
            lo = self.dropped
            items = list(islice(self._buf, max(start - lo, 0),
                                max(stop - lo, 0)))
            return items[::step] if step != 1 else items
        i = idx + self._total if idx < 0 else idx
        if not 0 <= i < self._total:
            raise IndexError(f"index {idx} out of range for {self._total} "
                             f"events")
        if i < self.dropped:
            raise IndexError(f"event {idx} was evicted (ring keeps the "
                             f"last {self.capacity})")
        return self._buf[i - self.dropped]

    def drain(self) -> List[Any]:
        """Return and clear the buffered entries. The total count is
        unaffected, so absolute marks taken before the drain stay
        consistent (the drained range simply reads as evicted)."""
        items = list(self._buf)
        self._buf.clear()
        return items
