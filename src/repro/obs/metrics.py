"""Metrics registry — counters, gauges, fixed-bucket histograms.

One registry absorbs the serving stack's scattered ``stats()`` dicts into
a single flat, JSON-able namespace: recompile counts from the
ModelRunner/PackedVitSegments compile ledgers, planner merge/fuse/deadline
decisions and the modeled-vs-measured cost error (the calibration-drift
signal), quality-controller tighten events per keep level, padding-waste
and device-idle gauges, admission accept/degrade/reject counters, and the
traffic harness's SLO distributions.

Design constraints, in order:

* **Deterministic.** Histograms use fixed bucket edges chosen at creation
  (no adaptive resizing), so two runs over the same sample stream produce
  byte-identical snapshots. ``percentile`` reads return bucket upper
  edges — a quantized but machine-independent answer.
* **Additive.** The registry *absorbs* the existing ``stats()`` dicts
  (:meth:`MetricsRegistry.absorb` hoovers every numeric entry into a
  gauge); it does not replace them — tests and launchers keep reading the
  dicts they always read.
* **Cheap.** A metric is a tiny mutable object; recording is a dict
  lookup + add. Nothing here touches the device or the wall clock.

A process-wide default registry exists (:func:`registry`) for launchers
that want one sink; engines and the traffic harness accept an explicit
``MetricsRegistry`` so tests can isolate streams.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "log_buckets", "registry", "reset_registry",
           "DEFAULT_MS_BUCKETS"]


def log_buckets(lo: float, hi: float, per_decade: int = 4
                ) -> Tuple[float, ...]:
    """Deterministic geometric bucket edges covering [lo, hi] with
    ``per_decade`` edges per decade. Edges are computed from integer
    exponents (not accumulated multiplication), so the same arguments
    always yield bit-identical edges."""
    if not (lo > 0.0 and hi > lo):
        raise ValueError(f"need 0 < lo < hi, got lo={lo}, hi={hi}")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1, got {per_decade}")
    k0 = math.floor(per_decade * math.log10(lo))
    k1 = math.ceil(per_decade * math.log10(hi))
    return tuple(10.0 ** (k / per_decade) for k in range(k0, k1 + 1))


# 1us .. 100s in ms units — wide enough for both virtual-clock SLO
# latencies (sub-ms at bench scale) and wall-clock step times
DEFAULT_MS_BUCKETS = log_buckets(1e-3, 1e5, per_decade=4)


class Counter:
    """Monotone event count."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters are monotone; cannot add {n}")
        self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket distribution; deterministic for a given sample stream.

    ``buckets`` are ascending upper edges; a sample lands in the first
    bucket whose edge is >= the sample, or the overflow bucket past the
    last edge. ``percentile`` is a nearest-rank read over the bucket
    counts: it returns the upper edge of the bucket containing the rank
    (quantized — exact percentiles stay with the raw-sample paths that
    need them, e.g. the traffic report)."""

    kind = "histogram"
    __slots__ = ("buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, buckets: Sequence[float] = DEFAULT_MS_BUCKETS):
        edges = tuple(float(b) for b in buckets)
        if not edges or any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError(f"bucket edges must be strictly ascending and "
                             f"non-empty, got {edges}")
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)  # [+ overflow]
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        lo, hi = 0, len(self.buckets)
        while lo < hi:  # first edge >= v
            mid = (lo + hi) // 2
            if self.buckets[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile as a bucket upper edge (the overflow
        bucket reads as the observed max). NaN when empty."""
        if self.count == 0:
            return float("nan")
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return (self.buckets[i] if i < len(self.buckets)
                        else self.max)
        return self.max

    def snapshot(self) -> Dict[str, Any]:
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "p50": self.percentile(50) if self.count else None,
            "p95": self.percentile(95) if self.count else None,
            "p99": self.percentile(99) if self.count else None,
            "buckets": list(self.buckets),
            "counts": list(self.counts),
        }


class MetricsRegistry:
    """Lazily-created named metrics behind one flat namespace.

    ``counter(name)`` / ``gauge(name)`` / ``histogram(name)`` return the
    existing metric or create it; asking for an existing name with a
    different type raises (one name, one meaning)."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                            f"{cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_MS_BUCKETS
                  ) -> Histogram:
        return self._get(name, Histogram, buckets)

    def absorb(self, prefix: str, stats: Dict[str, Any]) -> None:
        """Hoover every numeric entry of a ``stats()`` dict into gauges
        named ``<prefix>.<key>`` (non-numeric values — mode strings, level
        tuples — are skipped; record those explicitly if they matter)."""
        for k, v in stats.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            self.gauge(f"{prefix}.{k}").set(v)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able {name: metric snapshot}, name-sorted — the ``metrics``
        block of the schema-v4 bench envelope."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, default=str)


_GLOBAL: Optional[MetricsRegistry] = None


def registry() -> MetricsRegistry:
    """The process-wide default registry (created on first use)."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = MetricsRegistry()
    return _GLOBAL


def reset_registry() -> MetricsRegistry:
    """Replace the process-wide registry with a fresh one (tests)."""
    global _GLOBAL
    _GLOBAL = MetricsRegistry()
    return _GLOBAL
