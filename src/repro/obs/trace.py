"""Span tracer — Chrome ``trace_event`` timelines for the serving stack.

One tracer serves two clocks:

* **Wall clock** (default): ``span(name, **attrs)`` as a context manager,
  or explicit ``begin``/``end`` around async stages. The engines emit
  per-step ``plan``/``stage`` spans and the ``StepPipeline`` emits
  ``dispatch``/``complete`` spans around its phases — where a step's wall
  time actually goes.
* **Virtual clock**: every API takes an explicit ``t_ms`` override. The
  traffic harness stamps spans with its deterministic replay timestamps —
  per-step ``plan``/``stage``/``dispatch``/``complete`` spans keyed by the
  ``StepReport``, and per-request lifecycle spans stitched from the
  Scheduler event stream — so the exported trace is byte-identical at any
  pipeline depth (PR-8's timestamp guarantee, now visible in Perfetto).

Export targets:

* :meth:`Tracer.chrome_trace` / :meth:`write_chrome_trace` — Chrome
  ``trace_event`` JSON (the ``{"traceEvents": [...]}`` envelope), loadable
  in Perfetto / ``chrome://tracing``. Tracks map to threads via
  ``thread_name`` metadata events.
* :meth:`Tracer.write_jsonl` — one closed span per line (name, track,
  start, duration, attrs) for ad-hoc grep/pandas analysis.

The hot path pays one attribute check when tracing is off: engines guard
emission with ``if tracer.enabled:`` and the default is the shared
:data:`NULL_TRACER` (an :class:`Tracer` subclass whose methods no-op).
Tracing must never perturb serving results — spans observe, they do not
reorder; the CI overhead guard asserts ``outputs_digest`` equality
between traced and untraced runs.

Span discipline is enforced: per track, ``begin``/``end`` must nest
(LIFO); mismatched or unbalanced ends raise. :func:`validate_chrome_trace`
re-checks an exported document (well-formed envelope, balanced B/E pairs,
monotonic per-track timestamps) — shared by the tests and the CI
trace-schema step.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "validate_chrome_trace"]


class _SpanCtx:
    """Context manager yielded by :meth:`Tracer.span` (wall clock)."""

    __slots__ = ("_tracer", "_name", "_track", "_attrs")

    def __init__(self, tracer, name, track, attrs):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._attrs = attrs

    def __enter__(self):
        self._tracer.begin(self._name, track=self._track, **self._attrs)
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tracer.end(self._name, track=self._track)
        return False


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_CTX = _NullCtx()


class Tracer:
    """Collects spans; exports Chrome trace JSON and a JSONL span log.

    ``enabled=False`` builds a tracer whose emit methods return
    immediately (same surface, zero events) — the per-call cost the
    engines pay is one attribute check plus, when they skip the check, a
    cheap early return."""

    enabled: bool

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._t0 = time.perf_counter()
        # chrome events in emission order: (ph, name, tid, ts_us, attrs)
        self._events: List[Tuple[str, str, int, float,
                                 Optional[Dict[str, Any]]]] = []
        self._tracks: Dict[str, int] = {}      # track name -> tid
        self._stacks: Dict[int, List[Tuple[str, float,
                                           Optional[Dict[str, Any]]]]] = {}
        self._spans: List[Dict[str, Any]] = []  # closed spans (JSONL log)

    # -- clock / track plumbing --------------------------------------------
    def _ts_us(self, t_ms: Optional[float]) -> float:
        if t_ms is not None:
            return float(t_ms) * 1e3
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = self._tracks[track] = len(self._tracks) + 1
        return tid

    # -- emission -----------------------------------------------------------
    def begin(self, name: str, track: str = "main",
              t_ms: Optional[float] = None, **attrs: Any) -> None:
        """Open a span on ``track`` (wall clock, or at virtual ``t_ms``)."""
        if not self.enabled:
            return
        ts = self._ts_us(t_ms)
        tid = self._tid(track)
        a = attrs or None
        self._events.append(("B", name, tid, ts, a))
        self._stacks.setdefault(tid, []).append((name, ts, a))

    def end(self, name: Optional[str] = None, track: str = "main",
            t_ms: Optional[float] = None) -> None:
        """Close the innermost span on ``track``; ``name``, when given,
        must match it (spans nest — the ordering invariant the tests
        assert)."""
        if not self.enabled:
            return
        tid = self._tid(track)
        stack = self._stacks.get(tid)
        if not stack:
            raise ValueError(f"end({name!r}) on track {track!r} with no "
                             f"open span")
        top, ts0, attrs = stack.pop()
        if name is not None and name != top:
            stack.append((top, ts0, attrs))
            raise ValueError(f"end({name!r}) does not match open span "
                             f"{top!r} on track {track!r} (spans nest)")
        ts = self._ts_us(t_ms)
        if ts < ts0 - 1e-9:
            stack.append((top, ts0, attrs))
            raise ValueError(f"span {top!r} on track {track!r} ends at "
                             f"{ts}us before it began at {ts0}us")
        self._events.append(("E", top, tid, ts, None))
        self._spans.append({"name": top, "track": track,
                            "ts_ms": ts0 / 1e3,
                            "dur_ms": (ts - ts0) / 1e3,
                            "attrs": attrs or {}})

    def instant(self, name: str, track: str = "main",
                t_ms: Optional[float] = None, **attrs: Any) -> None:
        """Zero-duration marker (Chrome ``i`` event)."""
        if not self.enabled:
            return
        self._events.append(("i", name, self._tid(track),
                             self._ts_us(t_ms), attrs or None))

    def span(self, name: str, track: str = "main", **attrs: Any):
        """Wall-clock span context manager (``with tracer.span("plan"):``).
        Disabled tracers return a shared no-op context."""
        if not self.enabled:
            return _NULL_CTX
        return _SpanCtx(self, name, track, attrs)

    # -- introspection ------------------------------------------------------
    @property
    def event_count(self) -> int:
        return len(self._events)

    @property
    def span_log(self) -> List[Dict[str, Any]]:
        """Closed spans in completion order (the JSONL payload)."""
        return list(self._spans)

    def open_spans(self) -> List[str]:
        return [name for stack in self._stacks.values()
                for name, _, _ in stack]

    # -- export -------------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome ``trace_event`` JSON document (Perfetto-loadable).
        Raises if any span is still open — an unbalanced trace would fail
        its own validator."""
        still_open = self.open_spans()
        if still_open:
            raise ValueError(f"cannot export with open spans: {still_open}")
        events: List[Dict[str, Any]] = []
        for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tid, "args": {"name": track}})
        for ph, name, tid, ts, attrs in self._events:
            ev: Dict[str, Any] = {"ph": ph, "name": name, "pid": 1,
                                  "tid": tid, "ts": ts}
            if ph == "i":
                ev["s"] = "t"
            if attrs:
                ev["args"] = attrs
            events.append(ev)
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, default=str)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self._spans:
                f.write(json.dumps(rec, default=str) + "\n")


class NullTracer(Tracer):
    """The disabled default: same surface, no storage, no clock reads.
    Engines keep a ``self.tracer`` unconditionally and guard hot-path
    emission with ``if self.tracer.enabled:`` — one attribute check."""

    def __init__(self):
        # deliberately NOT calling super().__init__: no clock read, no
        # buffers — a NullTracer is free to construct and share
        self.enabled = False

    def begin(self, name, track="main", t_ms=None, **attrs):
        pass

    def end(self, name=None, track="main", t_ms=None):
        pass

    def instant(self, name, track="main", t_ms=None, **attrs):
        pass

    def span(self, name, track="main", **attrs):
        return _NULL_CTX

    @property
    def event_count(self) -> int:
        return 0

    @property
    def span_log(self):
        return []

    def open_spans(self):
        return []

    def chrome_trace(self):
        return {"displayTimeUnit": "ms", "traceEvents": []}


NULL_TRACER = NullTracer()


def validate_chrome_trace(doc: Any) -> Dict[str, int]:
    """Validate a Chrome ``trace_event`` document: well-formed envelope,
    required event fields, balanced B/E pairs per track (stack
    discipline), and monotonic (non-decreasing) per-track timestamps in
    emission order. Returns summary counts; raises ``ValueError`` on the
    first violation. Shared by the tests and the CI trace-schema step."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a trace_event document: missing traceEvents")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    stacks: Dict[Tuple[Any, Any], List[str]] = {}
    last_ts: Dict[Tuple[Any, Any], float] = {}
    n_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for field in ("ph", "name", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i}: missing {field!r}")
        ph = ev["ph"]
        if ph == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"event {i}: missing 'ts'")
        key = (ev["pid"], ev["tid"])
        ts = float(ev["ts"])
        if ts < last_ts.get(key, -float("inf")) - 1e-9:
            raise ValueError(f"event {i}: track {key} timestamp {ts} "
                             f"decreases (last {last_ts[key]})")
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(f"event {i}: E {ev['name']!r} on track "
                                 f"{key} with no open B")
            top = stack.pop()
            if top != ev["name"]:
                raise ValueError(f"event {i}: E {ev['name']!r} does not "
                                 f"match open B {top!r} on track {key}")
            n_spans += 1
        elif ph not in ("i", "I", "X", "C"):
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
    unbalanced = {k: v for k, v in stacks.items() if v}
    if unbalanced:
        raise ValueError(f"unbalanced B events: {unbalanced}")
    return {"events": len(events), "spans": n_spans,
            "tracks": len(last_ts)}
