"""AdamW (decoupled weight decay) — built from scratch, pytree-native.

Matches the paper's fine-pruning recipe defaults (lr 2e-5, wd 0.01) and is
the optimizer for all LM training paths. State is a pytree mirroring params,
so it shards with the same PartitionSpecs (ZeRO-style sharding falls out of
placing the data axis on the state; see dist/sharding.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


class AdamW(NamedTuple):
    lr: Callable[[jax.Array], jax.Array] | float = 2e-5
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        if self.grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            decay = self.weight_decay * p if p.ndim >= 2 else 0.0
            return p - lr * (delta + decay)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
