"""Gradient compression for the data-parallel axis: int8 quantization with
error feedback (EF-SGD style residual accumulation).

At 2+ pods the DP all-reduce crosses the pod interconnect; 4× smaller grads
cut that collective's bytes 4×. Error feedback keeps the quantization
noise from biasing convergence: the residual (g - dequant(quant(g))) is
added back into the next step's gradient.

Usage: wrap the gradient tree between value_and_grad and the optimizer
update — ``compressed, state = compress(grads, state)`` on each host, then
all-reduce the int8 payload (XLA does this when the arrays participate in
psum with their int8 dtype cast back after; here we expose the quant/dequant
pair and the train loop chooses where the collective happens).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # pytree like grads


def init_ef_state(grads_like: Any) -> EFState:
    return EFState(jax.tree.map(jnp.zeros_like, grads_like))


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale


def compress_grads(grads: Any, state: EFState
                   ) -> Tuple[Any, Any, EFState]:
    """Returns (quantized tree, scales tree, new EF state)."""
    def one(g, r):
        g = g + r
        q, s = quantize(g)
        deq = dequantize(q, s, g.dtype)
        return q, s, g - deq

    qs = jax.tree.map(one, grads, state.residual)
    # unzip the 3-tuples
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    r_tree = jax.tree.map(lambda t: t[2], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return q_tree, s_tree, EFState(r_tree)


def decompress_grads(q_tree: Any, s_tree: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda q, s: dequantize(q, s, dtype), q_tree, s_tree)
