from repro.serving.engine import (ServeEngine, EngineConfig, Request,
                                  prune_kv_caches)

__all__ = ["ServeEngine", "EngineConfig", "Request", "prune_kv_caches"]
