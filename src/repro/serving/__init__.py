"""Layered serving API.

``Scheduler`` (admission policy) / ``KVCacheManager`` (per-slot cache
state) / ``ModelRunner`` (jitted steps + compile cache) compose into
``ServeEngine``; ``prune_kv_caches`` is the standalone KV compaction.
"""
from repro.serving.cache_manager import (KVCacheManager, bucket_length,
                                         prune_kv_caches)
from repro.serving.engine import (ElasticContext, EngineConfig, Request,
                                  ServeEngine)
from repro.serving.runner import ModelRunner, build_padded_batch
from repro.serving.scheduler import Scheduler

__all__ = ["ServeEngine", "EngineConfig", "ElasticContext", "Request",
           "Scheduler", "KVCacheManager", "ModelRunner", "prune_kv_caches",
           "bucket_length", "build_padded_batch"]
