"""Layered serving API.

LM path: ``Scheduler`` (admission policy) / ``KVCacheManager`` (per-slot
cache state) / ``ModelRunner`` (jitted steps + compile cache) compose into
``ServeEngine``; ``prune_kv_caches`` is the standalone KV compaction.

Vision path: the same ``Scheduler`` + ``TilePlanner`` (cost-model-driven
execution planning over the ``RaggedBatcher``'s token-count buckets:
bucket merging, express-lane fusion, deadline-aware tiling; owns the
``QualityController`` that resolves per-request keep schedules under
load) + ``core.packed_runner.PackedVitSegments`` compose into
``VisionEngine`` —
continuous-batching inference for the packed, simultaneously-pruned ViT.

Both engines drive their step loops through the ``StepPipeline``
(``repro.serving.pipeline``): steps are staged (plan + input buffers),
dispatched asynchronously, and completed (blocked + materialized) as
separate phases, so at ``pipeline_depth`` 2 the host plans and stages step
N+1 while the device executes step N. Depth 1 reproduces the synchronous
path step for step.
"""
from repro.serving.cache_manager import (KVCacheManager, bucket_length,
                                         prune_kv_caches)
from repro.serving.engine import (ElasticContext, EngineConfig, Request,
                                  ServeEngine)
from repro.serving.pipeline import StagedStep, StepPipeline, StepReport
from repro.serving.planner import (PLANNER_MODES, ExecutionPlan, FusedLane,
                                   PlanItem, PlanStats, TileCostModel,
                                   TilePlanner)
from repro.serving.quality import (QUALITY_MODES, QualityConfig,
                                   QualityController)
from repro.serving.ragged_batcher import RaggedBatcher, Tile
from repro.serving.runner import ModelRunner, build_padded_batch
from repro.serving.scheduler import Scheduler
from repro.serving.vision import (VisionEngine, VisionEngineConfig,
                                  VisionRequest)

__all__ = ["ServeEngine", "EngineConfig", "ElasticContext", "Request",
           "Scheduler", "KVCacheManager", "ModelRunner", "prune_kv_caches",
           "bucket_length", "build_padded_batch",
           "StepPipeline", "StagedStep", "StepReport",
           "VisionEngine", "VisionEngineConfig", "VisionRequest",
           "RaggedBatcher", "Tile",
           "TilePlanner", "TileCostModel", "ExecutionPlan", "PlanItem",
           "FusedLane", "PlanStats", "PLANNER_MODES",
           "QualityController", "QualityConfig", "QUALITY_MODES"]
