from repro.serving.engine import (ServeEngine, EngineConfig, ElasticContext,
                                  Request, prune_kv_caches)

__all__ = ["ServeEngine", "EngineConfig", "ElasticContext", "Request",
           "prune_kv_caches"]
