"""KVCacheManager — owns per-slot serve-cache state and its lifecycle.

One of the three serving layers (Scheduler / KVCacheManager / ModelRunner —
see ``repro.serving.engine``). The manager holds the live device cache
pytree plus host mirrors of each slot's ``length`` (cache-buffer write
position) and ``valid_start`` (first real entry — everything before it is
left-padding or compacted-cache garbage). It decides capacity (admission
high-water checks, decode overflow) and runs the dynamic KV-prune cadence;
it never runs model math — the ModelRunner produces the cache contents the
manager accounts for.

Admission granularity is a *prefix-length bucket*: ``admit(slot,
prompt_len)`` rounds the prompt up to the next power-of-two bucket (capped
at ``max_len``) so the jitted per-slot prefill compiles once per bucket,
not once per prompt length.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import token_pruning as TP
from repro.models import attention as A
from repro.models import steps as ST


def bucket_length(n: int, cap: int, lo: int = 8) -> int:
    """Round ``n`` up to the next power-of-two bucket in [lo, cap]."""
    b = max(int(lo), 1)
    while b < n:
        b *= 2
    return min(b, cap)


class KVCacheManager:
    """Per-slot cache bookkeeping for one engine's ``max_batch`` slots.

    ``ec`` is an ``EngineConfig`` (duck-typed to avoid an import cycle with
    ``engine.py``): max_batch / max_len / kv_prune_interval / kv_prune_keep
    / prefill_bucket_min are read from it.
    """

    def __init__(self, cfg, ec):
        self.cfg = cfg
        self.ec = ec
        self.masked = cfg.family in ST.MASKABLE_FAMILIES
        self.caches: Any = None
        B = ec.max_batch
        self.lengths = np.zeros((B,), np.int64)   # mirrors device length
        self.starts = np.zeros((B,), np.int32)    # mirrors valid_start
        self.active = np.zeros((B,), bool)
        self.steps_since_prune = 0
        self.prune_events = 0

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        """Fresh zeroed caches for all slots; prune cadence restarts."""
        self.caches = ST.init_caches(self.cfg, self.ec.max_batch,
                                     self.ec.max_len)
        self.lengths[:] = 0
        self.starts[:] = 0
        self.active[:] = False
        self.steps_since_prune = 0

    def admit(self, slot: int, prompt_len: int,
              max_new_tokens: int = 0) -> Tuple[int, int]:
        """Account slot ``slot`` as holding a prompt of ``prompt_len`` real
        tokens. Returns ``(bucket_len, valid_start)``: the bucketed row
        width the runner must prefill at and the left-pad depth within it.
        Raises up-front when the slot's own high-water mark cannot fit
        (decidable only with KV pruning off)."""
        ec = self.ec
        if prompt_len > ec.max_len:
            raise RuntimeError(
                f"prompt of {prompt_len} tokens exceeds max_len={ec.max_len}")
        lb = bucket_length(prompt_len, ec.max_len, ec.prefill_bucket_min)
        # bucket padding must never turn a feasible request infeasible:
        # when the padded row would consume the decode headroom, fall back
        # to the largest bucket that fits — or the raw prompt length (costs
        # at most one extra jit shape, and only for prompts within a
        # bucket's padding of capacity)
        if self.pruning_enabled:
            # pruning bounds the cache dynamically, but only once it FIRES:
            # leave room to decode until the first compaction can fire — up
            # to (keep − prompt) steps growing to the keep target plus a
            # full cadence interval before the tick lands
            keep = max(1, min(int(ec.max_len * ec.kv_prune_keep),
                              ec.max_len))
            budget = ec.max_len - (max(0, keep - prompt_len)
                                   + ec.kv_prune_interval)
        else:
            budget = ec.max_len - max(max_new_tokens - 1, 0)
        if lb > budget:
            b = 1
            while b * 2 <= budget:
                b *= 2
            lb = b if b >= prompt_len else prompt_len
        self.check_capacity(lb + max_new_tokens - 1)
        start = lb - prompt_len
        self.lengths[slot] = lb
        self.starts[slot] = start
        self.active[slot] = True
        return lb, start

    def free(self, slot: int) -> None:
        """Slot retired; its device row is garbage until the next admit
        overwrites it (decode keeps advancing it harmlessly — outputs of
        inactive rows are never read)."""
        self.active[slot] = False

    def snapshot(self) -> Tuple:
        """Capture the full manager state for the pipelined engine's stage
        rollback: a step staged then dropped (mid-step admission forces a
        replan) must leave no trace — counters, host mirrors, and the cache
        binding all return to their pre-stage values. The device cache
        pytree is captured by *reference*: stage-time ops (``maybe_prune``)
        REBIND ``self.caches`` to new arrays and never mutate buffers in
        place, so the old handle stays valid exactly until a dispatch
        donates it — and dropped steps never dispatch."""
        return (self.caches, self.lengths.copy(), self.starts.copy(),
                self.active.copy(), self.steps_since_prune,
                self.prune_events)

    def restore(self, snap: Tuple) -> None:
        """Inverse of :meth:`snapshot` (mirror arrays keep their identity —
        callers hold views)."""
        caches, lengths, starts, active, since, events = snap
        self.caches = caches
        self.lengths[:] = lengths
        self.starts[:] = starts
        self.active[:] = active
        self.steps_since_prune = since
        self.prune_events = events

    def set_batch_state(self, lengths, starts) -> None:
        """Adopt mirrors after a whole-batch (re-)prefill replaced every
        row at once (fallback path: recurrent families, elastic rebuild)."""
        self.lengths[:] = np.asarray(lengths)
        self.starts[:] = np.asarray(starts) if starts is not None else 0
        self.steps_since_prune = 0  # fresh caches, fresh cadence

    # -- capacity ----------------------------------------------------------
    @property
    def pruning_enabled(self) -> bool:
        return self.ec.kv_prune_interval > 0 and self.ec.kv_prune_keep < 1.0

    def check_capacity(self, high_water: int) -> None:
        """Reject up-front a workload whose cache high-water mark cannot
        fit. Only decidable when KV pruning is off — pruning bounds the
        cache dynamically, so pruned runs rely on ``on_decode``."""
        if not self.pruning_enabled and high_water > self.ec.max_len:
            raise RuntimeError(
                f"max_len={self.ec.max_len} cannot hold {high_water} tokens "
                "(prefix + remaining decode); raise EngineConfig.max_len")

    def on_decode(self) -> None:
        """Account one decode step: every row's write position advances by
        one (the batched decode touches all rows). Raises before an active
        slot would write past the cache buffer."""
        over = self.active & (self.lengths >= self.ec.max_len)
        if over.any():
            slot = int(np.argmax(over))
            raise RuntimeError(
                f"KV cache overflow: decode step would write at "
                f"{int(self.lengths[slot])} >= max_len={self.ec.max_len} "
                f"(slot {slot})")
        self.lengths += 1

    def valid_starts(self) -> Optional[jax.Array]:
        """Per-slot valid_start for the next device call (None when the
        family cannot mask left-padding)."""
        return jnp.asarray(self.starts) if self.masked else None

    # -- dynamic KV pruning ------------------------------------------------
    def maybe_prune(self) -> bool:
        """Compact the caches when the cadence fires and they have outgrown
        the keep target. Returns True when a prune ran."""
        ec = self.ec
        if not self.pruning_enabled:
            return False
        keep = max(1, min(int(ec.max_len * ec.kv_prune_keep), ec.max_len))
        self.steps_since_prune += 1
        # gauge growth by REAL tokens of ACTIVE slots (write position minus
        # left-padding): buffer positions depend on bucket/padding geometry,
        # and freed slots keep advancing with every batched decode — keying
        # the cadence on either would make prune timing admission-path- or
        # retirement-history-dependent instead of workload-dependent
        act = self.active
        n_real = (int((self.lengths[act] - self.starts[act]).max())
                  if act.any() else 0)
        if self.steps_since_prune < ec.kv_prune_interval or n_real < keep:
            return False
        self.steps_since_prune = 0
        self.prune_events += 1
        starts = self.valid_starts()
        self.caches, new_starts = prune_kv_caches(
            self.caches, ec.kv_prune_keep, starts=starts)
        self.lengths[:] = keep
        if self.masked and new_starts is not None:
            self.starts[:] = np.asarray(new_starts)
        return True


def prune_kv_caches(caches: Any, keep_frac: float,
                    starts: Optional[jax.Array] = None) -> Tuple[Any, Any]:
    """Compact every KVCache to its top-``keep_frac`` attention-mass slots.

    Stacked caches ([L, ...]) are handled with vmap. ``starts`` ([B] int32)
    marks per-slot left-padding; pad slots score ``-inf`` and are never kept
    ahead of real tokens. Kept entries are packed so each slot's valid
    window ends at ``keep``: when a slot has fewer than ``keep`` valid
    entries, the (zeroed) garbage sits at the *front*, which the returned
    ``new_starts`` ([B] int32) masks — the compacted cache is left-padded
    exactly like the prompts were. ``length`` becomes ``min(length, keep)``
    per slot and attention mass resets (so the ranking adapts as decoding
    proceeds).

    Returns ``(pruned_caches, new_starts)``.
    """
    def one(c):
        if not isinstance(c, A.KVCache):
            return c  # recurrent state (ssm/mamba) passes through untouched

        def single(k, v, length, mass):
            n = k.shape[1]
            keep = max(1, min(int(n * keep_frac), n))
            scores = TP.kv_prune_scores(mass, length, start=starts)
            idx = TP.select_kv_keep(scores, keep, invalid_first=True)
            k2, v2 = TP.compact_kv_cache(k, v, idx)
            # zero the invalid (garbage) prefix each slot may carry
            n_valid = jnp.clip(
                length - (starts if starts is not None else 0), 0, keep)
            pos = jnp.arange(keep)
            valid = pos[None, :] >= (keep - n_valid)[..., None]
            k2 = jnp.where(valid[..., None, None], k2, 0)
            v2 = jnp.where(valid[..., None, None], v2, 0)
            k_new = jnp.zeros_like(k).at[:, :keep].set(k2)
            v_new = jnp.zeros_like(v).at[:, :keep].set(v2)
            new_len = jnp.full_like(length, keep)
            new_mass = jnp.zeros_like(mass)
            return A.KVCache(k_new, v_new, new_len, new_mass)

        if c.k.ndim == 5:  # stacked [L, B, S, KV, Dh]
            return jax.vmap(single)(c.k, c.v, c.length, c.attn_mass)
        return single(c.k, c.v, c.length, c.attn_mass)

    is_kv = lambda x: isinstance(x, A.KVCache)
    pruned = jax.tree.map(one, caches, is_leaf=is_kv)
    kv_leaves = [l for l in jax.tree_util.tree_leaves(caches, is_leaf=is_kv)
                 if isinstance(l, A.KVCache)]
    if not kv_leaves:  # pure recurrent state: nothing compacted
        return pruned, starts
    # analytic per-slot garbage prefix — identical for every layer because
    # it depends only on length/starts/keep, not the per-layer attn mass
    first = kv_leaves[0]
    n = first.k.shape[-3]
    keep = max(1, min(int(n * keep_frac), n))
    base = (starts if starts is not None
            else jnp.zeros((first.k.shape[-4],), jnp.int32))
    # per-slot lengths are uniform across layers — take the first layer's
    lens = first.length.reshape(-1, first.length.shape[-1])[0]
    n_valid = jnp.clip(lens - base, 0, keep)
    new_starts = (keep - n_valid).astype(jnp.int32)
    return pruned, new_starts
