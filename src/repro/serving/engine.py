"""ServeEngine — thin composition of the three serving layers.

Layers (each separately constructible and testable):

* ``Scheduler``      (``repro.serving.scheduler``) — admission/retirement
  policy over waiting + in-flight requests. FIFO by default,
  policy-pluggable. Owns the unified event stream: both serve paths emit
  the same ``("admit", uid)`` / ``("retire", uid)`` / ``("degrade", desc)``
  events through it.
* ``KVCacheManager`` (``repro.serving.cache_manager``) — owns per-slot
  cache state: the live device cache pytree, per-slot ``length`` /
  ``valid_start`` mirrors, prefix-length bucketing, capacity accounting
  (admission high-water checks + decode overflow), and the dynamic
  KV-prune cadence (``admit`` / ``free`` / ``maybe_prune``).
* ``ModelRunner``    (``repro.serving.runner``) — owns the jitted steps
  (whole-batch prefill, per-slot prefill, decode) behind a compile cache;
  recompiles are observable via ``runner.compile_count``.

Serve paths
-----------
* ``serve(requests)`` / ``run``          — static waves: up to
  ``max_batch`` requests prefill together and decode in lockstep until the
  longest request finishes.
* ``serve(requests, continuous=True)`` / ``run_continuous`` — continuous
  batching with ``max_batch`` fixed decode slots. Admission prefills ONLY
  the admitted prompt: ``ModelRunner.prefill_slot`` runs a B=1 prefill of
  the (bucket-padded) prompt and scatters the row into the admitted slot
  of the live batched cache, so admission cost is one prompt — independent
  of how many slots are active — and prefix-length bucketing bounds jit
  recompiles to one per bucket. Families whose serve state is not pure KV
  cache (recurrent ssm/hybrid) fall back to the PR-2 whole-batch
  re-prefill.

Per-slot cache geometry: every ``KVCache.length`` is ``[B]`` — each row
reads/writes at its own position, and RoPE phases count *real* tokens
(cache slot − ``valid_start``), which makes per-slot prefill bit-exact
against whole-batch left-padded prefill. Left-padding is masked wherever it
matters: attention and the KV ``attn_mass`` accumulation both exclude
positions before ``valid_start``, so pad slots never compete with real
tokens — neither in attention nor in KV-cache pruning.

The continuous path is driven through the ``StepPipeline``
(``repro.serving.pipeline``): each step is staged (host bookkeeping,
snapshot-protected so a mid-step submission can drop and restage it),
dispatched asynchronously (the device calls chain through pending cache and
next-token handles; the jitted steps donate their cache argument so XLA
reuses the buffers in place), and completed (token values materialize into
``req.generated``) as separate phases. ``EngineConfig.pipeline_depth`` 2
overlaps the host's staging of step N+1 with the device executing step N;
depth 1 reproduces the synchronous loop step for step.

KV pruning is the paper's token-scoring adapted to autoregressive decode:
attention mass accumulated per cached token ranks cache entries; every
``kv_prune_interval`` steps the KVCacheManager compacts each layer's cache
to the top ``kv_prune_keep`` fraction. This bounds decode memory *and* the
per-step attention read.

Elastic degradation (ROADMAP repro.dist): construct the engine with an
``ElasticContext`` and the continuous path probes ``device_count()`` every
step. On device loss it walks ``dist.elastic.degradation_path`` to the
first plan that fits, re-shards the weights via
``CheckpointManager.restore(..., shardings=...)``, emits a ``degrade``
event through the Scheduler, and tells the KVCacheManager to rebuild —
in-flight requests are re-prefilled on the new mesh, no request is
dropped.

``run`` and ``run_continuous`` are kept as compatibility wrappers over
``serve`` (same signatures, identical outputs); new code should construct
the layers through ``ServeEngine`` and call ``serve``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.elastic import MeshPlan, degradation_path, first_fit
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.cache_manager import KVCacheManager, prune_kv_caches
from repro.serving.pipeline import StagedStep, StepPipeline, StepReport
from repro.serving.runner import ModelRunner, build_padded_batch
from repro.serving.scheduler import Scheduler

__all__ = ["Request", "EngineConfig", "ElasticContext", "ServeEngine",
           "prune_kv_caches"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    prune_load: Optional[float] = None  # predicted post-prune token load
    # (set at submit when KV pruning is on; the prune_pressure_aware
    # admission policy reads it — see serving.scheduler)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8          # wave width / continuous decode slots
    max_len: int = 512
    kv_prune_interval: int = 0   # 0 = off
    kv_prune_keep: float = 1.0
    per_slot_prefill: bool = True   # False: PR-2 whole-batch re-prefill
    prefill_bucket_min: int = 8     # smallest prefix-length bucket
    pipeline_depth: int = 1     # StepPipeline depth: 1 = synchronous,
    # 2 = double-buffered (the host stages step N+1 — admission
    # accounting, prune cadence, decode bookkeeping — while the device
    # executes step N; tokens and events bit-exact at any depth)

    def __post_init__(self):
        if self.max_batch <= 0:
            raise ValueError(
                f"EngineConfig.max_batch must be a positive slot count, "
                f"got {self.max_batch}")
        if self.max_len <= 0:
            raise ValueError(
                f"EngineConfig.max_len must be a positive cache capacity "
                f"(tokens), got {self.max_len}")
        if not (0.0 < self.kv_prune_keep <= 1.0):
            raise ValueError(
                f"EngineConfig.kv_prune_keep must be in (0, 1] — the "
                f"fraction of cache entries kept per prune — got "
                f"{self.kv_prune_keep}")
        if self.kv_prune_interval < 0:
            raise ValueError(
                f"EngineConfig.kv_prune_interval must be >= 0 (decode "
                f"steps between prunes; 0 disables pruning), got "
                f"{self.kv_prune_interval}")
        if self.prefill_bucket_min <= 0:
            raise ValueError(
                f"EngineConfig.prefill_bucket_min must be a positive "
                f"bucket width, got {self.prefill_bucket_min}")
        if self.pipeline_depth <= 0:
            raise ValueError(
                f"EngineConfig.pipeline_depth must be >= 1 (1 = "
                f"synchronous stepping), got {self.pipeline_depth}")


@dataclasses.dataclass
class ElasticContext:
    """Everything the continuous path needs to survive simulated device
    loss.

    ``manager`` must hold a checkpoint of the engine's params (saved by the
    launcher before serving starts); ``device_count`` is the live-capacity
    probe the engine polls between steps (tests inject losses through it).
    """
    manager: Any                      # CheckpointManager with the weights
    plan: MeshPlan                    # healthy mesh plan
    budgets: Sequence[int]            # degradation_path device budgets
    device_count: Callable[[], int]   # live device probe
    step: Optional[int] = None        # checkpoint step (None = latest)


class ServeEngine:
    """Single-host reference engine (the multi-pod serve path lowers the
    same step functions through launch/serve.py). Construction wires the
    three layers; they are exposed as ``.scheduler`` / ``.cache`` /
    ``.runner`` for tests, policies, and telemetry."""

    def __init__(self, cfg: ModelConfig, params: Any, ec: EngineConfig,
                 elastic: Optional[ElasticContext] = None,
                 policy: "str | Callable" = "fifo",
                 tracer: Optional[Tracer] = None):
        self.cfg = cfg
        self.ec = ec
        self.elastic = elastic
        self.runner = ModelRunner(cfg, params)
        self.cache = KVCacheManager(cfg, ec)
        self.scheduler = Scheduler(ec.max_batch, policy=policy)
        # wall-clock span tracer (repro.obs): plan/stage spans here, the
        # pipeline adds dispatch/complete; disabled default costs one
        # attribute check per guarded region
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.pipeline = StepPipeline(ec.pipeline_depth, tracer=self.tracer)
        self._plan = elastic.plan if elastic is not None else None
        # padded tokens run through prefill at admissions (and rebuilds)
        self.admission_prefill_tokens = 0
        # pipelined continuous-path state: the device-resident next-token
        # vector chained step to step, and the host-side count of tokens
        # DISPATCHED per request uid (>= len(req.generated) until the
        # pipeline completes the step) — retirement is decided from the
        # counts, so slot reuse never blocks on in-flight device work
        self._toks: Any = None
        self._scheduled: Dict[int, int] = {}

    # -- compatibility surface (PR-2 attribute names) ----------------------
    @property
    def params(self):
        return self.runner.params

    @params.setter
    def params(self, value):
        self.runner.params = value

    @property
    def events(self):
        """The Scheduler's unified event stream (a bounded
        ``repro.obs.events.EventLog`` ring; iterate or slice with
        absolute indices)."""
        return self.scheduler.events

    @property
    def prune_events(self) -> int:
        return self.cache.prune_events

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Deprecated alias for ``serve(requests)`` (static waves)."""
        return self.serve(requests, continuous=False)

    def run_continuous(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Deprecated alias for ``serve(requests, continuous=True)``."""
        return self.serve(requests, continuous=True)

    # -- public API --------------------------------------------------------
    def serve(self, requests: List[Request],
              continuous: bool = False) -> Dict[int, List[int]]:
        self._annotate_prune_load(requests)
        if continuous:
            return self._serve_continuous(requests)
        out: Dict[int, List[int]] = {}
        for ws in range(0, len(requests), self.ec.max_batch):
            out.update(self._run_wave(requests[ws: ws + self.ec.max_batch]))
        return out

    def stats(self) -> Dict[str, Any]:
        adm = self.scheduler.num_admissions
        return {
            "admissions": adm,
            "admission_prefill_tokens": self.admission_prefill_tokens,
            "prefill_tokens_per_admission":
                self.admission_prefill_tokens / adm if adm else 0.0,
            "compile_count": self.runner.compile_count,
            "jit_compile_count": self.runner.jit_compile_count(),
            "prune_events": self.cache.prune_events,
            **{f"sched_{k}": v for k, v in self.scheduler.stats().items()},
            **{f"pipeline_{k}": v for k, v in self.pipeline.stats().items()},
        }

    def export_metrics(self, registry: MetricsRegistry,
                       prefix: str = "lm") -> MetricsRegistry:
        """Fold this engine's observable state into ``registry``: every
        numeric ``stats()`` entry (compile ledger, prefill amortization,
        KV prunes, scheduler backlog, pipeline overlap/starvation) as a
        ``<prefix>.<key>`` gauge."""
        registry.absorb(prefix, self.stats())
        return registry

    def _annotate_prune_load(self, requests: List[Request]) -> None:
        """Predicted post-prune token load for the prune_pressure_aware
        admission policy: the request's KV footprint (prompt + generation)
        discounted by the dynamic KV-prune keep rate. Engines own this
        prediction so the Scheduler stays model-agnostic."""
        keep = self.ec.kv_prune_keep if self.ec.kv_prune_interval else 1.0
        for r in requests:
            if getattr(r, "prune_load", None) is None:
                r.prune_load = (len(r.prompt) + r.max_new_tokens) * keep

    # -- static-wave path --------------------------------------------------
    def _run_wave(self, wave: List[Request]) -> Dict[int, List[int]]:
        sched, kvm, runner = self.scheduler, self.cache, self.runner
        max_new = max(r.max_new_tokens for r in wave)
        sched.submit(wave)
        admitted = sched.schedule()  # every slot free: the whole wave fits
        toks = np.zeros((self.ec.max_batch,), np.int64)

        if runner.supports_slot_prefill and self.ec.per_slot_prefill:
            kvm.reset()  # the fallback path allocates inside its prefill
            for slot, req in admitted:
                lb, _ = kvm.admit(slot, len(req.prompt), max_new)
                tok, kvm.caches = runner.prefill_slot(
                    np.asarray(req.prompt, np.int32), kvm.caches, slot, lb)
                toks[slot] = tok
                self.admission_prefill_tokens += lb
        else:
            toks = self._prefill_whole_batch(max_new)

        out: Dict[int, List[int]] = {}
        self._append_and_retire(toks, sched.running.keys(), out)
        while sched.running:
            kvm.maybe_prune()
            kvm.on_decode()
            tok_dev, kvm.caches = runner.decode(toks, kvm.caches,
                                                kvm.valid_starts())
            toks = np.asarray(tok_dev).astype(np.int64)
            self._append_and_retire(toks, sched.running.keys(), out)
        return out

    # -- continuous-batching path ------------------------------------------
    def _serve_continuous(self, requests: List[Request]
                          ) -> Dict[int, List[int]]:
        """``max_batch`` decode slots with per-request admission, driven
        through the ``StepPipeline``. Each step produces at most one token
        per slot: per-slot prefill for slots admitted this step, or one
        batched decode step for the slots already live. Steps are *staged*
        (admission accounting, prune cadence, decode bookkeeping — all
        host mutations, snapshot-protected), *dispatched* (the device
        calls, chaining the pending next-token vector and cache handles)
        and *completed* (token values materialize into ``req.generated``)
        as separate phases, so at depth 2 the host stages step N+1 while
        the device executes step N. Which step finishes a request is
        host-known at dispatch time (one token per produced slot), so
        retirement and slot reuse never block on in-flight device work.
        Depth 1 reproduces the synchronous loop step for step — identical
        tokens, identical admit/retire/degrade event stream."""
        self.enqueue(requests)
        self.start_continuous()
        out: Dict[int, List[int]] = {}
        while True:
            rep = self.tick_continuous(out)
            if not rep.dispatched:
                break
        self.pipeline.flush()
        return out

    def enqueue(self, requests: Sequence[Request]) -> None:
        """Annotate + submit ``requests`` into the Scheduler (continuous
        path). External drivers (``repro.traffic.harness``) pair this with
        :meth:`start_continuous` / :meth:`tick_continuous` to interleave
        submission with stepping on their own clock; an installed
        ``Scheduler.admission_control`` hook gates each request here."""
        self._annotate_prune_load(list(requests))
        self.scheduler.submit(requests)

    def start_continuous(self) -> None:
        """Reset the continuous-serve step state (slot token vector,
        dispatched-token counts, rebuild flag) ahead of a
        :meth:`tick_continuous` loop."""
        if (self.runner.supports_slot_prefill
                and self.ec.per_slot_prefill):
            self.cache.reset()  # per-slot admissions write into live
            # caches; the fallback's whole-batch prefill allocates its own
        self._toks = np.zeros((self.ec.max_batch,), np.int64)
        self._scheduled = {}
        self._rebuild = False  # caches need a whole-batch re-prefill

    def tick_continuous(self, out: Dict[int, List[int]]) -> StepReport:
        """One continuous-batching step: retire dispatched-to-budget
        slots, admit waiting requests, stage + dispatch one step (per-slot
        prefills or a batched decode) through the pipeline. Mirrors
        ``VisionEngine.tick``: the returned :class:`StepReport` carries
        host-deterministic facts only (``work_tokens`` = prompt tokens
        prefilled + tokens decoded this step — the traffic harness prices
        them onto its virtual clock), identical at every pipeline depth."""
        sched, kvm, runner = self.scheduler, self.cache, self.runner
        use_slot = runner.supports_slot_prefill and self.ec.per_slot_prefill
        self._retire_scheduled()
        if not sched.has_work():
            return StepReport(dispatched=False)
        if self.elastic is not None:
            avail = self.elastic.device_count()
            if avail < self._plan.num_devices:
                # in-flight steps ran on the healthy mesh and their
                # outputs stay valid; drain them so every
                # req.generated is materialized before the rebuild
                # re-prefills prompt + generated-so-far
                self.pipeline.flush()
                self._degrade(avail)
                self._rebuild = True  # re-prefill on the degraded mesh
        prefill_mark = self.admission_prefill_tokens
        sched_mark = sum(self._scheduled.values())
        staged: Optional[StagedStep] = None
        admitted: List[Tuple[int, Request]] = []
        tr = self.tracer
        while True:
            sub_mark = sched.submitted_total
            if tr.enabled:
                tr.begin("plan", track="engine")
            admitted.extend(sched.schedule())
            if tr.enabled:
                tr.end("plan", track="engine")
            if self._rebuild or (admitted and not use_slot):
                break  # sync fallback below; nothing staged to drop
            if tr.enabled:
                tr.begin("stage", track="engine",
                         admissions=len(admitted))
            staged = (self._stage_admissions(admitted, out)
                      if admitted else self._stage_decode(out))
            if tr.enabled:
                tr.end("stage", track="engine")
            if sched.submitted_total == sub_mark:
                break
            # submitted while staging: drop + restage so the request
            # is considered for THIS step's admissions — it never
            # mutates a step already staged, and is never silently
            # deferred past a step boundary
            self.pipeline.drop(staged)
            staged = None
        if staged is not None:
            self.pipeline.submit(staged)
        else:
            # sync fallback (recurrent families, elastic rebuild): a
            # whole-batch/per-slot re-prefill replaces every cache row at
            # once from prompt + generated-so-far, so drain the pipeline
            # first, then account the rebuilt step synchronously
            self.pipeline.flush()
            toks = (self._rebuild_per_slot() if use_slot
                    else self._reprefill_active())
            self._rebuild = False
            produced = [(s, sched.running[s]) for s in sorted(sched.running)]
            for _, req in produced:
                self._scheduled[req.uid] = \
                    self._scheduled.get(req.uid, 0) + 1
            self._toks = toks
            self._complete_tokens(toks, produced, out)
        # which uids finished is host-known at dispatch time (one token
        # per produced slot); their values may still be in flight
        completed = tuple(sorted(
            req.uid for req in sched.running.values()
            if self._scheduled.get(req.uid, 0) >= req.max_new_tokens))
        return StepReport(
            dispatched=True,
            work_tokens=(self.admission_prefill_tokens - prefill_mark
                         + sum(self._scheduled.values()) - sched_mark),
            admitted=tuple(sorted(r.uid for _, r in admitted)),
            completed=completed)

    def _stage_admissions(self, admitted: List[Tuple[int, "Request"]],
                          out: Dict[int, List[int]]) -> StagedStep:
        """Stage one admission step: the capacity checks and mirror
        bookkeeping (``kvm.admit``) run now; the per-slot prefills and the
        next-token scatter dispatch later, chained through the pending
        cache/token handles."""
        kvm, runner = self.cache, self.runner
        snap = kvm.snapshot()
        plan: List[Tuple[int, Request, np.ndarray, int]] = []
        for slot, req in admitted:
            lb, _ = kvm.admit(slot, len(req.prompt), req.max_new_tokens)
            plan.append((slot, req, np.asarray(req.prompt, np.int32), lb))

        def dispatch():
            toks = jnp.asarray(self._toks, jnp.int32)
            caches = kvm.caches
            for slot, req, prompt, lb in plan:
                tok1, caches = runner.prefill_slot_async(prompt, caches,
                                                         slot, lb)
                toks = toks.at[slot].set(tok1[0])
                self.admission_prefill_tokens += lb
                self._scheduled[req.uid] = \
                    self._scheduled.get(req.uid, 0) + 1
            kvm.caches = caches
            self._toks = toks
            return toks

        def complete(toks_dev):
            self._complete_tokens(np.asarray(toks_dev),
                                  [(s, r) for s, r, _, _ in plan], out)

        return StagedStep(dispatch=dispatch, complete=complete,
                          rollback=lambda: kvm.restore(snap),
                          label=f"lm-prefill-x{len(plan)}")

    def _stage_decode(self, out: Dict[int, List[int]]) -> StagedStep:
        """Stage one batched decode step: prune cadence and write-position
        accounting (with their overflow checks) run now against the host
        mirrors; the decode itself dispatches later against the pending
        cache handle. ``maybe_prune`` may rebind ``kvm.caches`` to freshly
        dispatched compacted arrays — the snapshot keeps the pre-prune
        handle so a drop rewinds cleanly (nothing was donated yet)."""
        sched, kvm, runner = self.scheduler, self.cache, self.runner
        snap = kvm.snapshot()
        kvm.maybe_prune()
        kvm.on_decode()
        starts = kvm.valid_starts()
        produced = [(s, sched.running[s]) for s in sorted(sched.running)]

        def dispatch():
            tok_dev, kvm.caches = runner.decode(self._toks, kvm.caches,
                                                starts)
            self._toks = tok_dev
            for _, req in produced:
                self._scheduled[req.uid] = \
                    self._scheduled.get(req.uid, 0) + 1
            return tok_dev

        def complete(toks_dev):
            self._complete_tokens(np.asarray(toks_dev), produced, out)

        return StagedStep(dispatch=dispatch, complete=complete,
                          rollback=lambda: kvm.restore(snap),
                          label="lm-decode")

    def _complete_tokens(self, toks: np.ndarray,
                         produced: List[Tuple[int, "Request"]],
                         out: Dict[int, List[int]]) -> None:
        """Materialize this step's token for every slot that produced one;
        a request that reached its budget is marked done and its output
        recorded. Slot/event bookkeeping is ``_retire_scheduled``'s — it
        runs at the next step boundary, which keeps the admit/retire
        stream identical to the synchronous path's."""
        for slot, req in produced:
            req.generated.append(int(toks[slot]))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                out[req.uid] = list(req.generated)

    def _retire_scheduled(self) -> None:
        """Free every slot whose request has had its full token budget
        DISPATCHED (host-side count — no device sync): the values may
        still be in flight, but which step finishes a request is known at
        dispatch time, so retirement and slot reuse never wait on the
        device. The completion closures fill ``req.generated`` / ``out``
        when the tokens materialize."""
        sched, kvm = self.scheduler, self.cache
        for slot in sorted(sched.running):
            req = sched.running[slot]
            if self._scheduled.get(req.uid, 0) >= req.max_new_tokens:
                sched.retire(slot)
                kvm.free(slot)
                self._scheduled.pop(req.uid, None)

    # -- shared helpers ----------------------------------------------------
    def _append_and_retire(self, toks: np.ndarray, produced, out) -> None:
        sched, kvm = self.scheduler, self.cache
        for slot in sorted(produced):
            req = sched.running.get(slot)
            if req is None:
                continue
            req.generated.append(int(toks[slot]))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                out[req.uid] = list(req.generated)
                sched.retire(slot)
                kvm.free(slot)

    def _prefill_whole_batch(self, max_new: int) -> np.ndarray:
        """Wave-start whole-batch prefill (fallback families / per-slot
        prefill disabled): every admitted prompt left-padded to a common
        length."""
        sched, kvm, runner = self.scheduler, self.cache, self.runner
        prefixes: List[Optional[np.ndarray]] = \
            [None] * self.ec.max_batch
        for slot, req in sched.running.items():
            prefixes[slot] = np.asarray(req.prompt, np.int32)
        return self._prefill_prefixes(prefixes, max_new)

    def _rebuild_per_slot(self) -> np.ndarray:
        """Rebuild the live caches by per-slot prefilling every active
        prefix (elastic rebuild on a degraded mesh). Unlike the
        whole-batch fallback this keeps per-slot capacity semantics — no
        cross-slot padding — so a mid-stream degrade can never reject a
        workload its admissions already accepted."""
        sched, kvm, runner = self.scheduler, self.cache, self.runner
        kvm.reset()
        toks = np.zeros((self.ec.max_batch,), np.int64)
        for slot, req in sched.running.items():
            p = np.asarray(req.prompt, np.int32)
            if req.generated:
                p = np.concatenate([p, np.asarray(req.generated, np.int32)])
            rem = req.max_new_tokens - len(req.generated)
            lb, _ = kvm.admit(slot, len(p), rem)
            tok, kvm.caches = runner.prefill_slot(p, kvm.caches, slot, lb)
            toks[slot] = tok
            self.admission_prefill_tokens += lb
        return toks

    def _reprefill_active(self) -> np.ndarray:
        """Whole-batch re-prefill of every active prefix (prompt +
        generated so far) — the PR-2 admission path, kept for recurrent
        families and elastic rebuilds. Re-deriving the prefix's greedy
        continuation is exact: prefill over a prefix is mathematically the
        decode that produced it."""
        sched = self.scheduler
        prefixes: List[Optional[np.ndarray]] = [None] * self.ec.max_batch
        rem = 1
        for slot, req in sched.running.items():
            p = np.asarray(req.prompt, np.int32)
            if req.generated:
                p = np.concatenate(
                    [p, np.asarray(req.generated, np.int32)])
            prefixes[slot] = p
            rem = max(rem, req.max_new_tokens - len(req.generated))
        return self._prefill_prefixes(prefixes, rem)

    def _prefill_prefixes(self, prefixes, max_new: int) -> np.ndarray:
        kvm, runner = self.cache, self.runner
        L = max(len(p) for p in prefixes if p is not None)
        if L > self.ec.max_len:
            raise RuntimeError(
                f"prompt of {L} tokens exceeds max_len={self.ec.max_len}")
        # worst case before the next re-prefill: the longest (left-padded)
        # prefix decodes until the slowest slot retires
        kvm.check_capacity(L + max_new - 1)
        tokens, starts = build_padded_batch(prefixes)
        kvm.reset()
        tok_dev, kvm.caches = runner.prefill(tokens, starts, kvm.caches)
        kvm.set_batch_state(np.full((self.ec.max_batch,), L),
                            starts if kvm.masked else None)
        kvm.active[:] = [p is not None for p in prefixes]
        n_active = sum(p is not None for p in prefixes)
        self.admission_prefill_tokens += n_active * L
        return np.asarray(tok_dev).astype(np.int64)

    # -- elastic degradation -----------------------------------------------
    def _degrade(self, avail: int) -> None:
        """Walk the degradation ladder to a plan fitting ``avail`` devices,
        rebuild the mesh, re-shard the weights onto it from the checkpoint
        (CheckpointManager.restore with the new shardings), and surface the
        event through the Scheduler."""
        from repro.dist import sharding as SH
        from repro.launch.mesh import make_mesh

        ladder = degradation_path(self.elastic.plan,
                                  list(self.elastic.budgets))
        new_plan = first_fit(ladder, avail)
        if new_plan is None:
            raise RuntimeError(
                f"no degradation plan fits {avail} surviving devices "
                f"(ladder: {[p.describe() for p in ladder]})")
        if new_plan == self._plan:
            return
        mesh = make_mesh(new_plan.shape, new_plan.axes)
        shardings = SH.params_shardings(self.cfg, mesh, self.runner.params)
        self.runner.params = self.elastic.manager.restore(
            self.runner.params, step=self.elastic.step, shardings=shardings)
        self._plan = new_plan
        self.scheduler.observe("degrade", new_plan.describe())
