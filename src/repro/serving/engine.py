"""Batched serving engine: static-wave and continuous (slot-based) batching
over shared jitted prefill/decode steps, with beyond-paper dynamic KV-cache
pruning and elastic degradation on device loss.

Serve paths
-----------
* ``run``            — static waves: up to ``max_batch`` requests prefill
  together and decode in lockstep until the longest request finishes.
* ``run_continuous`` — continuous batching: ``max_batch`` fixed decode
  slots; waiting requests are admitted into slots as earlier requests
  finish (``Request.done``). Admission re-prefills the active prefixes
  (left-padded to a common length) so every jitted call keeps a static
  batch shape; slots then decode together until the next admission.

Left-padding is masked wherever it matters: the per-slot ``valid_start``
(index of the first real token) is threaded through prefill/decode
attention masks and the KV ``attn_mass`` accumulation, so pad slots never
compete with real tokens — neither in attention nor in KV-cache pruning.

KV pruning is the paper's token-scoring adapted to autoregressive decode:
attention mass accumulated per cached token ranks cache entries; every
``kv_prune_interval`` steps the engine compacts each layer's cache to the
top ``kv_prune_keep`` fraction (skipped while the cache is still shorter
than the target — there is nothing to prune). This bounds decode memory
*and* the per-step attention read — the decode-shape memory roofline term
scales by ``kv_prune_keep``.

Elastic degradation (ROADMAP repro.dist): construct the engine with an
``ElasticContext`` and ``run_continuous`` probes ``device_count()`` every
step. On device loss it walks ``dist.elastic.degradation_path`` to the
first plan that fits, rebuilds the mesh, re-shards the weights via
``CheckpointManager.restore(..., shardings=...)``, and keeps serving at
the reduced data-parallel width — in-flight requests are re-prefilled on
the new mesh, no request is dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import token_pruning as TP
from repro.dist.elastic import MeshPlan, degradation_path, first_fit
from repro.models import attention as A
from repro.models import steps as ST

# Families whose serve state is pure KV cache — left-padding can be masked
# exactly. Recurrent families (ssm, hybrid mamba states) absorb pad tokens
# into state, so the engine serves them without the valid_start masking
# (pre-existing behavior; see forward_lm docstring).
_MASKABLE = ("dense", "moe", "vlm", "audio")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8          # wave width / continuous decode slots
    max_len: int = 512
    kv_prune_interval: int = 0   # 0 = off
    kv_prune_keep: float = 1.0


@dataclasses.dataclass
class ElasticContext:
    """Everything ``run_continuous`` needs to survive simulated device loss.

    ``manager`` must hold a checkpoint of the engine's params (saved by the
    launcher before serving starts); ``device_count`` is the live-capacity
    probe the engine polls between steps (tests inject losses through it).
    """
    manager: Any                      # CheckpointManager with the weights
    plan: MeshPlan                    # healthy mesh plan
    budgets: Sequence[int]            # degradation_path device budgets
    device_count: Callable[[], int]   # live device probe
    step: Optional[int] = None        # checkpoint step (None = latest)


class ServeEngine:
    """Single-host reference engine (the multi-pod serve path lowers the
    same prefill/decode step functions through launch/serve.py)."""

    def __init__(self, cfg: ModelConfig, params: Any, ec: EngineConfig,
                 elastic: Optional[ElasticContext] = None):
        self.cfg = cfg
        self.params = params
        self.ec = ec
        self.elastic = elastic
        self.prefill = jax.jit(ST.make_prefill(cfg))
        self.decode = jax.jit(ST.make_decode_step(cfg))
        self.steps_since_prune = 0
        self._masked = cfg.family in _MASKABLE
        self._plan = elastic.plan if elastic is not None else None
        self.events: List[Tuple[str, Any]] = []
        self.prune_events = 0

    # ------------------------------------------------------------------
    # Static-wave path
    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a list of requests with static batching per wave."""
        out: Dict[int, List[int]] = {}
        for wave_start in range(0, len(requests), self.ec.max_batch):
            wave = requests[wave_start: wave_start + self.ec.max_batch]
            out.update(self._run_wave(wave))
        return out

    def _run_wave(self, wave: List[Request]) -> Dict[int, List[int]]:
        max_new = max(r.max_new_tokens for r in wave)
        S = max(len(r.prompt) for r in wave)
        self._check_capacity(S + max_new - 1)
        tok, caches, starts, cur_len = self._prefill_batch(
            [np.asarray(r.prompt, np.int32) for r in wave])
        gen = [tok]
        for _ in range(max_new - 1):
            caches, starts, cur_len = self._maybe_prune_kv(
                caches, starts, cur_len)
            self._check_overflow(cur_len)
            tok, caches = self.decode(self.params, tok[:, None], caches,
                                      valid_start=starts)
            cur_len += 1
            gen.append(tok)
        gen = np.stack([np.asarray(g) for g in gen], axis=1)  # [B, T]
        out = {}
        for i, r in enumerate(wave):
            r.generated = gen[i, : r.max_new_tokens].tolist()
            r.done = True
            out[r.uid] = r.generated
        return out

    # ------------------------------------------------------------------
    # Continuous-batching path
    # ------------------------------------------------------------------
    def run_continuous(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve with ``max_batch`` decode slots and per-request admission.

        Requests wait in FIFO order; a slot frees as soon as its request
        reaches ``max_new_tokens`` (``Request.done``). Admission and elastic
        degradation both trigger a re-prefill of every active prefix, which
        re-derives the same greedy continuation for in-flight requests
        (prefill over a prefix is mathematically the decode that produced
        it). Inactive slots carry a single dummy token and are masked via
        ``valid_start``; their outputs are discarded.
        """
        ec = self.ec
        pending: List[Request] = list(requests)
        slots: List[Optional[Request]] = [None] * ec.max_batch
        out: Dict[int, List[int]] = {}
        tok = caches = starts = None
        cur_len = 0

        while pending or any(r is not None for r in slots):
            if self.elastic is not None:
                avail = self.elastic.device_count()
                if avail < self._plan.num_devices:
                    self._degrade(avail)
                    tok = None  # re-prefill on the degraded mesh
            for i in range(ec.max_batch):
                if slots[i] is None and pending:
                    slots[i] = pending.pop(0)
                    self.events.append(("admit", slots[i].uid))
                    tok = None  # admission re-prefills the batch
            if tok is None:
                tok, caches, starts, cur_len = self._prefill_slots(slots)
            else:
                caches, starts, cur_len = self._maybe_prune_kv(
                    caches, starts, cur_len)
                self._check_overflow(cur_len)
                tok, caches = self.decode(self.params, tok[:, None], caches,
                                          valid_start=starts)
                cur_len += 1
            toks = np.asarray(tok)
            for i, r in enumerate(slots):
                if r is None:
                    continue
                r.generated.append(int(toks[i]))
                if len(r.generated) >= r.max_new_tokens:
                    r.done = True
                    out[r.uid] = list(r.generated)
                    slots[i] = None  # slot freed for the next admission
                    self.events.append(("retire", r.uid))
        return out

    def _prefill_slots(self, slots: List[Optional[Request]]):
        """(Re-)prefill every active slot's full prefix (prompt + generated
        so far), left-padded to a common length; inactive slots get a single
        dummy token. Returns (next_token, caches, valid_start, cur_len)."""
        prefixes: List[Optional[np.ndarray]] = []
        for r in slots:
            if r is None:
                prefixes.append(None)
                continue
            p = np.asarray(r.prompt, np.int32)
            if r.generated:
                p = np.concatenate(
                    [p, np.asarray(r.generated, np.int32)])
            prefixes.append(p)
        # worst case before the next re-prefill: the longest (left-padded)
        # prefix decodes until the slowest slot retires
        L = max(len(p) for p in prefixes if p is not None)
        rem = max(r.max_new_tokens - len(r.generated)
                  for r in slots if r is not None)
        self._check_capacity(L + rem - 1)
        return self._prefill_batch(prefixes)

    # ------------------------------------------------------------------
    # Shared batch construction + capacity guards
    # ------------------------------------------------------------------
    def _prefill_batch(self, prefixes: List[Optional[np.ndarray]]):
        """Left-pad ``prefixes`` (None = inactive slot -> one dummy token)
        to their common length, build fresh caches + valid_start, and run
        prefill. Returns (next_token, caches, valid_start, cur_len)."""
        self.steps_since_prune = 0  # fresh caches, fresh prune cadence
        ec = self.ec
        B = len(prefixes)
        L = max(len(p) for p in prefixes if p is not None)
        if L > ec.max_len:
            raise RuntimeError(
                f"prompt of {L} tokens exceeds max_len={ec.max_len}")
        toks = np.zeros((B, L), np.int32)
        starts_np = np.full((B,), max(L - 1, 0), np.int32)  # dummy slots
        for i, p in enumerate(prefixes):
            if p is None:
                continue
            toks[i, L - len(p):] = p
            starts_np[i] = L - len(p)
        caches = ST.init_caches(self.cfg, B, ec.max_len)
        starts = jnp.asarray(starts_np) if self._masked else None
        batch = {"tokens": jnp.asarray(toks)}
        if starts is not None:
            batch["valid_start"] = starts
        tok, caches = self.prefill(self.params, batch, caches)
        return tok, caches, starts, L

    def _check_capacity(self, high_water: int) -> None:
        """Reject up-front a workload whose cache high-water mark cannot
        fit. Only decidable when KV pruning is off — pruning bounds the
        cache dynamically, so pruned runs rely on ``_check_overflow``."""
        ec = self.ec
        pruning = ec.kv_prune_interval > 0 and ec.kv_prune_keep < 1.0
        if not pruning and high_water > ec.max_len:
            raise RuntimeError(
                f"max_len={ec.max_len} cannot hold {high_water} tokens "
                "(left-padded prefix + remaining decode); raise "
                "EngineConfig.max_len")

    def _check_overflow(self, cur_len: int) -> None:
        if cur_len >= self.ec.max_len:
            raise RuntimeError(
                f"KV cache overflow: decode step would write at "
                f"{cur_len} >= max_len={self.ec.max_len}")

    # ------------------------------------------------------------------
    # Elastic degradation
    # ------------------------------------------------------------------
    def _degrade(self, avail: int) -> None:
        """Walk the degradation ladder to a plan fitting ``avail`` devices,
        rebuild the mesh, and re-shard the weights onto it from the
        checkpoint (CheckpointManager.restore with the new shardings)."""
        from repro.dist import sharding as SH
        from repro.launch.mesh import make_mesh

        ladder = degradation_path(self.elastic.plan,
                                  list(self.elastic.budgets))
        new_plan = first_fit(ladder, avail)
        if new_plan is None:
            raise RuntimeError(
                f"no degradation plan fits {avail} surviving devices "
                f"(ladder: {[p.describe() for p in ladder]})")
        if new_plan == self._plan:
            return
        mesh = make_mesh(new_plan.shape, new_plan.axes)
        shardings = SH.params_shardings(self.cfg, mesh, self.params)
        self.params = self.elastic.manager.restore(
            self.params, step=self.elastic.step, shardings=shardings)
        self._plan = new_plan
        self.events.append(("degrade", new_plan.describe()))

    # ------------------------------------------------------------------
    # Dynamic KV pruning
    # ------------------------------------------------------------------
    def _maybe_prune_kv(self, caches, starts, cur_len: int):
        """Returns (caches, starts, cur_len) — compacted when the cadence
        fires and the cache has outgrown the keep target."""
        ec = self.ec
        if ec.kv_prune_interval <= 0 or ec.kv_prune_keep >= 1.0:
            return caches, starts, cur_len
        keep = max(1, min(int(ec.max_len * ec.kv_prune_keep), ec.max_len))
        self.steps_since_prune += 1
        if self.steps_since_prune < ec.kv_prune_interval or cur_len < keep:
            return caches, starts, cur_len
        self.steps_since_prune = 0
        self.prune_events += 1
        caches, new_starts = prune_kv_caches(caches, ec.kv_prune_keep,
                                             starts=starts)
        return caches, (new_starts if self._masked else None), keep


def prune_kv_caches(caches: Any, keep_frac: float,
                    starts: Optional[jax.Array] = None) -> Tuple[Any, Any]:
    """Compact every KVCache to its top-``keep_frac`` attention-mass slots.

    Stacked caches ([L, ...]) are handled with vmap. ``starts`` ([B] int32)
    marks per-slot left-padding; pad slots score ``-inf`` and are never kept
    ahead of real tokens. Kept entries are packed so each slot's valid
    window ends at ``keep``: when a slot has fewer than ``keep`` valid
    entries, the (zeroed) garbage sits at the *front*, which the returned
    ``new_starts`` ([B] int32) masks — the compacted cache is left-padded
    exactly like the prompts were. ``length`` becomes ``min(length, keep)``
    per layer and attention mass resets (so the ranking adapts as decoding
    proceeds).

    Returns ``(pruned_caches, new_starts)``.
    """
    def one(c):
        if not isinstance(c, A.KVCache):
            return c  # recurrent state (ssm/mamba) passes through untouched

        def single(k, v, length, mass):
            n = k.shape[1]
            keep = max(1, min(int(n * keep_frac), n))
            scores = TP.kv_prune_scores(mass, length, start=starts)
            idx = TP.select_kv_keep(scores, keep, invalid_first=True)
            k2, v2 = TP.compact_kv_cache(k, v, idx)
            # zero the invalid (garbage) prefix each slot may carry
            n_valid = jnp.clip(
                length - (starts if starts is not None else 0), 0, keep)
            pos = jnp.arange(keep)
            valid = pos[None, :] >= (keep - n_valid)[..., None]
            k2 = jnp.where(valid[..., None, None], k2, 0)
            v2 = jnp.where(valid[..., None, None], v2, 0)
            k_new = jnp.zeros_like(k).at[:, :keep].set(k2)
            v_new = jnp.zeros_like(v).at[:, :keep].set(v2)
            new_len = jnp.full_like(length, keep)
            new_mass = jnp.zeros_like(mass)
            return A.KVCache(k_new, v_new, new_len, new_mass)

        if c.k.ndim == 5:  # stacked [L, B, S, KV, Dh]
            return jax.vmap(single)(c.k, c.v, c.length, c.attn_mass)
        return single(c.k, c.v, c.length, c.attn_mass)

    is_kv = lambda x: isinstance(x, A.KVCache)
    pruned = jax.tree.map(one, caches, is_leaf=is_kv)
    kv_leaves = [l for l in jax.tree_util.tree_leaves(caches, is_leaf=is_kv)
                 if isinstance(l, A.KVCache)]
    if not kv_leaves:  # pure recurrent state: nothing compacted
        return pruned, starts
    # analytic per-slot garbage prefix — identical for every layer because
    # it depends only on length/starts/keep, not the per-layer attn mass
    first = kv_leaves[0]
    n = first.k.shape[-3]
    keep = max(1, min(int(n * keep_frac), n))
    base = (starts if starts is not None
            else jnp.zeros((first.k.shape[-4],), jnp.int32))
    n_valid = jnp.clip(jnp.max(first.length) - base, 0, keep)
    new_starts = (keep - n_valid).astype(jnp.int32)
    return pruned, new_starts
