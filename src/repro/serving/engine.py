"""Batched serving engine: prefill + decode loop with continuous batching
slots and the beyond-paper dynamic KV-cache pruning.

The KV pruning is the paper's token-scoring adapted to autoregressive
decode: attention mass accumulated per cached token (KVCache.attn_mass,
maintained by the decode path) ranks cache entries; every
``kv_prune_interval`` steps the engine compacts each layer's cache to the
top ``kv_prune_keep`` fraction. This bounds decode memory *and* the
per-step attention read — the decode-shape memory roofline term scales by
``kv_prune_keep``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import token_pruning as TP
from repro.models import attention as A
from repro.models import steps as ST


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 512
    kv_prune_interval: int = 0   # 0 = off
    kv_prune_keep: float = 1.0


class ServeEngine:
    """Single-host reference engine (the multi-pod serve path lowers the
    same prefill/decode step functions through launch/serve.py)."""

    def __init__(self, cfg: ModelConfig, params: Any, ec: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ec = ec
        self.prefill = jax.jit(ST.make_prefill(cfg))
        self.decode = jax.jit(ST.make_decode_step(cfg))
        self.steps_since_prune = 0

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a list of requests with static batching per wave (the
        continuous-batching slot logic lives in ``run_continuous``)."""
        out: Dict[int, List[int]] = {}
        for wave_start in range(0, len(requests), self.ec.max_batch):
            wave = requests[wave_start: wave_start + self.ec.max_batch]
            out.update(self._run_wave(wave))
        return out

    def _run_wave(self, wave: List[Request]) -> Dict[int, List[int]]:
        B = len(wave)
        S = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(wave):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad
        caches = ST.init_caches(self.cfg, B, self.ec.max_len)
        batch = {"tokens": jnp.asarray(toks)}
        tok, caches = self.prefill(self.params, batch, caches)
        max_new = max(r.max_new_tokens for r in wave)
        gen = [tok]
        for step in range(max_new - 1):
            caches = self._maybe_prune_kv(caches)
            tok, caches = self.decode(self.params, tok[:, None], caches)
            gen.append(tok)
        gen = np.stack([np.asarray(g) for g in gen], axis=1)  # [B, T]
        return {r.uid: gen[i, : r.max_new_tokens].tolist()
                for i, r in enumerate(wave)}

    # ------------------------------------------------------------------
    def _maybe_prune_kv(self, caches):
        ec = self.ec
        if ec.kv_prune_interval <= 0 or ec.kv_prune_keep >= 1.0:
            return caches
        self.steps_since_prune += 1
        if self.steps_since_prune < ec.kv_prune_interval:
            return caches
        self.steps_since_prune = 0
        return prune_kv_caches(caches, ec.kv_prune_keep)


def prune_kv_caches(caches: Any, keep_frac: float) -> Any:
    """Compact every KVCache to its top-``keep_frac`` attention-mass slots.

    Stacked caches ([L, ...]) are handled with vmap. The kept entries move
    to the front, ``length`` shrinks, and attention mass resets (so the
    ranking adapts as decoding proceeds)."""
    def one(c: A.KVCache) -> A.KVCache:
        def single(k, v, length, mass):
            n = k.shape[1]
            keep = max(1, int(n * keep_frac))
            scores = TP.kv_prune_scores(mass, length)
            idx = TP.select_kv_keep(scores, keep)
            k2, v2 = TP.compact_kv_cache(k, v, idx)
            k_new = jnp.zeros_like(k).at[:, :keep].set(k2)
            v_new = jnp.zeros_like(v).at[:, :keep].set(v2)
            new_len = jnp.minimum(length, keep)
            new_mass = jnp.zeros_like(mass)
            return A.KVCache(k_new, v_new, new_len, new_mass)

        if c.k.ndim == 5:  # stacked [L, B, S, KV, Dh]
            return jax.vmap(single)(c.k, c.v, c.length, c.attn_mass)
        return single(c.k, c.v, c.length, c.attn_mass)

    is_kv = lambda x: isinstance(x, A.KVCache)
    return jax.tree.map(one, caches, is_leaf=is_kv)
