"""StepPipeline — pipelined step execution shared by both serving engines.

The paper's accelerator overlaps on-the-fly token pruning with compute via
multi-level parallelism; the software engines used to run every step
synchronously (plan -> dispatch -> block), leaving the host idle while the
device ran and vice versa. This module is the runtime half of the fix: a
step is split into three phases and only the last one ever waits.

    stage     (engine) build the ExecutionPlan and the padded input
              buffers for step N. Pure host bookkeeping plus data-movement
              ops on device handles — it mutates no shared engine state it
              cannot roll back, so a staged step can still be dropped and
              replanned (e.g. a request was submitted mid-step and belongs
              in this plan).
    dispatch  (pipeline) enqueue step N's jitted segment calls. JAX's
              async dispatch returns pending arrays immediately; nothing
              here blocks. Host mirrors (seg_idx, cache lengths, token
              chains) advance now, because they are deterministic given
              the plan — the enabler for computing plan N+1 while the
              device still executes plan N.
    complete  (pipeline) block on step N's output handles and materialize
              host-visible results (logits, generated tokens).

``depth`` bounds how many dispatched-but-incomplete steps may be in
flight. Depth 1 completes each step inside :meth:`submit` — bit-exact,
step-for-step identical to the old synchronous loops. Depth 2
double-buffers: while the device executes step N, the host stages step
N+1, and step N is completed only when N+1's dispatch has been enqueued.
Results are bit-exact at any depth — the pipeline reorders *waiting*, not
math: every step's inputs are fully determined at its stage time.

This module owns the engines' ONLY ``jax.block_until_ready`` call site
(CI greps for strays); everything upstream must hand the pipeline handles
instead of blocking.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Optional

import jax

from repro.obs.trace import NULL_TRACER, Tracer

__all__ = ["StagedStep", "StepPipeline", "StepReport"]


@dataclasses.dataclass(frozen=True)
class StepReport:
    """What one engine ``tick`` did, in host-deterministic terms.

    Both engines' incremental step APIs (``VisionEngine.tick``,
    ``ServeEngine.tick_continuous``) return one of these so external
    drivers — the trace-replay harness in ``repro.traffic`` foremost — can
    account request lifecycles on a *virtual* clock: every field is known
    at dispatch time from host bookkeeping alone (no device sync), and is
    identical at every pipeline depth for the same request stream.

    ``dispatched``   — whether the tick put a step on the device (False =
                       idle bookkeeping tick: nothing admitted/running).
    ``modeled_ms``   — the cost model's price of the dispatched step
                       (vision: the committed ``ExecutionPlan``'s modeled
                       cycles; LM engines leave it 0 and report
                       ``work_tokens`` for the driver to price).
    ``work_tokens``  — tokens this step dispatched (LM: prefilled +
                       decoded; vision engines leave it 0).
    ``admitted``     — uids that entered slots this tick (their first
                       segment/prefill dispatches in this very step).
    ``completed``    — uids whose final segment/token was dispatched this
                       tick; their host-visible outputs materialize when
                       the pipeline completes the step.
    """

    dispatched: bool
    modeled_ms: float = 0.0
    work_tokens: int = 0
    admitted: tuple = ()
    completed: tuple = ()


@dataclasses.dataclass
class StagedStep:
    """One fully-staged engine step awaiting dispatch.

    ``dispatch`` enqueues the device work and returns the output handles
    to block on; ``complete`` runs after the block and materializes
    host-visible results; ``rollback`` (optional) undoes any host-mirror
    mutations staging made, so the step can be dropped pre-dispatch when a
    replan invalidates it (mid-step submission). Once dispatched, a step
    can no longer be dropped — device work is in flight."""

    dispatch: Callable[[], Any]
    complete: Callable[[Any], None]
    rollback: Optional[Callable[[], None]] = None
    label: str = ""
    handles: Any = None
    dispatched: bool = False
    completed: bool = False
    modeled_ms: float = 0.0   # the cost model's price of this step (vision
    # engines set it from the staged ExecutionPlan; 0 = unmodeled). Paired
    # with the measured dispatch+block wall time at completion, this is
    # the per-step modeled-vs-measured sample behind the calibration-drift
    # metric (pipeline stats: modeled_ms_total / measured_ms_total).
    dispatch_wall_s: float = 0.0  # wall seconds this step's dispatch took
    # (pipeline-recorded; the complete phase adds its block time to form
    # the measured cost)


class StepPipeline:
    """Bounded in-flight window of engine steps.

    ``depth`` = max steps dispatched but not yet completed. ``submit``
    dispatches the new step, then completes the oldest in-flight steps
    until at most ``depth - 1`` remain — so depth 1 is the synchronous
    path and depth 2 keeps exactly one step on the device while the host
    stages the next.
    """

    def __init__(self, depth: int = 1, tracer: Optional[Tracer] = None):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.depth = depth
        # wall-clock span tracer (repro.obs): dispatch/complete spans on
        # the "pipeline" track. Disabled by default — one attribute check
        # per phase; it observes timing only, never reorders work
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._inflight: Deque[StagedStep] = deque()
        # accounting (the bench's wall_vs_device column reads these)
        self.steps = 0           # steps dispatched
        self.drops = 0           # staged steps dropped pre-dispatch
        self.overlap_hits = 0    # completions whose handles were already
        #                          ready — the device finished while the
        #                          host was staging (overlap realized)
        self.block_s = 0.0       # wall seconds inside block_until_ready
        self.dispatch_s = 0.0    # wall seconds enqueueing device work
        self.modeled_ms_total = 0.0   # sum of completed steps' cost-model
        #                               prices (steps with modeled_ms > 0)
        self.measured_ms_total = 0.0  # their measured dispatch+block wall
        #                               ms — modeled vs measured is the
        #                               calibration-drift signal
        self.starved_s = 0.0     # wall seconds the device spent with NO
        #                          step in flight — the host was planning/
        #                          staging while the device sat idle. This
        #                          is the quantity double-buffering
        #                          removes, and it is meaningful even when
        #                          host and device share cores (CPU): it
        #                          measures queue emptiness, not wall
        #                          speedup.
        self._idle_since = time.perf_counter()

    # -- lifecycle ----------------------------------------------------------
    def submit(self, step: StagedStep) -> None:
        """Dispatch ``step`` and drain completions down to ``depth - 1``
        in-flight steps."""
        tr = self.tracer
        t0 = time.perf_counter()
        if not self._inflight:
            # the device queue was empty for the whole host-side gap since
            # it last drained — that gap is device starvation
            self.starved_s += t0 - self._idle_since
        if tr.enabled:
            tr.begin("dispatch", track="pipeline", label=step.label)
        step.handles = step.dispatch()
        step.dispatched = True
        if tr.enabled:
            tr.end("dispatch", track="pipeline")
        step.dispatch_wall_s = time.perf_counter() - t0
        self.dispatch_s += step.dispatch_wall_s
        self.steps += 1
        self._inflight.append(step)
        while len(self._inflight) > self.depth - 1:
            self._complete_oldest()

    def drop(self, step: StagedStep) -> None:
        """Discard a staged-but-not-dispatched step (a replan invalidated
        it); runs its rollback so staged host-mirror state resets."""
        if step.dispatched:
            raise RuntimeError("cannot drop a dispatched step: its device "
                               "work is already in flight")
        if step.rollback is not None:
            step.rollback()
        self.drops += 1

    def flush(self) -> None:
        """Complete every in-flight step (end of serve, or before an
        operation that must observe fully-materialized state, e.g. an
        elastic rebuild)."""
        while self._inflight:
            self._complete_oldest()

    def _complete_oldest(self) -> None:
        step = self._inflight.popleft()
        tr = self.tracer
        leaves = jax.tree_util.tree_leaves(step.handles)
        if leaves and all(l.is_ready() for l in leaves
                          if hasattr(l, "is_ready")):
            self.overlap_hits += 1
        if tr.enabled:
            tr.begin("complete", track="pipeline", label=step.label)
        t0 = time.perf_counter()
        jax.block_until_ready(step.handles)
        block = time.perf_counter() - t0
        self.block_s += block
        step.complete(step.handles)
        step.completed = True
        if tr.enabled:
            tr.end("complete", track="pipeline")
        if step.modeled_ms > 0.0:
            # dispatch wall + block wall brackets the device's work for
            # this step (exactly the bench's device-busy proxy), measured
            # per step so drift against the cost model is attributable
            self.modeled_ms_total += step.modeled_ms
            self.measured_ms_total += (step.dispatch_wall_s + block) * 1e3
        if not self._inflight:
            self._idle_since = time.perf_counter()

    # -- observability ------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    def stats(self) -> Dict[str, Any]:
        return {
            "depth": self.depth,
            "steps": self.steps,
            "drops": self.drops,
            "overlap_hits": self.overlap_hits,
            "block_s": self.block_s,
            "dispatch_s": self.dispatch_s,
            "starved_s": self.starved_s,
            "modeled_ms_total": self.modeled_ms_total,
            "measured_ms_total": self.measured_ms_total,
            # signed relative drift of the cost model against measured
            # wall time ((modeled - measured) / measured): the closed-loop
            # adaptation signal; 0.0 until a modeled step completes
            "cost_error": ((self.modeled_ms_total - self.measured_ms_total)
                           / self.measured_ms_total
                           if self.measured_ms_total > 0.0 else 0.0),
        }
