"""TilePlanner — cost-model-driven execution planning for ragged ViT serving.

PR 4's ``RaggedBatcher`` buckets the ragged population by exact token count
and dispatches every bucket as its own tile — it never asks whether a
grouping is *worth it*. SPViT and HeatViT both argue that pruning-era
scheduling must be driven by a latency model, not token counts alone, and
the paper's own hardware contribution is exactly such a load balancer for
the irregular work that simultaneous pruning produces. This module is the
missing layer: a planner that *prices* tiles with the accelerator cycle
model (``core.perf_model``) before dispatching them.

Each engine step, :class:`TilePlanner` takes the live population as
:class:`PlanItem` s and emits an :class:`ExecutionPlan` — hashable,
deterministic, stats-carrying — chosen by a pluggable
:class:`TileCostModel`:

* **bucket merging** (modes ``merge``/``full``) — neighboring under-full
  token buckets of the same stage are bin-packed into one masked tile when
  the modeled padding cost is below the modeled dispatch saving;
* **express lanes** (modes ``fuse``/``full``) — a request that is a
  singleton in *every* bucket of its remaining trajectory pays one dispatch
  per segment for nothing; the planner fuses its consecutive segments into
  one jitted trajectory program (``PackedVitSegments.run_fused``);
* **deadline-aware tiling** (any non-``off`` mode) — requests carrying a
  ``deadline_ms`` whose modeled slack has run out are carved out of shared
  tiles into their own smaller tiles, dispatched first, and excluded from
  merging (merging only adds padded work to their critical path).

Mode ``off`` is the identity: the plan's tiles are exactly
``RaggedBatcher.plan``'s output (property-tested), no lanes, no deadline
handling — the trivial cost model's special case, preserving PR 4's
bit-exact balanced path unchanged.

Exactness: merging pads rows inside *masked* kernels whose padded keys
contribute exactly zero, and fused lanes compose the same pure segment
bodies into one XLA program — both are bit-exact against the unmerged
balanced path at the head logits (asserted in tests/test_planner.py and
tests/test_vision_engine.py on the CPU backend).

Recompile discipline: every tile maps to a ``bucket_key`` and every lane to
a ``traj_key``; jit compiles are bounded by the union of the two sets (the
``bucket ∪ trajectory`` bound, checked by the vision bench and CI).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

from repro.core.perf_model import (PAPER_U250, PRECISION_SPEEDUP,
                                   AcceleratorConfig, precision_speedup,
                                   vit_segment_cycles)
from repro.serving.ragged_batcher import RaggedBatcher, Tile

__all__ = ["PLANNER_MODES", "PRECISIONS", "PlanItem", "FusedLane",
           "PlanStats", "ExecutionPlan", "TileCostModel", "TilePlanner"]

PLANNER_MODES = ("off", "merge", "fuse", "full")

# Precision candidates in tie-break order: fp32 first, so a quantized tier
# must be STRICTLY cheaper under the cost model to displace full precision.
PRECISIONS = tuple(PRECISION_SPEEDUP)

# FPGA-era default: roughly the cost of streaming one column-block group
# through the MPCA between kernels (~3 µs at 300 MHz). Deliberately coarse —
# ``TileCostModel.calibrate`` replaces it with a fitted wall-clock constant
# so merge decisions aren't hostage to this number.
DEFAULT_DISPATCH_OVERHEAD_CYCLES = 1000.0


@dataclasses.dataclass(frozen=True)
class PlanItem:
    """One live request as the planner sees it.

    ``trajectory`` is the remaining (stage key, entry token count) sequence
    INCLUDING the current stage at offset 0 — offsets align with engine
    steps, which is what makes the fusion singleton check sound: two live
    requests can only ever share a future bucket at equal trajectory
    offsets. Empty trajectory = opaque item (fusion disabled for it).
    ``deadline_left_ms`` is wall-clock milliseconds until the request's
    deadline (``None`` = no deadline)."""

    stage: Hashable
    n_tokens: int
    cap: Optional[int] = None
    trajectory: Tuple[Tuple[Hashable, int], ...] = ()
    deadline_left_ms: Optional[float] = None

    def __post_init__(self):
        if self.trajectory:
            s0, n0 = self.trajectory[0]
            if s0 != self.stage or n0 != self.n_tokens:
                raise ValueError(
                    f"trajectory[0] {(s0, n0)!r} must restate the item's "
                    f"current (stage, n_tokens) {(self.stage, self.n_tokens)!r}")


@dataclasses.dataclass(frozen=True)
class FusedLane:
    """An express lane: ``member`` (caller-side item index) runs its whole
    remaining trajectory — one jitted program, one dispatch — instead of one
    tile per segment."""

    member: int
    trajectory: Tuple[Tuple[Hashable, int], ...]  # (stage, entry count)

    @property
    def traj_key(self) -> Tuple:
        """Compile identity of the fused program (the ledger key)."""
        return self.trajectory

    @property
    def real_cells(self) -> int:
        return sum(n for _, n in self.trajectory)


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """Per-plan accounting, carried by the :class:`ExecutionPlan`."""

    tiles: int = 0
    lanes: int = 0
    merges: int = 0              # bin-pack operations applied
    fused_segments: int = 0      # segments covered by lanes
    deadline_urgent: int = 0     # members whose modeled slack ran out
    deadline_splits: int = 0     # tiles carved apart for urgent members
    modeled_cycles: float = 0.0  # cost of THIS plan under the cost model
    base_cycles: float = 0.0     # cost of the identity plan for same items

    @property
    def modeled_saving_cycles(self) -> float:
        return self.base_cycles - self.modeled_cycles


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """What one engine step dispatches: dense tiles + fused express lanes.
    Frozen/hashable (tiles and lanes are frozen dataclasses over hashable
    fields) and deterministic given the item sequence and planner state.
    ``urgent`` lists the members whose deadline slack ran out — the engine
    must dispatch their tiles BEFORE everything else in the step (tiles
    are already ordered urgent-first; lanes come after urgent tiles, since
    a fused lane is the most expensive single dispatch of the step)."""

    tiles: Tuple[Tile, ...]
    lanes: Tuple[FusedLane, ...]
    stats: PlanStats
    urgent: Tuple[int, ...] = ()

    def covered_members(self) -> List[int]:
        """Sorted item indices covered — a correct plan covers each item
        exactly once across tiles ∪ lanes (property-tested)."""
        out = [i for t in self.tiles for i in t.members]
        out += [l.member for l in self.lanes]
        return sorted(out)

    def urgent_tile_count(self) -> int:
        """Tiles containing at least one urgent member; by construction
        (``TilePlanner._order``) these are exactly the leading tiles."""
        u = set(self.urgent)
        return sum(1 for t in self.tiles if any(m in u for m in t.members))

    def staging_meta(self) -> Tuple[Tuple, ...]:
        """Per-tile staging recipe, one entry per tile in dispatch order:
        ``(members, n_tokens, n_tile, b_tile, needs_mask, n_valid)`` where
        ``n_valid`` is the full padded-row valid-count vector (real counts
        then ``n_tile`` for batch-pad rows) or ``None`` when no row is
        token-padded. Hashable and device-free — the pipelined engines
        build step N+1's input buffers from this while step N executes."""
        out = []
        for t in self.tiles:
            nv = None
            if t.needs_mask:
                nv = t.n_tokens + (t.n_tile,) * (t.b_tile - len(t.members))
            out.append((t.members, t.n_tokens, t.n_tile, t.b_tile,
                        t.needs_mask, nv))
        return tuple(out)


# ===========================================================================
# Cost model
# ===========================================================================
class TileCostModel:
    """Prices tiles and lanes in modeled accelerator cycles.

    Stage keys produced by the ``VisionEngine`` have the shape
    ``(seg_idx, segment, k)`` with ``segment`` one of the
    ``core.packed_runner`` segments; those are priced through the paper's
    cycle model (``encoder_cycles``/``sbmm_cycles``, Table III). Opaque
    stage keys (planner unit tests, foreign engines) fall back to a
    quadratic-in-tokens proxy of attention cost.

    ``dispatch_overhead_cycles`` is the per-dispatch fixed cost the merge
    rule trades against padding; :meth:`calibrate` fits it (and the
    cycle→seconds scale) from measured wall-clock timings, so decisions on
    a real host aren't hostage to the FPGA-era default.
    """

    def __init__(self, cfg=None, acc: AcceleratorConfig = PAPER_U250,
                 dispatch_overhead_cycles: float =
                 DEFAULT_DISPATCH_OVERHEAD_CYCLES,
                 seconds_per_cycle: Optional[float] = None):
        self.cfg = cfg
        self.acc = acc
        self.dispatch_overhead_cycles = float(dispatch_overhead_cycles)
        self.seconds_per_cycle = (1.0 / acc.freq_hz if seconds_per_cycle
                                  is None else float(seconds_per_cycle))
        self.calibrated = False

    # -- per-stage pricing -------------------------------------------------
    @staticmethod
    def _segment_of(stage) -> Optional[Tuple]:
        """Extract the packed_runner segment from an engine stage key
        ``(seg_idx, segment, k)`` — or its marker-extended variants
        ``(…, "soft")`` / ``(…, precision)`` / ``(…, "soft", precision)``
        (same segment weights, so the same base pricing); None for opaque
        keys."""
        if (isinstance(stage, tuple) and len(stage) >= 3
                and isinstance(stage[1], tuple) and stage[1]
                and isinstance(stage[1][0], str)):
            return stage[1]
        return None

    @staticmethod
    def _precision_of(stage) -> str:
        """Precision marker of a stage key: non-fp32 stages carry the
        precision string as their LAST element (after the optional "soft"
        marker); fp32 keys carry no marker — by construction in the engine,
        so fp32 stage keys (and therefore fp32 plans, digests and compile
        ledgers) are byte-identical to the pre-quantization ones."""
        if (isinstance(stage, tuple) and stage
                and isinstance(stage[-1], str)
                and stage[-1] in PRECISION_SPEEDUP):
            return stage[-1]
        return "fp32"

    def stage_row_cycles(self, stage, n_tokens: int) -> float:
        """Modeled cycles for ONE row (one image) of a tile at ``stage``
        with ``n_tokens`` (padded) tokens. Quantized stages price at the
        cycle model's precision throughput (``PRECISION_SPEEDUP``)."""
        seg = self._segment_of(stage)
        precision = self._precision_of(stage)
        if seg is None or self.cfg is None:
            # opaque stage: attention-shaped proxy (quadratic term + linear),
            # scaled by the same precision speedup so foreign engines and
            # the proxy-priced benches see consistent precision ordering
            return (float(n_tokens * n_tokens + 8 * n_tokens)
                    / precision_speedup(precision))
        return vit_segment_cycles(self.cfg, seg, n_tokens, self.acc,
                                  precision=precision)

    # -- tile / lane / trajectory pricing ----------------------------------
    def tile_work_cycles(self, tile: Tile) -> float:
        """Variable (per-cell) part of a tile's cost: every padded row pays
        the full padded token count — that's the padding cost merging
        trades against the dispatch overhead."""
        return tile.b_tile * self.stage_row_cycles(tile.stage, tile.n_tile)

    def tile_cycles(self, tile: Tile) -> float:
        return self.dispatch_overhead_cycles + self.tile_work_cycles(tile)

    def lane_cycles(self, lane: FusedLane) -> float:
        """A fused lane is ONE dispatch covering its whole trajectory."""
        work = sum(self.stage_row_cycles(s, n) for s, n in lane.trajectory)
        return self.dispatch_overhead_cycles + work

    def trajectory_cycles(self, trajectory: Sequence[Tuple[Hashable, int]]
                          ) -> float:
        """Cost of running a trajectory the DEFAULT way — one dispatch per
        stage (the baseline a lane is compared against, and the remaining
        work term in deadline slack)."""
        return sum(self.dispatch_overhead_cycles
                   + self.stage_row_cycles(s, n) for s, n in trajectory)

    def ms(self, cycles: float) -> float:
        return cycles * self.seconds_per_cycle * 1e3

    # -- calibration -------------------------------------------------------
    def calibrate(self, measured: Sequence[Tuple[float, float]]
                  ) -> Dict[str, float]:
        """Fit the model to wall-clock: ``measured`` is (work_cycles,
        seconds) per observed dispatch, with ``work_cycles`` the *variable*
        cost (:meth:`tile_work_cycles`). Least-squares
        ``seconds ≈ a + b·work`` sets ``seconds_per_cycle = b`` and
        ``dispatch_overhead_cycles = a / b`` — after which modeled cycles
        are directly comparable to the host's wall clock and merge/deadline
        decisions reflect measured dispatch overhead, not the FPGA-era
        default. Returns the fit."""
        pts = [(float(x), float(y)) for x, y in measured]
        if len(pts) < 2:
            raise ValueError(f"calibrate needs >= 2 samples, got {len(pts)}")
        n = len(pts)
        mx = sum(x for x, _ in pts) / n
        my = sum(y for _, y in pts) / n
        var = sum((x - mx) ** 2 for x, _ in pts)
        if var == 0.0:
            raise ValueError("calibrate needs samples at >= 2 distinct "
                             "work sizes to separate overhead from work")
        b = sum((x - mx) * (y - my) for x, y in pts) / var
        a = my - b * mx
        # Guard degenerate fits (noise on near-constant timings): keep the
        # scale positive and the overhead non-negative.
        b = max(b, 1e-15)
        a = max(a, 0.0)
        self.seconds_per_cycle = b
        self.dispatch_overhead_cycles = a / b
        self.calibrated = True
        ss_res = sum((y - (a + b * x)) ** 2 for x, y in pts)
        ss_tot = sum((y - my) ** 2 for _, y in pts) or 1e-30
        return {"seconds_per_cycle": b,
                "dispatch_overhead_cycles": self.dispatch_overhead_cycles,
                "overhead_seconds": a,
                "r2": 1.0 - ss_res / ss_tot,
                "samples": n}


# ===========================================================================
# Planner
# ===========================================================================
class TilePlanner:
    """Plans one engine step's dispatches over the ragged population.

    Owns the :class:`RaggedBatcher` (grouping + padding stats) and a
    :class:`TileCostModel` (pricing); accumulates merge/fusion/deadline
    counters and the trajectory ledger across calls."""

    def __init__(self, batcher: RaggedBatcher,
                 cost_model: Optional[TileCostModel] = None,
                 mode: str = "full", fuse_min_segments: int = 2,
                 quality: Optional[object] = None):
        if mode not in PLANNER_MODES:
            raise ValueError(f"planner mode must be one of {PLANNER_MODES}, "
                             f"got {mode!r}")
        if mode != "off" and batcher.mode != "balanced":
            raise ValueError(
                f"planner mode {mode!r} requires the balanced batcher "
                f"(merge/fuse/deadline rewrite exact-count buckets); "
                f"batcher mode is {batcher.mode!r}")
        if fuse_min_segments < 1:
            raise ValueError("fuse_min_segments must be >= 1")
        self.batcher = batcher
        self.cost_model = cost_model if cost_model is not None \
            else TileCostModel()
        self.mode = mode
        self.fuse_min_segments = fuse_min_segments
        # keep-schedule resolution is a planning decision (it rewrites
        # trajectories, and trajectories are what plans are built from),
        # so the QualityController lives here; a strict (off) controller
        # is the default and resolves every schedule to itself
        if quality is None:
            from repro.serving.quality import QualityController
            quality = QualityController()
        self.quality = quality
        # cumulative accounting
        self.plans = 0
        self.merges = 0
        self.lanes_planned = 0
        self.lane_cells = 0      # real token·segment cells served via lanes
        self.fused_segments = 0
        self.deadline_urgent = 0
        self.deadline_splits = 0
        self.modeled_cycles = 0.0
        self.base_cycles = 0.0
        self.trajectory_keys: Set = set()
        self.precision_decisions: Dict[str, int] = {p: 0 for p in PRECISIONS}

    # -- public API --------------------------------------------------------
    def plan(self, items: Sequence[PlanItem]) -> ExecutionPlan:
        """Emit the :class:`ExecutionPlan` for one step's population and
        fold it into the cumulative ledgers (build + :meth:`commit`).
        Deterministic: identical items + planner config -> identical plan."""
        return self.commit(self._build(list(items)))

    def plan_ahead(self, items: Sequence[PlanItem],
                   horizon: int) -> List[ExecutionPlan]:
        """Speculative plans for this step and up to ``horizon - 1``
        predicted successors. Plans are hashable, deterministic values, so
        they CAN be computed before the device work that realizes them —
        the pipelined engines stage plan N+1 while plan N executes, then
        :meth:`commit` only what actually dispatches (nothing here touches
        the cumulative ledgers or the batcher's padding stats).

        Prediction semantics: every live item advances one trajectory
        offset per step; lane-fused members and items at their last
        segment leave the population (:meth:`advance_items` is the same
        rule, exposed for the engines' cache-validity fingerprints).
        Deadlines are not propagated — urgency is wall-clock-scoped to the
        step that observes it, so speculative successors carry none (the
        engines skip lookahead caching for deadline-bearing populations).

        The trajectory-singleton (express-lane) check is memoized across
        the horizon: the pairwise last-collision offsets are computed once
        (one O(n²·L) trajectory scan) and each successor's fusible set is
        derived from them by integer comparison — item ``i`` is solo at
        horizon step ``h`` iff its last collision with every still-live
        item falls before ``h``.
        """
        if horizon < 1:
            raise ValueError(f"plan_ahead horizon must be >= 1, "
                             f"got {horizon}")
        items = list(items)
        plans = [self._build(items)]
        if horizon == 1:
            return plans
        fuse_on = self.mode in ("fuse", "full")
        maxcol = self._pairwise_last_collision(items) if fuse_on else None
        cur, orig = items, list(range(len(items)))
        for h in range(1, horizon):
            cur, kept = self._advance(cur, plans[-1])
            orig = [orig[ci] for ci in kept]
            if not cur:
                break
            fused_members = None
            if fuse_on:
                fused_members = {
                    ci for ci, oi in enumerate(orig)
                    if len(cur[ci].trajectory) >= self.fuse_min_segments
                    and all(maxcol[oi][oj] < h
                            for cj, oj in enumerate(orig) if cj != ci)}
            plans.append(self._build(cur, fused_members=fused_members))
        return plans

    def commit(self, plan: ExecutionPlan) -> ExecutionPlan:
        """Fold a plan that is actually dispatching into the cumulative
        ledgers (planner counters, trajectory-key set, batcher padding
        stats). The engines call this from the pipeline's dispatch phase —
        a staged-then-dropped plan never touches the ledgers, so replans
        leak no accounting (the staged-state audit's planner half)."""
        st = plan.stats
        self.plans += 1
        self.merges += st.merges
        self.lanes_planned += st.lanes
        self.lane_cells += sum(l.real_cells for l in plan.lanes)
        self.fused_segments += st.fused_segments
        self.deadline_urgent += st.deadline_urgent
        self.deadline_splits += st.deadline_splits
        self.modeled_cycles += st.modeled_cycles
        self.base_cycles += st.base_cycles
        for l in plan.lanes:
            self.trajectory_keys.add(l.traj_key)
        self.batcher.record(plan.tiles)
        return plan

    def choose_precision(self, candidates: Sequence[Tuple[str, Tuple]],
                         record: bool = True) -> str:
        """Pick the execution precision for one request — the third planner
        knob next to merging and quality. ``candidates`` is a sequence of
        ``(precision, trajectory)`` pairs, each trajectory already carrying
        that precision's stage-key markers (so it prices through the same
        :meth:`TileCostModel.trajectory_cycles` every other decision uses).

        Deterministic: the strict argmin of modeled trajectory cycles,
        scanning candidates in the given order — engines list fp32 first,
        so a quantized tier only wins by being STRICTLY cheaper, and ties
        keep full precision. ``record=False`` skips the decision counters
        for pure pricing probes (``modeled_request_ms``/backlog estimates),
        so ``precision_decisions`` counts actual admissions only."""
        cands = list(candidates)
        if not cands:
            raise ValueError("choose_precision needs at least one candidate")
        best_p: Optional[str] = None
        best_c: Optional[float] = None
        for p, traj in cands:
            c = self.cost_model.trajectory_cycles(traj)
            if best_c is None or c < best_c:
                best_p, best_c = p, c
        if record:
            self.precision_decisions[best_p] = (
                self.precision_decisions.get(best_p, 0) + 1)
        return best_p

    def advance_items(self, items: Sequence[PlanItem],
                      plan: ExecutionPlan) -> List[PlanItem]:
        """Predicted next-step population after ``plan`` runs over
        ``items``: lane-fused members run to completion and leave, items
        at their last trajectory segment retire, everything else advances
        one offset (caps and deadlines are not propagated — a cap only
        binds at the embed stage, which no advanced item revisits)."""
        return self._advance(items, plan)[0]

    @staticmethod
    def _advance(items: Sequence[PlanItem], plan: ExecutionPlan
                 ) -> Tuple[List[PlanItem], List[int]]:
        fused = {l.member for l in plan.lanes}
        nxt: List[PlanItem] = []
        kept: List[int] = []
        for i, it in enumerate(items):
            if i in fused or len(it.trajectory) <= 1:
                continue
            traj = it.trajectory[1:]
            nxt.append(PlanItem(stage=traj[0][0], n_tokens=traj[0][1],
                                trajectory=traj))
            kept.append(i)
        return nxt, kept

    def _build(self, items: Sequence[PlanItem],
               fused_members: Optional[Set[int]] = None) -> ExecutionPlan:
        """Pure plan construction — no ledger mutation (see
        :meth:`commit`). ``fused_members`` overrides the express-lane
        singleton scan with a precomputed set (``plan_ahead``'s memoized
        horizon steps); ``None`` runs the exact pairwise scan."""
        raw = [(it.stage, it.n_tokens) if it.cap is None
               else (it.stage, it.n_tokens, it.cap) for it in items]
        base_tiles = self.batcher.partition(raw)

        if self.mode == "off":
            stats = self._plan_stats(base_tiles, [], items, base_tiles,
                                     merges=0, urgent=set(), splits=0)
            return ExecutionPlan(tuple(base_tiles), (), stats, ())

        urgent = self._urgent_members(items)
        if self.mode in ("fuse", "full"):
            if fused_members is None:
                lanes = self._fuse(items)
            else:
                lanes = [FusedLane(member=i, trajectory=items[i].trajectory)
                         for i in sorted(fused_members)]
        else:
            lanes = []
        fused = {l.member for l in lanes}
        # a fusible item is by construction a singleton in its current
        # bucket, so removing it removes exactly its singleton tile
        tiles = [t for t in base_tiles
                 if not (len(t.members) == 1 and t.members[0] in fused)]
        tiles, splits = self._split_urgent(tiles, urgent - fused, items)
        merges = 0
        if self.mode in ("merge", "full"):
            tiles, merges = self._merge(tiles, items, exclude=urgent)
        tiles = self._order(tiles, urgent)
        stats = self._plan_stats(tiles, lanes, items, base_tiles,
                                 merges=merges, urgent=urgent, splits=splits)
        return ExecutionPlan(tuple(tiles),
                             tuple(sorted(lanes, key=lambda l: l.member)),
                             stats, tuple(sorted(urgent)))

    def stats(self) -> Dict[str, object]:
        """Cumulative planner counters (the engine folds these into its
        ``stats()`` under ``plan_*``)."""
        cm = self.cost_model
        saving = self.base_cycles - self.modeled_cycles
        out = {
            "mode": self.mode,
            "plans": self.plans,
            "merges": self.merges,
            "lanes": self.lanes_planned,
            "lane_cells": self.lane_cells,
            "fused_segments": self.fused_segments,
            "deadline_urgent": self.deadline_urgent,
            "deadline_splits": self.deadline_splits,
            "trajectory_count": len(self.trajectory_keys),
            "modeled_cycles": self.modeled_cycles,
            "base_cycles": self.base_cycles,
            "modeled_saving_cycles": saving,
            "modeled_saving_ms": cm.ms(saving),
            "calibrated": cm.calibrated,
        }
        for p in PRECISIONS:
            out[f"precision_{p}"] = self.precision_decisions.get(p, 0)
        return out

    @property
    def trajectory_count(self) -> int:
        """Distinct fused-lane compile identities planned so far — together
        with the batcher's bucket set this bounds jit recompiles."""
        return len(self.trajectory_keys)

    # -- deadline handling -------------------------------------------------
    def _urgent_members(self, items: Sequence[PlanItem]) -> Set[int]:
        """Members whose modeled slack has run out: time left is below the
        modeled cost of their remaining trajectory."""
        urgent: Set[int] = set()
        for i, it in enumerate(items):
            if it.deadline_left_ms is None:
                continue
            traj = it.trajectory or ((it.stage, it.n_tokens),)
            remaining_ms = self.cost_model.ms(
                self.cost_model.trajectory_cycles(traj))
            if it.deadline_left_ms - remaining_ms <= 0.0:
                urgent.add(i)
        return urgent

    def _split_urgent(self, tiles: List[Tile], urgent: Set[int],
                      items: Sequence[PlanItem]
                      ) -> Tuple[List[Tile], int]:
        """Carve urgent members out of shared tiles into their own
        exact-count singleton tiles (smaller batch tile = less work on the
        urgent request's critical path; dispatch ordering puts them first).
        Splitting preserves exactness: the carved tile is exact-count and
        the remainder keeps its bucket's n_tile."""
        if not urgent:
            return tiles, 0
        out: List[Tile] = []
        splits = 0
        for t in tiles:
            mine = [m for m in t.members if m in urgent]
            if not mine or len(t.members) == 1:
                out.append(t)
                continue
            splits += 1
            rest = [m for m in t.members if m not in urgent]
            for m in mine:
                it = items[m]
                out.append(Tile(
                    stage=t.stage, members=(m,), n_tokens=(it.n_tokens,),
                    n_tile=self.batcher.tile_tokens(it.n_tokens, it.cap),
                    b_tile=1))
            if rest:
                out.append(Tile(
                    stage=t.stage, members=tuple(rest),
                    n_tokens=tuple(items[m].n_tokens for m in rest),
                    n_tile=t.n_tile,
                    b_tile=self.batcher.tile_batch(len(rest))))
        return out, splits

    # -- express lanes -----------------------------------------------------
    def _fuse(self, items: Sequence[PlanItem]) -> List[FusedLane]:
        """Items that are singletons in EVERY bucket of their remaining
        trajectory. Trajectory offsets align with engine steps, so two live
        items can only ever share a future bucket at equal offsets — one
        pairwise scan decides fusibility exactly (arrivals admitted later
        always trail in segment index and can never collide)."""
        lanes: List[FusedLane] = []
        tt = self.batcher.tile_tokens
        for i, it in enumerate(items):
            if len(it.trajectory) < self.fuse_min_segments:
                continue
            solo = True
            for j, jt in enumerate(items):
                if j == i:
                    continue
                other = jt.trajectory or ((jt.stage, jt.n_tokens),)
                for d in range(min(len(it.trajectory), len(other))):
                    si, ni = it.trajectory[d]
                    sj, nj = other[d]
                    if si == sj and tt(ni) == tt(nj):
                        solo = False
                        break
                if not solo:
                    break
            if solo:
                lanes.append(FusedLane(member=i, trajectory=it.trajectory))
        return lanes

    def _pairwise_last_collision(self, items: Sequence[PlanItem]
                                 ) -> List[List[int]]:
        """``maxcol[i][j]`` = largest trajectory offset at which items
        ``i`` and ``j`` would land in the same bucket (-1 = never). One
        O(n²·L) scan; ``plan_ahead`` derives every horizon step's fusible
        set from it by comparison — ``i`` is solo among a live set at
        offset ``h`` iff ``maxcol[i][j] < h`` for every live ``j`` (no
        collision at or past ``h``), which is exactly :meth:`_fuse`'s
        pairwise check on the advanced trajectories."""
        tt = self.batcher.tile_tokens
        trajs = [it.trajectory or ((it.stage, it.n_tokens),) for it in items]
        n = len(items)
        maxcol = [[-1] * n for _ in range(n)]
        for i in range(n):
            for j in range(i + 1, n):
                m = -1
                ti, tj = trajs[i], trajs[j]
                for d in range(min(len(ti), len(tj))):
                    if (ti[d][0] == tj[d][0]
                            and tt(ti[d][1]) == tt(tj[d][1])):
                        m = d
                maxcol[i][j] = maxcol[j][i] = m
        return maxcol

    # -- bucket merging ----------------------------------------------------
    def _merge(self, tiles: List[Tile], items: Sequence[PlanItem],
               exclude: Set[int]) -> Tuple[List[Tile], int]:
        """Greedy bin-packing of neighboring token buckets per stage: walk
        each stage's tiles in ascending n_tile and absorb a tile into its
        neighbor whenever the cost model says the merged masked tile is
        cheaper than two dispatches. Urgent members never merge."""
        cm = self.cost_model
        groups: Dict = {}
        out: List[Tile] = []
        merges = 0
        for t in tiles:
            if any(m in exclude for m in t.members):
                out.append(t)  # deadline-pinned: never pad its rows further
            else:
                groups.setdefault(t.stage, []).append(t)
        for stage in sorted(groups, key=repr):
            group = sorted(groups[stage], key=lambda t: (t.n_tile, t.members))
            cur = group[0]
            for nxt in group[1:]:
                cand = self._merged(cur, nxt, items)
                if cand is not None and (cm.tile_cycles(cur)
                                         + cm.tile_cycles(nxt)
                                         - cm.tile_cycles(cand)) > 0.0:
                    cur = cand
                    merges += 1
                else:
                    out.append(cur)
                    cur = nxt
            out.append(cur)
        return out, merges

    def _merged(self, a: Tile, b: Tile,
                items: Sequence[PlanItem]) -> Optional[Tile]:
        """The masked tile covering a ∪ b, or None when a hard token cap
        (e.g. the embed stage's position-table capacity) forbids padding a
        member to the merged tile width."""
        n_tile = max(a.n_tile, b.n_tile)
        members = a.members + b.members
        for m in members:
            cap = items[m].cap
            if cap is not None and cap < n_tile:
                return None
        if self.batcher.max_batch and len(members) > self.batcher.max_batch:
            return None
        return Tile(stage=a.stage, members=members,
                    n_tokens=a.n_tokens + b.n_tokens, n_tile=n_tile,
                    b_tile=self.batcher.tile_batch(len(members)))

    # -- ordering / accounting ---------------------------------------------
    @staticmethod
    def _order(tiles: List[Tile], urgent: Set[int]) -> List[Tile]:
        """Deterministic dispatch order, urgent tiles first (forced early
        dispatch — the host runs tiles sequentially, so ordering is the
        within-step latency lever)."""
        def key(t: Tile):
            has_urgent = any(m in urgent for m in t.members)
            return (0 if has_urgent else 1, repr((t.stage, t.n_tile,
                                                  t.members)))
        return sorted(tiles, key=key)

    def _plan_stats(self, tiles: List[Tile], lanes: List[FusedLane],
                    items: Sequence[PlanItem], base_tiles: List[Tile],
                    merges: int, urgent: Set[int], splits: int) -> PlanStats:
        """Per-plan accounting only — the cumulative ledgers are folded by
        :meth:`commit` when (and only when) the plan dispatches."""
        cm = self.cost_model
        fused = {l.member for l in lanes}
        modeled = (sum(cm.tile_cycles(t) for t in tiles)
                   + sum(cm.lane_cycles(l) for l in lanes))
        # identity baseline: per-bucket tiles now + one dispatch per future
        # segment for the items a lane absorbs (the lane replaces those
        # future dispatches, so they belong in its baseline)
        base = sum(cm.tile_cycles(t) for t in base_tiles
                   if not (len(t.members) == 1 and t.members[0] in fused))
        base += sum(cm.trajectory_cycles(items[l.member].trajectory)
                    for l in lanes)
        return PlanStats(
            tiles=len(tiles), lanes=len(lanes), merges=merges,
            fused_segments=sum(len(l.trajectory) for l in lanes),
            deadline_urgent=len(urgent), deadline_splits=splits,
            modeled_cycles=modeled, base_cycles=base)
