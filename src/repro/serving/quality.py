"""QualityController — keep-rates as a load-control knob.

The paper fixes the TDM keep-rate ``r_t`` at design time; the adaptive-
pruning literature (HeatViT, SPViT, PPT) is unanimous that it should be an
inference-time decision. This module is the serving half of that argument:
the controller maps *scheduler pressure* (queue depth against the slot
count, deadline slack priced by the calibrated ``TileCostModel``) plus each
request's accuracy/latency preference to a per-step keep schedule —
graceful **quality** degradation under overload, the serving twin of
``dist/elastic``'s device degradation.

Design constraints, in order:

* **Controller off == today.** Mode ``strict`` (the default) returns every
  schedule untouched, so plans, stage keys, digests and recompiles are
  bit-identical to the pre-controller engine at every pipeline depth.
* **Resolution is pure.** ``resolve`` mutates nothing — the engine calls
  it from the staging phase, which must stay drop/replan-safe
  (``StepPipeline``). Accounting folds in via ``record`` at dispatch, the
  same commit discipline as ``TilePlanner``.
* **Recompiles stay bounded.** Tightened rates only ever come from the
  quantized ``keep_levels`` grid, so the set of distinct TDM ``k`` values
  (= jit cache keys) is bounded by grid × token-count buckets no matter
  how pressure fluctuates. Untightened entries keep the request's own
  base rate — exactly the pre-controller behavior.
* **Never loosen a step below its request's floor, never loosen at all.**
  Tightening moves DOWN the grid only; ``keep_floor`` truncates the grid
  from below.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Set, Tuple

__all__ = ["QUALITY_MODES", "QualityConfig", "QualityController"]

QUALITY_MODES = ("strict", "auto", "degrade")

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class QualityConfig:
    """Controller policy knobs.

    ``mode``        — ``strict``: controller off (schedules untouched);
                      ``auto``: tighten with queue/deadline pressure;
                      ``degrade``: run every consenting request at the
                      tightest usable grid level (shed-load mode).
    ``keep_levels`` — the quantized keep-rate grid, strictly descending in
                      (0, 1]. Resolved rates are drawn from here (bounded
                      recompiles); a base rate below every usable level is
                      left alone.
    ``keep_floor``  — truncates the grid: levels below it are unusable, so
                      no request is ever tightened past it.
    ``backlog_per_level`` — in ``auto`` mode, one grid level of tightening
                      per this many *fully-backlogged slot sets* of queue
                      depth (pressure = queue_depth // num_slots //
                      backlog_per_level).
    """

    mode: str = "strict"
    keep_levels: Tuple[float, ...] = (1.0, 0.85, 0.7, 0.55, 0.4)
    keep_floor: float = 0.4
    backlog_per_level: int = 1

    def __post_init__(self):
        if self.mode not in QUALITY_MODES:
            raise ValueError(f"quality mode must be one of {QUALITY_MODES}, "
                             f"got {self.mode!r}")
        lv = tuple(float(l) for l in self.keep_levels)
        if not lv:
            raise ValueError("keep_levels must be non-empty")
        for l in lv:
            if not (math.isfinite(l) and 0.0 < l <= 1.0):
                raise ValueError(f"keep_levels entries must be finite in "
                                 f"(0, 1], got {l}")
        if any(a <= b for a, b in zip(lv, lv[1:])):
            raise ValueError(f"keep_levels must be strictly descending, "
                             f"got {lv}")
        if not (math.isfinite(self.keep_floor)
                and 0.0 < self.keep_floor <= 1.0):
            raise ValueError(f"keep_floor must be finite in (0, 1], got "
                             f"{self.keep_floor}")
        if not any(l >= self.keep_floor - _EPS for l in lv):
            raise ValueError(f"keep_floor {self.keep_floor} is above every "
                             f"keep level {lv} — no usable grid remains")
        if self.backlog_per_level < 1:
            raise ValueError("backlog_per_level must be >= 1")
        object.__setattr__(self, "keep_levels", lv)

    @property
    def usable_levels(self) -> Tuple[float, ...]:
        """The grid truncated at the floor (descending)."""
        return tuple(l for l in self.keep_levels
                     if l >= self.keep_floor - _EPS)


class QualityController:
    """Resolves per-request keep schedules at plan time.

    Owned by the :class:`~repro.serving.planner.TilePlanner` (quality is a
    planning decision: it rewrites trajectories, and trajectories are what
    plans are built from). The engine calls :meth:`resolve` once per live
    request per staged step and :meth:`record` at dispatch.
    """

    def __init__(self, config: Optional[QualityConfig] = None,
                 num_slots: int = 1):
        self.config = config if config is not None else QualityConfig()
        self.num_slots = max(int(num_slots), 1)
        # cumulative accounting (folded at dispatch via record())
        self.decisions = 0
        self.tightened = 0
        self.deadline_tightened = 0
        self.levels_used: Set[float] = set()
        # tighten events per resolved keep level (the per-level counter
        # the obs metrics registry exports)
        self.level_counts: Dict[float, int] = {}

    @property
    def enabled(self) -> bool:
        return self.config.mode != "strict"

    # -- pure resolution ---------------------------------------------------
    def pressure_steps(self, queue_depth: int) -> int:
        """Queue backlog -> grid-tightening steps: one level per
        ``backlog_per_level`` full slot-widths of waiting requests. Zero
        when the queue fits the slots — the controller is a no-op on an
        unloaded engine."""
        return (max(int(queue_depth), 0) // self.num_slots
                // self.config.backlog_per_level)

    def tighten(self, r: float, steps: int) -> float:
        """``r`` moved ``steps`` levels down the usable grid (monotone:
        never up, never past the floor). A rate already below every usable
        level is left alone — the controller never *loosens*."""
        if steps <= 0:
            return r
        below = [l for l in self.config.usable_levels if l < r - _EPS]
        if not below:
            return r
        return below[min(steps, len(below)) - 1]

    def resolve(self, schedule: Sequence[float], done: int = 0,
                preference: Optional[str] = None, queue_depth: int = 0,
                deadline_left_ms: Optional[float] = None,
                remaining_ms: Optional[Callable[[Tuple[float, ...]], float]]
                = None) -> Tuple[float, ...]:
        """The per-step keep schedule a request should run under NOW.

        Pure — safe to call from the pipeline's staging phase and to call
        again after a drop/replan. Entries before ``done`` (TDM steps
        already executed) pass through untouched; they are history.

        ``preference`` is the request's accuracy/latency stance: ``strict``
        pins the base schedule even under load (accuracy-critical),
        ``degrade`` invites maximum tightening (latency-critical), ``None``
        follows the controller mode. A ``strict`` *controller* ignores
        preferences entirely — controller-off must be bit-exact with the
        pre-controller engine.

        ``deadline_left_ms`` + ``remaining_ms`` (a callable pricing the
        remaining trajectory under a candidate schedule, from the
        calibrated cost model) add deadline pressure in ``auto`` mode: the
        schedule tightens further until the modeled remainder fits the
        slack or the floor is reached.
        """
        base = tuple(float(r) for r in schedule)
        if not self.enabled:
            return base
        mode = self.config.mode
        if preference is not None:
            if preference not in QUALITY_MODES:
                raise ValueError(f"quality preference must be one of "
                                 f"{QUALITY_MODES}, got {preference!r}")
            mode = preference
        if mode == "strict":
            return base

        max_steps = len(self.config.usable_levels)
        if mode == "degrade":
            steps = max_steps
        else:  # auto
            steps = min(self.pressure_steps(queue_depth), max_steps)

        def apply(t: int) -> Tuple[float, ...]:
            return base[:done] + tuple(
                self.tighten(r, t) for r in base[done:])

        out = apply(steps)
        if (mode == "auto" and deadline_left_ms is not None
                and remaining_ms is not None):
            while steps < max_steps and remaining_ms(out) > deadline_left_ms:
                steps += 1
                out = apply(steps)
        return out

    # -- dispatch-time accounting -----------------------------------------
    def record(self, decisions: int, tightened: int,
               levels: Sequence[float] = (),
               deadline_tightened: int = 0) -> None:
        """Fold one dispatched step's resolution accounting into the
        cumulative counters (the engine calls this next to
        ``TilePlanner.commit`` — staged-then-dropped steps leave no
        trace)."""
        self.decisions += decisions
        self.tightened += tightened
        self.deadline_tightened += deadline_tightened
        for l in levels:
            lv = float(l)
            self.levels_used.add(lv)
            self.level_counts[lv] = self.level_counts.get(lv, 0) + 1

    def stats(self) -> Dict[str, Any]:
        return {
            "mode": self.config.mode,
            "keep_floor": self.config.keep_floor,
            "keep_levels": self.config.keep_levels,
            "decisions": self.decisions,
            "tightened": self.tightened,
            "deadline_tightened": self.deadline_tightened,
            "levels_used": tuple(sorted(self.levels_used)),
            "level_counts": tuple(sorted(self.level_counts.items())),
        }
