"""RaggedBatcher — load-balanced regrouping of ragged vision requests.

The packed ViT's dynamic token pruning makes the in-flight population
*ragged*: every image enters with its own patch count and sheds tokens at
each TDM layer according to its own keep rate, so at any instant the live
requests sit at different segments with diverging token counts. The
SBMM / attention kernels want rectangular work. The batcher is the bridge —
the software twin of the paper's load-balancing of irregular block-pruned
work across PE lanes: at every segment boundary it bin-packs the survivors
into dense tiles.

Two packing modes (the vision bench A/Bs them):

* ``balanced`` — requests are grouped into *token-count buckets*: the
  bucket key is the token count rounded up to ``token_tile`` (default 1 =
  exact counts) and the batch dimension is padded to the next power of two.
  With ``token_tile == 1`` no token padding exists, so results are
  bit-exact against the single-request path; larger tiles trade a bounded
  amount of (masked) padding for fewer distinct compiled shapes.
* ``naive``   — the classic padded batch: per segment, ONE tile padded to
  the largest member's token count and to ``max_batch`` rows. Small images
  pay the largest image's quadratic attention cost; the bench shows the
  throughput gap.

Guarantees (property-tested in tests/test_ragged_batcher.py):
  * every item lands in exactly one tile (zero dropped requests);
  * per-row token padding < ``token_tile`` (== 0 when ``token_tile`` is 1)
    in balanced mode;
  * batch padding < the tile's member count (next-pow2 rounding), so a
    tile's padded cell area is < 4x its real cell area when
    ``token_tile`` is 1.

Recompiles are bounded by the bucket set: every tile maps to a
``bucket_key`` = (stage key, token tile, batch tile), and the engine's
jitted segments compile at most once per distinct key.

The batcher is the *grouping* layer; cost-driven rewrites of the grouping
(bucket merging, express-lane fusion, deadline splits) live above it in
``serving.planner.TilePlanner``, which calls :meth:`partition` /
:meth:`record` so this class keeps owning the padding/bucket accounting
for whatever was actually dispatched.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from repro.serving.cache_manager import bucket_length


@dataclasses.dataclass(frozen=True)
class Tile:
    """One rectangular unit of work: ``members`` (caller-side item indices)
    stacked into a [b_tile, n_tile] token grid."""

    stage: Hashable            # opaque segment key (weights + static args)
    members: Tuple[int, ...]   # item indices covered by this tile
    n_tokens: Tuple[int, ...]  # per-member real token counts
    n_tile: int                # padded token count
    b_tile: int                # padded batch rows (>= len(members))

    @property
    def bucket_key(self) -> Tuple:
        """Compile-shape identity: tiles sharing it reuse one XLA program
        (masked and unmasked variants of a shape trace separately, so the
        mask bit is part of the identity)."""
        return (self.stage, self.n_tile, self.b_tile, self.needs_mask)

    @property
    def real_cells(self) -> int:
        return sum(self.n_tokens)

    @property
    def padded_cells(self) -> int:
        return self.n_tile * self.b_tile

    @property
    def needs_mask(self) -> bool:
        """True when any member row is token-padded (engine must pass
        ``n_valid``); batch-only padding needs no mask — rows are
        independent."""
        return any(n != self.n_tile for n in self.n_tokens)


class RaggedBatcher:
    """Plans tiles over (stage, token-count) items; accumulates padding and
    bucket statistics across calls."""

    def __init__(self, token_tile: int = 1, mode: str = "balanced",
                 max_batch: Optional[int] = None):
        if token_tile <= 0:
            raise ValueError(f"token_tile must be positive, got {token_tile}")
        if mode not in ("balanced", "naive"):
            raise ValueError(f"mode must be 'balanced' or 'naive', "
                             f"got {mode!r}")
        if mode == "naive" and not max_batch:
            raise ValueError("naive mode pads to max_batch rows; pass it")
        self.token_tile = token_tile
        self.mode = mode
        self.max_batch = max_batch
        # cumulative accounting
        self.real_cells = 0
        self.padded_cells = 0
        self.tiles_planned = 0
        self.bucket_keys: set = set()

    # -- tiling rules ------------------------------------------------------
    def tile_tokens(self, n: int, cap: Optional[int] = None) -> int:
        t = self.token_tile
        tile = -(-n // t) * t
        if cap is not None:
            tile = min(tile, cap)  # never quantize past a hard shape bound
        return tile

    def tile_batch(self, b: int) -> int:
        # same pow2 bucketing as the LM path's prefix-length buckets
        return bucket_length(b, cap=self.max_batch or b, lo=1)

    # -- planning ----------------------------------------------------------
    def plan(self, items: Sequence[Tuple]) -> List[Tile]:
        """items: ``(stage_key, n_tokens)`` — or ``(stage_key, n_tokens,
        n_cap)`` where ``n_cap`` bounds the padded token tile (e.g. the
        position-table capacity at the embed stage) — per live request.
        Returns tiles covering every item exactly once, deterministically
        ordered, and records them into the cumulative stats. This is the
        *identity plan* — ``serving.planner.TilePlanner`` in mode ``off``
        reproduces it exactly; richer modes call :meth:`partition`,
        transform the tiles (merge/fuse/split), then :meth:`record` what
        was actually dispatched."""
        tiles = self.partition(items)
        self.record(tiles)
        return tiles

    def partition(self, items: Sequence[Tuple]) -> List[Tile]:
        """Pure grouping: the tiles of :meth:`plan` without touching the
        cumulative accounting (callers that rewrite the tiling — the
        ``TilePlanner`` — record the final tiles themselves)."""
        groups: Dict[Tuple, List[int]] = {}
        for idx, item in enumerate(items):
            stage, n = item[0], item[1]
            cap = item[2] if len(item) > 2 else None
            if n <= 0:
                raise ValueError(f"item {idx}: token count must be "
                                 f"positive, got {n}")
            if cap is not None and cap < n:
                raise ValueError(f"item {idx}: token cap {cap} below "
                                 f"count {n}")
            key = (stage,) if self.mode == "naive" \
                else (stage, self.tile_tokens(n, cap))
            groups.setdefault(key, []).append(idx)

        tiles: List[Tile] = []
        for key in sorted(groups, key=repr):
            members = groups[key]
            if self.mode == "naive":
                # one tile per stage, token-padded to the largest member,
                # batch-padded to the full slot width — but never beyond
                # it: overflow spills into further max_batch-wide tiles
                cap = self.max_batch
                for s in range(0, len(members), cap):
                    chunk = members[s: s + cap]
                    counts = tuple(items[i][1] for i in chunk)
                    tiles.append(Tile(
                        stage=key[0], members=tuple(chunk),
                        n_tokens=counts, n_tile=max(counts), b_tile=cap))
            else:
                cap = self.max_batch or len(members)
                for s in range(0, len(members), cap):
                    chunk = members[s: s + cap]
                    counts = tuple(items[i][1] for i in chunk)
                    tiles.append(Tile(
                        stage=key[0], members=tuple(chunk), n_tokens=counts,
                        n_tile=key[1], b_tile=self.tile_batch(len(chunk))))
        return tiles

    def record(self, tiles: Sequence[Tile]) -> None:
        """Fold dispatched tiles into the cumulative padding/bucket stats."""
        for t in tiles:
            self.real_cells += t.real_cells
            self.padded_cells += t.padded_cells
            self.tiles_planned += 1
            self.bucket_keys.add(t.bucket_key)

    # -- observability -----------------------------------------------------
    @property
    def bucket_count(self) -> int:
        """Distinct compile shapes planned so far — the recompile bound."""
        return len(self.bucket_keys)

    def padding_waste(self) -> float:
        """Fraction of dispatched cells that were padding."""
        if self.padded_cells == 0:
            return 0.0
        return 1.0 - self.real_cells / self.padded_cells

    def stats(self) -> Dict[str, float]:
        return {
            "mode": self.mode,
            "token_tile": self.token_tile,
            "tiles": self.tiles_planned,
            "buckets": self.bucket_count,
            "real_cells": self.real_cells,
            "padded_cells": self.padded_cells,
            "padding_waste": self.padding_waste(),
        }
