"""ModelRunner — owns the jitted model steps behind a compile cache.

One of the three serving layers (Scheduler / KVCacheManager / ModelRunner —
see ``repro.serving.engine``). The runner holds the params and the jitted
prefill / per-slot prefill / decode functions, and tracks which input
shapes have been compiled: serving cost regressions from jit churn are
observable as ``runner.compile_count`` (our shape ledger) and
``runner.jit_compile_count()`` (the jit caches' own entry counts).

Shape discipline is what bounds recompiles:
  * ``decode``       — one shape per batch width, compiled once.
  * ``prefill``      — one shape per (batch, padded length); the fallback
    whole-batch path still pays one compile per distinct common length.
  * ``prefill_slot`` — one shape per *bucketed* prefix length (the
    KVCacheManager rounds prompts up to power-of-two buckets), so a churny
    request mix compiles at most ``log2(max_len)``-ish variants; the slot
    index is a traced argument and never recompiles.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import steps as ST


def build_padded_batch(prefixes: Sequence[Optional[np.ndarray]],
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Left-pad ``prefixes`` (None = inactive slot -> one dummy token) to
    their common length. Returns ``(tokens [B, L], valid_start [B])``."""
    B = len(prefixes)
    L = max(len(p) for p in prefixes if p is not None)
    toks = np.zeros((B, L), np.int32)
    starts = np.full((B,), max(L - 1, 0), np.int32)  # dummy slots
    for i, p in enumerate(prefixes):
        if p is None:
            continue
        toks[i, L - len(p):] = p
        starts[i] = L - len(p)
    return toks, starts


class ModelRunner:
    """Jitted step functions for one (cfg, params) pair.

    ``donate_caches`` donates the cache pytree argument of every step to
    its output (``donate_argnums``): prefill/decode consume a cache state
    and return the successor of identical shapes/dtypes, so XLA reuses the
    buffers in place instead of allocating a fresh cache tree per step.
    The serving engines always rebind ``kvm.caches`` to the returned tree,
    which is exactly the discipline donation requires (reading a donated
    input afterwards raises — the engines never do). Disable it for
    callers that hold on to pre-step cache references."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 donate_caches: bool = True):
        self.cfg = cfg
        self.params = params
        self.donate_caches = donate_caches
        self.masked = cfg.family in ST.MASKABLE_FAMILIES
        self.supports_slot_prefill = cfg.family in ST.SLOT_PREFILL_FAMILIES
        don = dict(donate_argnums=(2,)) if donate_caches else {}
        self._prefill = jax.jit(ST.make_prefill(cfg), **don)
        self._decode = jax.jit(ST.make_decode_step(cfg), **don)
        self._prefill_slot = (jax.jit(ST.make_prefill_slot(cfg), **don)
                              if self.supports_slot_prefill else None)
        self._compiled: set = set()

    # -- steps -------------------------------------------------------------
    def prefill(self, tokens: np.ndarray, valid_start: Optional[np.ndarray],
                caches: Any) -> Tuple[jax.Array, Any]:
        """Whole-batch prefill of ``tokens`` [B, L] (left-padded; pad depth
        per row in ``valid_start``). Returns (next_token [B], caches)."""
        batch = {"tokens": jnp.asarray(tokens)}
        if self.masked and valid_start is not None:
            batch["valid_start"] = jnp.asarray(valid_start, jnp.int32)
        self._compiled.add(("prefill",) + tokens.shape)
        return self._prefill(self.params, batch, caches)

    def prefill_slot(self, prompt: np.ndarray, caches: Any, slot: int,
                     bucket_len: int) -> Tuple[int, Any]:
        """Prefill one prompt into batch row ``slot`` of the live caches,
        padded to ``bucket_len`` (from ``KVCacheManager.admit``). Returns
        (next_token as int, caches) — the synchronous wrapper over
        :meth:`prefill_slot_async` (materializing the token blocks)."""
        tok, caches = self.prefill_slot_async(prompt, caches, slot,
                                              bucket_len)
        return int(np.asarray(tok)[0]), caches

    def prefill_slot_async(self, prompt: np.ndarray, caches: Any, slot: int,
                           bucket_len: int) -> Tuple[jax.Array, Any]:
        """Async per-slot prefill: identical dispatch to
        :meth:`prefill_slot` but returns the next-token as a pending
        device handle ([1] array) instead of blocking on it — the
        pipelined continuous path chains it into the decode token vector
        and lets the ``StepPipeline`` block at completion time."""
        if self._prefill_slot is None:
            raise RuntimeError(
                f"per-slot prefill unsupported for family "
                f"'{self.cfg.family}' — use the whole-batch prefill path")
        P = len(prompt)
        row = np.zeros((1, bucket_len), np.int32)
        row[0, bucket_len - P:] = prompt
        batch = {"tokens": jnp.asarray(row),
                 "valid_start": jnp.asarray([bucket_len - P], jnp.int32)}
        self._compiled.add(("prefill_slot", bucket_len))
        return self._prefill_slot(self.params, batch, caches,
                                  jnp.asarray(slot, jnp.int32))

    def decode(self, tokens: np.ndarray, caches: Any,
               valid_start: Optional[jax.Array]) -> Tuple[jax.Array, Any]:
        """One decode step for every slot. ``tokens`` [B] host ints."""
        self._compiled.add(("decode", len(tokens)))
        return self._decode(self.params, jnp.asarray(tokens,
                                                     jnp.int32)[:, None],
                            caches, valid_start=valid_start)

    # -- compile observability ---------------------------------------------
    @property
    def compile_count(self) -> int:
        """Distinct step shapes dispatched so far (our ledger)."""
        return len(self._compiled)

    def compiled_shapes(self) -> List[Tuple]:
        return sorted(self._compiled)

    def jit_compile_count(self) -> int:
        """Total entries across the jit caches themselves (ground truth —
        counts what XLA actually compiled, including dtype/sharding
        variants our shape ledger can't see)."""
        fns = [self._prefill, self._decode] + (
            [self._prefill_slot] if self._prefill_slot is not None else [])
        total = 0
        for fn in fns:
            try:
                total += fn._cache_size()
            except AttributeError:  # older jax: fall back to the ledger
                return self.compile_count
        return total
