"""Scheduler — admission/retirement policy over waiting + in-flight requests.

One of the three serving layers (Scheduler / KVCacheManager / ModelRunner —
see ``repro.serving.engine``). The Scheduler owns *which request runs in
which slot and when*; it never touches device state. Both serve paths
(static waves and continuous batching) drive their request lifecycles
through it, so they emit one unified event stream:

    ("admit",   uid)    request entered a slot
    ("retire",  uid)    request finished, slot freed
    ("reject",  uid)    request refused at submit by the admission hook
    ("degrade", desc)   elastic event observed mid-stream (mesh shrank)

Backlog accounting is first-class: :meth:`stats` reports queue depth (and
its peak), slot occupancy, and the submit/reject/admit/retire counters.
These are THE load counters — the ``QualityController`` reads the same
``queue_depth`` the traffic harness reports, so "backlog pressure" means
one thing everywhere.

``admission_control`` is the load-shedding hook: an optional callable
``hook(request) -> bool`` consulted once per submitted request. Returning
``False`` refuses the request — it never enters the waiting queue, a
``("reject", uid)`` event is emitted, and ``submitted_total`` does not
advance (a rejected request must not trigger the engines' mid-step
replans). ``repro.traffic.admission`` installs its cost-model controller
here; the hook may mutate the request (e.g. set its quality preference)
before accepting it.

Admission order is policy-pluggable: pass ``policy="fifo"`` (default), one
of the latency-aware built-ins below, or a callable
``policy(waiting: Sequence[Request]) -> int`` returning the index of the
next request to admit. The built-ins are shared by BOTH serve paths (LM
``ServeEngine`` and vision ``VisionEngine``) — they read request size
duck-typed (``prompt`` tokens or ``patches`` rows):

* ``"fifo"``                  — arrival order.
* ``"shortest_prompt_first"`` — smallest request first (SJF): minimizes
  mean latency under skewed sizes; ties stay FIFO.
* ``"prune_pressure_aware"``  — prefer the request with the lowest
  *predicted post-prune token load* (``req.prune_load``, set at submit:
  the TDM token-count trajectory for vision, the KV-prune-discounted
  footprint for LMs). HeatViT/SPViT motivate scheduling on the pruned
  load, not the raw size — a heavily-pruned large image is cheaper than a
  lightly-pruned medium one. Vision requests carrying a ``deadline_ms``
  get the same annotation additionally discounted by deadline tightness
  relative to their cost-model solo latency (``serving.planner``), so the
  SAME policy admits urgent requests earlier — deadline awareness needs
  no separate policy.
"""
from __future__ import annotations

from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

from repro.obs.events import EventLog

# Request lives in engine.py / vision.py (public API compat); import lazily
# to avoid a cycle — the annotation below is intentionally loose.
Request = Any

PolicyFn = Callable[[Sequence[Request]], int]


def request_tokens(req: Request) -> float:
    """Duck-typed request size: LM prompt tokens, or vision patch rows
    (+CLS). The latency-aware policies rank on this."""
    prompt = getattr(req, "prompt", None)
    if prompt is not None:
        return float(len(prompt))
    patches = getattr(req, "patches", None)
    if patches is not None:
        return float(patches.shape[0] + 1)
    return 0.0


def predicted_prune_load(req: Request) -> float:
    """Predicted post-prune token load; falls back to the raw size when the
    submitting engine didn't annotate ``prune_load``."""
    load = getattr(req, "prune_load", None)
    return float(load) if load is not None else request_tokens(req)


def fifo_policy(waiting: Sequence[Request]) -> int:
    return 0


def shortest_prompt_first(waiting: Sequence[Request]) -> int:
    return min(range(len(waiting)), key=lambda i: request_tokens(waiting[i]))


def prune_pressure_aware(waiting: Sequence[Request]) -> int:
    return min(range(len(waiting)),
               key=lambda i: predicted_prune_load(waiting[i]))


_POLICIES: Dict[str, PolicyFn] = {
    "fifo": fifo_policy,
    "shortest_prompt_first": shortest_prompt_first,
    "prune_pressure_aware": prune_pressure_aware,
}


class Scheduler:
    """Tracks waiting requests and slot occupancy; decides admissions."""

    def __init__(self, num_slots: int, policy: "str | PolicyFn" = "fifo",
                 admission_control: Optional[Callable[[Request], bool]]
                 = None, event_capacity: int = 65536):
        if num_slots <= 0:
            raise ValueError(f"num_slots must be positive, got {num_slots}")
        self.num_slots = num_slots
        if isinstance(policy, str):
            if policy not in _POLICIES:
                raise ValueError(f"unknown policy {policy!r}; built-ins: "
                                 f"{sorted(_POLICIES)}")
            policy = _POLICIES[policy]
        self.policy: PolicyFn = policy
        # optional load-shedding gate consulted at submit (see module
        # docstring); engines/harnesses may also install it post-hoc
        self.admission_control = admission_control
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}   # slot -> request
        # bounded ring: len() is the absolute sequence length and slices
        # take absolute indices, so incremental consumers (the traffic
        # harness's events[mark:] scan) are eviction-safe; drain() hands
        # the buffered window to exporters
        self.events = EventLog(capacity=event_capacity)
        # monotone submission counter: the pipelined engines snapshot it
        # when they stage a step and compare before dispatch — a request
        # submitted while a plan is in flight lands in the NEXT plan
        # (stage is rolled back and rebuilt), never mutates the one being
        # staged, and is never silently deferred past a step boundary
        self.submitted_total = 0
        self.rejected_total = 0
        self.admitted_total = 0
        self.retired_total = 0
        self.peak_queue_depth = 0

    # -- request lifecycle -------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> None:
        """Enqueue ``requests`` for admission, consulting the
        ``admission_control`` hook (if any) one request at a time — so a
        controller watching backlog sees each acceptance before pricing
        the next request of the same batch."""
        for req in requests:
            if (self.admission_control is not None
                    and not self.admission_control(req)):
                self.rejected_total += 1
                self.events.append(("reject", req.uid))
                continue
            self.waiting.append(req)
            self.submitted_total += 1
        self.peak_queue_depth = max(self.peak_queue_depth,
                                    len(self.waiting))

    def free_slots(self) -> List[int]:
        return [i for i in range(self.num_slots) if i not in self.running]

    def schedule(self) -> List[Tuple[int, Request]]:
        """Admit waiting requests into free slots (policy order). Returns
        the [(slot, request), ...] admitted this call and emits ``admit``
        events for each."""
        admitted: List[Tuple[int, Request]] = []
        for slot in self.free_slots():
            if not self.waiting:
                break
            idx = self.policy(self.waiting)
            req = self.waiting[idx]
            del self.waiting[idx]
            self.running[slot] = req
            self.events.append(("admit", req.uid))
            self.admitted_total += 1
            admitted.append((slot, req))
        return admitted

    def retire(self, slot: int) -> Request:
        """Free ``slot``; emits a ``retire`` event for its request."""
        req = self.running.pop(slot)
        self.events.append(("retire", req.uid))
        self.retired_total += 1
        return req

    # -- observability -----------------------------------------------------
    def observe(self, kind: str, payload: Any = None) -> None:
        """Record an externally observed event (e.g. elastic degradation)
        into the same stream as admit/retire."""
        self.events.append((kind, payload))

    def drain_events(self) -> List[Tuple[str, Any]]:
        """Consume the buffered event window (see ``EventLog.drain``:
        counters and absolute marks stay valid)."""
        return self.events.drain()

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def num_admissions(self) -> int:
        # counter-backed (NOT an event scan: the ring may have evicted
        # old admit events on a long-lived engine)
        return self.admitted_total

    @property
    def num_retirements(self) -> int:
        return self.retired_total

    @property
    def queue_depth(self) -> int:
        """Requests submitted but not yet in a slot — THE backlog-pressure
        number (the QualityController and the traffic harness both read
        this one, not private mirrors of it)."""
        return len(self.waiting)

    def stats(self) -> Dict[str, Any]:
        """First-class backlog/occupancy block, shared by both engines'
        ``stats()`` (prefixed ``sched_``) and sampled per virtual step by
        the traffic harness."""
        return {
            "queue_depth": self.queue_depth,
            "peak_queue_depth": self.peak_queue_depth,
            "running": len(self.running),
            "free_slots": self.num_slots - len(self.running),
            "num_slots": self.num_slots,
            "submitted_total": self.submitted_total,
            "rejected_total": self.rejected_total,
            "admitted_total": self.admitted_total,
            "retired_total": self.retired_total,
            "events_dropped": self.events.dropped,
        }
