"""Scheduler — admission/retirement policy over waiting + in-flight requests.

One of the three serving layers (Scheduler / KVCacheManager / ModelRunner —
see ``repro.serving.engine``). The Scheduler owns *which request runs in
which slot and when*; it never touches device state. Both serve paths
(static waves and continuous batching) drive their request lifecycles
through it, so they emit one unified event stream:

    ("admit",   uid)    request entered a slot
    ("retire",  uid)    request finished, slot freed
    ("degrade", desc)   elastic event observed mid-stream (mesh shrank)

Admission order is policy-pluggable: pass ``policy="fifo"`` (default) or a
callable ``policy(waiting: Sequence[Request]) -> int`` returning the index
of the next request to admit — e.g. shortest-prompt-first for latency-aware
token-pruning experiments (HeatViT/SPViT motivate keeping such policy out
of the execution loop).
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Deque, Dict, List, Sequence, Tuple

# Request lives in engine.py (public API compat); import lazily to avoid a
# cycle — the annotation below is intentionally loose.
Request = Any

PolicyFn = Callable[[Sequence[Request]], int]


def fifo_policy(waiting: Sequence[Request]) -> int:
    return 0


_POLICIES: Dict[str, PolicyFn] = {"fifo": fifo_policy}


class Scheduler:
    """Tracks waiting requests and slot occupancy; decides admissions."""

    def __init__(self, num_slots: int, policy: "str | PolicyFn" = "fifo"):
        if num_slots <= 0:
            raise ValueError(f"num_slots must be positive, got {num_slots}")
        self.num_slots = num_slots
        self.policy: PolicyFn = (_POLICIES[policy]
                                 if isinstance(policy, str) else policy)
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}   # slot -> request
        self.events: List[Tuple[str, Any]] = []

    # -- request lifecycle -------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> None:
        self.waiting.extend(requests)

    def free_slots(self) -> List[int]:
        return [i for i in range(self.num_slots) if i not in self.running]

    def schedule(self) -> List[Tuple[int, Request]]:
        """Admit waiting requests into free slots (policy order). Returns
        the [(slot, request), ...] admitted this call and emits ``admit``
        events for each."""
        admitted: List[Tuple[int, Request]] = []
        for slot in self.free_slots():
            if not self.waiting:
                break
            idx = self.policy(self.waiting)
            req = self.waiting[idx]
            del self.waiting[idx]
            self.running[slot] = req
            self.events.append(("admit", req.uid))
            admitted.append((slot, req))
        return admitted

    def retire(self, slot: int) -> Request:
        """Free ``slot``; emits a ``retire`` event for its request."""
        req = self.running.pop(slot)
        self.events.append(("retire", req.uid))
        return req

    # -- observability -----------------------------------------------------
    def observe(self, kind: str, payload: Any = None) -> None:
        """Record an externally observed event (e.g. elastic degradation)
        into the same stream as admit/retire."""
        self.events.append((kind, payload))

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def num_admissions(self) -> int:
        return sum(1 for e in self.events if e[0] == "admit")
