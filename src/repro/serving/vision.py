"""VisionEngine — continuous-batching inference for the packed, pruned ViT.

The paper's headline system claim is an accelerator that *serves* the
simultaneously-pruned ViT: multi-level parallelism plus load balancing for
the irregular work left by block-pruned weights and on-the-fly token
pruning. This engine is the software twin of that serving layer:

* Admission rides the same ``Scheduler`` as the LM path (one unified
  admit/retire/degrade event stream, policy-pluggable — FIFO,
  shortest-prompt-first, prune-pressure-aware).
* Execution walks the per-stage segmentation of ``forward_vit_packed``
  (``core.packed_runner.vit_segments``): prune boundaries are batching
  boundaries. Each engine step advances every in-flight image one segment.
* Between segments the ``TilePlanner`` (``serving.planner``) prices the
  ragged population with the accelerator cost model and emits an
  ``ExecutionPlan``: dense token-count tiles (grouped by the
  ``RaggedBatcher``, optionally bin-packed/merged when the modeled padding
  cost is below the dispatch saving), express-lane fused trajectories for
  bucket-singleton requests, and deadline-driven tile splits/ordering for
  requests carrying a ``deadline_ms``. Jit recompiles are bounded by the
  bucket ∪ trajectory set. ``VisionEngineConfig.planner="off"`` (default)
  is the identity plan — exactly PR 4's ``RaggedBatcher.plan`` behavior.

Bit-exactness: in the default ``balanced`` mode with ``token_tile=1``,
buckets hold requests at *identical* token counts, the batch dimension is
padded with don't-care rows (rows are computationally independent), and the
jitted segment bodies are the same pure functions the offline
single-request path composes — so every request's logits are bit-exact
against ``forward_vit_packed`` regardless of batch composition
(tests/test_vision_engine.py). ``token_tile > 1`` and ``naive`` mode
token-pad rows inside masked kernels: same math, FP reduction order may
differ.

Requests may carry per-request keep rates (``r_t``) and arbitrary patch
counts (images of different resolutions) — both are sources of raggedness;
``arrival_step`` staggers admission so the population mixes stages, the
continuous-batching scenario.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packed_runner as PR
from repro.core import quant as Q
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.planner import (PLANNER_MODES, PlanItem, TileCostModel,
                                   TilePlanner)
from repro.serving.pipeline import StagedStep, StepPipeline, StepReport
from repro.serving.quality import (QUALITY_MODES, QualityConfig,
                                   QualityController)
from repro.serving.ragged_batcher import RaggedBatcher
from repro.serving.scheduler import Scheduler

__all__ = ["VisionRequest", "VisionEngineConfig", "VisionEngine"]


@dataclasses.dataclass
class VisionRequest:
    uid: int
    patches: np.ndarray              # [n_patches, patch²·3] float32
    r_t: Optional[float] = None      # per-request TDM keep rate (None = cfg)
    arrival_step: int = 0            # engine step at which it may be admitted
    deadline_ms: Optional[float] = None  # wall-clock SLO from admission; the
    # planner carves the request into smaller, first-dispatched tiles when
    # its modeled slack runs out, and the admission annotation below shrinks
    # so prune_pressure_aware admits tight-deadline requests earlier
    keep_schedule: Optional[Tuple[float, ...]] = None  # explicit per-TDM
    # keep schedule (one entry per TDM segment, in segment order) —
    # overrides r_t; None broadcasts r_t over every TDM step
    quality: Optional[str] = None    # accuracy/latency preference for the
    # QualityController: "strict" pins the base schedule even under load,
    # "degrade" invites maximum tightening, None follows the engine mode.
    # Ignored (bit-exactly) while the engine controller is off.
    soft_prune: bool = False         # serve with the soft-pruning TDM:
    # dropped tokens fold into a persistent package token instead of being
    # re-fused per layer (keeps accuracy honest at aggressive keep rates)
    logits: Optional[np.ndarray] = None
    done: bool = False
    prune_load: Optional[float] = None   # predicted post-prune token load
    # (sum of the per-segment token counts, deadline-discounted; set at
    # submit and REFRESHED each admission pass for waiting deadline
    # requests — the prune_pressure_aware admission policy reads it)
    prune_load_base: Optional[float] = None  # undiscounted load (engine-set)
    solo_ms: Optional[float] = None  # modeled solo latency (engine-set)
    submit_t: Optional[float] = None  # monotonic submit time (engine-set;
    # waiting time consumes deadline slack in the refresh)

    @property
    def n_patches(self) -> int:
        return int(self.patches.shape[0])


@dataclasses.dataclass
class VisionEngineConfig:
    max_batch: int = 8        # in-flight image slots
    token_tile: int = 1       # bucket quantization (1 = exact, bit-exact)
    mode: str = "balanced"    # 'balanced' buckets | 'naive' pad-to-max
    planner: str = "off"      # TilePlanner mode: off|merge|fuse|full
    use_tdm: Optional[bool] = None   # None = cfg.pruning.token_pruning_enabled
    pipeline_depth: int = 1   # StepPipeline depth: 1 = synchronous,
    # 2 = double-buffered (host plans/stages step N+1 while the device
    # executes step N; results bit-exact at any depth)
    quality: str = "strict"   # QualityController mode: strict = off
    # (bit-exact with the pre-controller path), auto = tighten keep rates
    # with queue/deadline pressure, degrade = shed-load floor
    keep_levels: Tuple[float, ...] = (1.0, 0.85, 0.7, 0.55, 0.4)
    # quantized keep-rate grid the controller resolves onto (bounds the
    # distinct TDM k values, hence recompiles)
    keep_floor: float = 0.4   # no request is ever tightened below this
    precision: str = "fp32"   # serving precision tier: "fp32" is the
    # bit-exact reference path; "fp16"/"int8" make that tier available to
    # the planner, which prices each request's trajectory at both fp32 and
    # the tier and picks the cheaper (fp32 ties win). Requests with
    # quality="strict" are always pinned to fp32. Encoder segments only —
    # embed and head run fp32 at every tier.
    quant_granularity: str = "channel"  # int8 scale granularity:
    # "block" = one scale per kept block, "channel" = per output channel

    def __post_init__(self):
        if self.precision not in Q.PRECISIONS:
            raise ValueError(f"VisionEngineConfig.precision must be one of "
                             f"{Q.PRECISIONS}, got {self.precision!r}")
        if self.quant_granularity not in Q.GRANULARITIES:
            raise ValueError(f"VisionEngineConfig.quant_granularity must be "
                             f"one of {Q.GRANULARITIES}, "
                             f"got {self.quant_granularity!r}")
        if self.max_batch <= 0:
            raise ValueError(f"VisionEngineConfig.max_batch must be a "
                             f"positive slot count, got {self.max_batch}")
        if self.pipeline_depth <= 0:
            raise ValueError(f"VisionEngineConfig.pipeline_depth must be "
                             f">= 1, got {self.pipeline_depth}")
        if self.token_tile <= 0:
            raise ValueError(f"VisionEngineConfig.token_tile must be "
                             f"positive, got {self.token_tile}")
        if self.mode not in ("balanced", "naive"):
            raise ValueError(f"VisionEngineConfig.mode must be 'balanced' "
                             f"or 'naive', got {self.mode!r}")
        if self.planner not in PLANNER_MODES:
            raise ValueError(f"VisionEngineConfig.planner must be one of "
                             f"{PLANNER_MODES}, got {self.planner!r}")
        if self.planner != "off" and self.mode != "balanced":
            raise ValueError(f"planner {self.planner!r} requires "
                             f"mode='balanced' (got {self.mode!r})")
        # delegate grid/floor/mode validation to the config the controller
        # is built from (one source of truth for the constraints)
        self.quality_config = QualityConfig(mode=self.quality,
                                            keep_levels=self.keep_levels,
                                            keep_floor=self.keep_floor)


@dataclasses.dataclass
class _Live:
    """Per-slot in-flight state: the request, its current activation
    (unpadded — padding is a per-tile concern) and where it is in the
    segment plan."""
    req: VisionRequest
    seg_idx: int
    x: Any               # patches (pre-embed) or [n_tokens, D] activations
    n_tokens: int        # real rows of x (grouping key)
    schedule: Tuple[float, ...]  # BASE per-TDM keep schedule (static per
    # request; the QualityController resolves the *effective* schedule
    # from it at every staging pass — already-executed entries are baked
    # into n_tokens and never revisited)
    soft: bool = False   # package-token soft TDM for this request
    pkg_mass: Any = None  # accumulated package mass (0-d device array)
    # after the first soft TDM; updated at dispatch like x/n_tokens
    admit_t: float = 0.0  # monotonic admission time (deadline slack base)
    precision: str = "fp32"  # execution precision chosen at admission
    # (planner-priced; "strict" quality pins fp32) — static per request so
    # its stage keys, and therefore its tiles, stay precision-uniform


class VisionEngine:
    """Single-host reference engine for packed-ViT serving. Exposes the
    layers as ``.scheduler`` / ``.planner`` (owning ``.batcher``) /
    ``.segments`` for tests, policies, and telemetry (mirroring
    ``ServeEngine``'s three layers)."""

    def __init__(self, cfg: ModelConfig, params: Dict, packed: Dict,
                 vc: Optional[VisionEngineConfig] = None,
                 policy: "str | Callable" = "fifo",
                 cost_model: Optional[TileCostModel] = None,
                 tracer: Optional[Tracer] = None):
        if cfg.family != "vit":
            raise ValueError(f"VisionEngine serves the 'vit' family, "
                             f"got {cfg.family!r}")
        self.cfg = cfg
        self.vc = vc if vc is not None else VisionEngineConfig()
        # the engine stages a fresh padded batch per tile and never
        # re-reads a dispatched one, so layers tiles can donate their
        # input buffers to the output allocation
        self.segments = PR.PackedVitSegments(
            cfg, params, packed, use_tdm=self.vc.use_tdm,
            donate_activations=True,
            quant_granularity=self.vc.quant_granularity)
        self.scheduler = Scheduler(self.vc.max_batch, policy=policy)
        self.batcher = RaggedBatcher(token_tile=self.vc.token_tile,
                                     mode=self.vc.mode,
                                     max_batch=self.vc.max_batch)
        self.planner = TilePlanner(
            self.batcher,
            cost_model if cost_model is not None else TileCostModel(cfg),
            mode=self.vc.planner,
            quality=QualityController(self.vc.quality_config,
                                      num_slots=self.vc.max_batch))
        self._live: Dict[int, _Live] = {}   # slot -> state
        # not-yet-arrived requests as (absolute arrival step, request):
        # arrival_step is relative to the serve() call that submitted it,
        # so identical request streams replay identically (warmup == run)
        self._pending: List[Any] = []
        # wall-clock span tracer (repro.obs): plan/stage spans here, the
        # pipeline adds dispatch/complete. NULL_TRACER default = one
        # attribute check per guarded region; traces observe wall time
        # only and never perturb the dispatched math (CI asserts digest
        # equality traced vs untraced)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.pipeline = StepPipeline(self.vc.pipeline_depth,
                                     tracer=self.tracer)
        # speculative next-step plan from plan_ahead: (population
        # fingerprint it is valid for, plan). Consumed on fingerprint
        # match; dropped (and replanned) when admissions/retirements made
        # the prediction stale.
        self._plan_cache: Optional[Any] = None
        self.plan_ahead_hits = 0
        self.plan_ahead_drops = 0
        self.steps = 0
        self.images_served = 0
        # quantization observability: tiles+lanes dispatched per precision,
        # and how many of those went through the dequant-in-kernel int8
        # SBMM path (counted at the dispatch phase, like planner.commit)
        self.precision_dispatches: Dict[str, int] = {
            p: 0 for p in Q.PRECISIONS}
        self.dequant_dispatches = 0
        self._n_patches_max = (cfg.image_size // cfg.patch_size) ** 2
        self._use_tdm = (cfg.pruning.token_pruning_enabled
                         if self.vc.use_tdm is None else self.vc.use_tdm)
        # TDM ordinal bookkeeping: _tdm_before[si] = how many TDM segments
        # precede plan index si — the keep-schedule index of the NEXT TDM
        # a request at seg_idx=si will hit (executed entries are history)
        self._tdm_before: List[int] = []
        n_tdm = 0
        for seg in self.segments.plan:
            self._tdm_before.append(n_tdm)
            if seg[0] == "tdm":
                n_tdm += 1
        self._tdm_before.append(n_tdm)  # seg_idx == len(plan) (finished)
        self._n_tdm = n_tdm

    @classmethod
    def from_pruned(cls, cfg: ModelConfig, params: Dict, scores: Dict,
                    vc: Optional[VisionEngineConfig] = None,
                    policy: "str | Callable" = "fifo",
                    tracer: Optional[Tracer] = None) -> "VisionEngine":
        """Harden the pruning and build the engine: masks the dense params
        (the DBMM path) and SBMM-packs the attention weights."""
        from repro.models import pruning_glue as PG
        masked = PG.apply_pruning(cfg, params, scores)
        packed = PR.pack_model(cfg, params, scores)
        return cls(cfg, masked, packed, vc=vc, policy=policy,
                   tracer=tracer)

    # -- events / compat ---------------------------------------------------
    @property
    def events(self):
        return self.scheduler.events

    # -- public API --------------------------------------------------------
    def serve(self, requests: Sequence[VisionRequest]
              ) -> Dict[int, np.ndarray]:
        """Serve ``requests`` to completion; returns {uid: logits}. Requests
        with ``arrival_step > 0`` join the waiting queue only once the
        engine has taken that many steps (staggered admission — the
        continuous-batching scenario)."""
        out: Dict[int, np.ndarray] = {}
        self.enqueue(requests)
        while self._pending or self.scheduler.has_work():
            self.tick(out)
        self.finish()
        return out

    def enqueue(self, requests: Sequence[VisionRequest]) -> None:
        """Validate + annotate ``requests`` and queue them for admission
        (``arrival_step`` relative to the CURRENT engine step). ``serve``
        is ``enqueue`` + ``tick`` until idle + ``finish``; external
        drivers (``repro.traffic.harness``) call the pieces themselves to
        interleave submission with stepping on their own clock."""
        base = self.steps
        for r in requests:  # validate ALL before enqueueing ANY: a bad
            self._validate(r)  # request must not leak its siblings into
        for r in requests:     # the engine (they'd surface next serve())
            if r.prune_load is None:
                sched = self._base_schedule(r)
                traj = PR.token_trajectory(
                    self.cfg, r.n_patches, use_tdm=self._use_tdm,
                    schedule=sched if self._use_tdm else None,
                    soft=r.soft_prune)
                r.prune_load_base = float(sum(traj))
                r.prune_load = r.prune_load_base
                r.submit_t = time.monotonic()
                if r.deadline_ms is not None:
                    # deadline-aware admission annotation: discount the
                    # post-prune load by how tight the deadline is relative
                    # to the request's modeled solo latency, so the SAME
                    # prune_pressure_aware policy admits urgent requests
                    # earlier (no new policy needed). Recomputed every
                    # admission pass (_refresh_prune_loads): waiting time
                    # consumes slack, so urgency RISES while queued.
                    cm = self.planner.cost_model
                    r.solo_ms = cm.ms(cm.trajectory_cycles(
                        self._traj_from(0, r.n_patches, sched, r.soft_prune,
                                        precision=self._precision_for(r))))
                    r.prune_load *= min(1.0, r.deadline_ms
                                        / max(r.solo_ms, 1e-9))
            self._pending.append((base + r.arrival_step, r))
        self._pending.sort(key=lambda ar: ar[0])
        self._plan_cache = None  # stale speculation from a previous batch

    def tick(self, out: Dict[int, np.ndarray]) -> StepReport:
        """One serve-loop iteration: retire finished slots, admit due
        arrivals, stage + dispatch one engine step through the pipeline.
        Returns a :class:`StepReport` of host-deterministic facts about
        the step (dispatched plan's modeled cost, admitted/completed
        uids) — identical at every pipeline depth for the same request
        stream, which is what lets the traffic harness keep a virtual
        clock that doesn't depend on wall time."""
        # retire bookkeeping for the step in flight: trajectories are
        # deterministic, so which slots finished is host-known before
        # their logits materialize (the pipeline completion fills out)
        self._retire_finished()
        self._admit_arrivals()
        self._refresh_prune_loads(time.monotonic())
        live_before = {st.req.uid for st in self._live.values()}
        cycles_before = self.planner.modeled_cycles
        staged = None
        while True:
            # requests submitted after staging began belong in THIS
            # plan: drop the staged step (rolls back, leaks nothing)
            # and replan with the admissions included
            sub_mark = self.scheduler.submitted_total
            self.scheduler.schedule()
            self._sync_admissions()
            if not self._live:
                break
            staged = self._stage_step(out)
            if self.scheduler.submitted_total == sub_mark:
                break
            self.pipeline.drop(staged)
            staged = None
        admitted = tuple(sorted(
            {st.req.uid for st in self._live.values()} - live_before))
        if staged is None:
            if self._pending or self.scheduler.has_work():
                # nothing admitted yet (future arrivals): advance time
                self.steps += 1
            return StepReport(dispatched=False, admitted=admitted)
        self.pipeline.submit(staged)
        n_segs = len(self.segments.plan)
        completed = tuple(sorted(
            st.req.uid for st in self._live.values()
            if st.seg_idx >= n_segs))
        return StepReport(
            dispatched=True,
            # planner.commit ran inside the dispatch above, so the ledger
            # delta is exactly this step's ExecutionPlan modeled cost
            modeled_ms=self.planner.cost_model.ms(
                self.planner.modeled_cycles - cycles_before),
            admitted=admitted, completed=completed)

    def finish(self) -> None:
        """Drain the pipeline (materializing every in-flight step's
        outputs) and retire the finished slots."""
        self.pipeline.flush()
        self._retire_finished()

    def modeled_request_ms(self, r: VisionRequest,
                           schedule: Optional[Sequence[float]] = None
                           ) -> float:
        """Cost-model price (ms) of serving ``r`` solo from scratch under
        ``schedule`` (default: its own base keep schedule). The admission
        controller prices marginal cost with this — including the
        quality-degraded variant (pass the floored schedule)."""
        sched = (tuple(float(v) for v in schedule) if schedule is not None
                 else self._base_schedule(r))
        cm = self.planner.cost_model
        return cm.ms(cm.trajectory_cycles(
            self._traj_from(0, r.n_patches, sched, r.soft_prune,
                            precision=self._precision_for(r))))

    def modeled_backlog_ms(self) -> float:
        """Modeled time to drain the engine's current commitment: the
        remaining trajectories of every live slot plus the full
        trajectories of every waiting request — the capacity term the
        admission controller compares offered work against."""
        cm = self.planner.cost_model
        ms = sum(self.modeled_request_ms(r) for r in self.scheduler.waiting)
        for st in self._live.values():
            ms += cm.ms(cm.trajectory_cycles(self._traj_from(
                st.seg_idx, st.n_tokens, st.schedule, st.soft,
                precision=st.precision)))
        return ms

    def stats(self) -> Dict[str, Any]:
        buckets = self.batcher.bucket_count
        trajectories = self.planner.trajectory_count
        return {
            "images_served": self.images_served,
            "steps": self.steps,
            "admissions": self.scheduler.num_admissions,
            "compile_count": self.segments.compile_count,
            "jit_compile_count": self.segments.jit_compile_count(),
            "bucket_count": buckets,
            "trajectory_count": trajectories,
            # the recompile bound: jit_compile_count <= compile_budget
            "compile_budget": buckets + trajectories,
            "plan_ahead_hits": self.plan_ahead_hits,
            "plan_ahead_drops": self.plan_ahead_drops,
            # quantized-serving counters: the engine tier, tile+lane
            # dispatches per execution precision, and how many dispatches
            # ran the dequant-in-kernel int8 SBMM
            "precision": self.vc.precision,
            **{f"dispatch_{p}": n
               for p, n in self.precision_dispatches.items()},
            "dequant_dispatches": self.dequant_dispatches,
            **{f"sched_{k}": v for k, v in self.scheduler.stats().items()},
            **{f"pipeline_{k}": v for k, v in self.pipeline.stats().items()},
            **{f"batcher_{k}": v for k, v in self.batcher.stats().items()},
            **{f"plan_{k}": v for k, v in self.planner.stats().items()},
            **{f"quality_{k}": v
               for k, v in self.planner.quality.stats().items()},
        }

    def export_metrics(self, registry: MetricsRegistry,
                       prefix: str = "vision") -> MetricsRegistry:
        """Fold this engine's observable state into ``registry``: every
        numeric ``stats()`` entry as a ``<prefix>.<key>`` gauge (compile
        ledgers, planner merge/fuse/deadline counters, padding waste,
        device idle, backlog), plus the signals the flat dicts cannot
        carry — the modeled-vs-measured plan cost error (calibration
        drift) and the quality controller's tighten count per keep
        level. The quantization counters (``dispatch_<precision>``,
        ``dequant_dispatches``, the planner's ``plan_precision_*``
        decisions) ride the absorb like every other numeric stat."""
        registry.absorb(prefix, self.stats())
        p = self.pipeline.stats()
        registry.gauge(f"{prefix}.plan_cost_error").set(p["cost_error"])
        for lvl, n in sorted(self.planner.quality.level_counts.items()):
            registry.gauge(
                f"{prefix}.quality_tightened_level_{lvl:g}").set(n)
        return registry

    def quantization_report(self) -> Dict[str, Any]:
        """Weight-quantization accounting at the engine's precision tier:
        the max-abs weight delta vs the fp32 packed dict (the launcher's
        quantization-error stat) and the packed model size at both tiers
        (``PackedWeight.nbytes`` semantics — surviving blocks + headers +
        scales, at actual dtype widths). fp32 engines report a zero error
        without ever building a quantized dict."""
        fp32_bytes = Q.packed_dict_nbytes(self.segments.packed)
        rep = {"precision": self.vc.precision,
               "granularity": self.vc.quant_granularity,
               "packed_bytes_fp32": fp32_bytes,
               "packed_bytes": fp32_bytes,
               "quant_max_abs_error": 0.0}
        if self.vc.precision != "fp32":
            qd = self.segments.packed_for(self.vc.precision)
            rep["packed_bytes"] = Q.packed_dict_nbytes(qd)
            rep["quant_max_abs_error"] = Q.max_abs_error(
                self.segments.packed, qd)
        return rep

    # -- engine internals --------------------------------------------------
    def _validate(self, r: VisionRequest) -> None:
        n = r.n_patches
        if not 1 <= n <= self._n_patches_max:
            raise ValueError(
                f"request {r.uid}: {n} patches outside "
                f"[1, {self._n_patches_max}] (pos-table capacity for "
                f"image_size={self.cfg.image_size}, "
                f"patch_size={self.cfg.patch_size})")
        pdim = self.cfg.patch_size ** 2 * 3
        if r.patches.shape[-1] != pdim:
            raise ValueError(f"request {r.uid}: patch dim "
                             f"{r.patches.shape[-1]} != {pdim}")
        r_t = self.cfg.pruning.r_t if r.r_t is None else r.r_t
        # explicit isfinite: NaN fails every comparison, so `not a < x <= b`
        # happens to catch it, but inf/NaN deserve their own message and
        # deadline_ms's `<= 0.0` test would WAVE A NaN THROUGH
        if not (math.isfinite(r_t) and 0.0 < r_t <= 1.0):
            raise ValueError(f"request {r.uid}: r_t must be finite in "
                             f"(0, 1], got {r_t}")
        if r.deadline_ms is not None and not (
                math.isfinite(r.deadline_ms) and r.deadline_ms > 0.0):
            raise ValueError(f"request {r.uid}: deadline_ms must be finite "
                             f"and positive, got {r.deadline_ms}")
        if r.keep_schedule is not None:
            ks = tuple(float(v) for v in r.keep_schedule)
            if self._use_tdm and len(ks) != self._n_tdm:
                raise ValueError(
                    f"request {r.uid}: keep_schedule has {len(ks)} entries, "
                    f"model has {self._n_tdm} TDM segments")
            for v in ks:
                if not (math.isfinite(v) and 0.0 < v <= 1.0):
                    raise ValueError(f"request {r.uid}: keep_schedule "
                                     f"entries must be finite in (0, 1], "
                                     f"got {v}")
        if r.quality is not None and r.quality not in QUALITY_MODES:
            raise ValueError(f"request {r.uid}: quality must be one of "
                             f"{QUALITY_MODES}, got {r.quality!r}")

    def _admit_arrivals(self) -> None:
        arrived = [r for at, r in self._pending if at <= self.steps]
        if arrived:
            self._pending = [(at, r) for at, r in self._pending
                             if at > self.steps]
            self.scheduler.submit(arrived)

    def _sync_admissions(self) -> None:
        """Initialize in-flight state for slots the Scheduler filled."""
        for slot, req in self.scheduler.running.items():
            if slot in self._live:
                continue
            self._live[slot] = _Live(
                req=req, seg_idx=0,
                x=np.asarray(req.patches, np.float32),
                n_tokens=req.n_patches,
                schedule=self._base_schedule(req),
                soft=req.soft_prune,
                admit_t=time.monotonic(),
                precision=self._precision_for(req, record=True))

    def _precision_for(self, r: VisionRequest, record: bool = False) -> str:
        """Execution precision for ``r`` — the planner's third knob. fp32
        engines short-circuit (no planner call, no counters: the fp32 path
        stays byte-identical to the pre-quantization engine), and
        quality="strict" requests pin fp32 on any engine. Otherwise the
        planner prices the request's full trajectory at fp32 AND at the
        engine tier and takes the strict argmin (fp32 listed first, so
        ties keep full precision). ``record=True`` only at admission —
        pricing probes (modeled_request_ms / backlog) must not inflate the
        decision counters."""
        if self.vc.precision == "fp32" or r.quality == "strict":
            return "fp32"
        sched = self._base_schedule(r)
        cands = [(p, self._traj_from(0, r.n_patches, sched, r.soft_prune,
                                     precision=p))
                 for p in ("fp32", self.vc.precision)]
        return self.planner.choose_precision(cands, record=record)

    def _base_schedule(self, r: VisionRequest) -> Tuple[float, ...]:
        """The request's own per-TDM keep schedule BEFORE any controller
        tightening: an explicit ``keep_schedule`` verbatim, else its
        ``r_t`` (else the config's) broadcast over the TDM segments."""
        if r.keep_schedule is not None:
            return tuple(float(v) for v in r.keep_schedule)
        return PR.keep_schedule(self.cfg, r_t=r.r_t, use_tdm=self._use_tdm)

    def _refresh_prune_loads(self, now: float) -> None:
        """Re-discount waiting deadline requests' ``prune_load`` by their
        CURRENT slack each admission pass (not once at submit): waiting
        time consumes slack, so a queued deadline request's urgency rises
        until ``prune_pressure_aware`` prefers it."""
        for req in self.scheduler.waiting:
            if (req.deadline_ms is None or req.prune_load_base is None
                    or req.solo_ms is None or req.submit_t is None):
                continue
            left = req.deadline_ms - (now - req.submit_t) * 1e3
            req.prune_load = req.prune_load_base * min(
                1.0, max(left, 0.0) / max(req.solo_ms, 1e-9))

    def _traj_from(self, seg_idx: int, n_tokens: int,
                   schedule: Sequence[float], soft: bool = False,
                   precision: str = "fp32"):
        """Remaining (stage key, entry token count) trajectory from segment
        ``seg_idx`` at ``n_tokens`` real tokens under ``schedule`` (full
        per-TDM keep schedule; entries before this point are history —
        already baked into ``n_tokens``). A stage key is the batcher
        grouping identity — the segment (weights + static layer range)
        plus, at TDM segments, the static keep count (tiles must be
        k-uniform because k is a compile-time top-k width); soft-pruning
        TDM stages append a ``"soft"`` marker (different kernel, and the
        package row makes padded-batch membership semantics different), so
        soft and hard requests never share a TDM tile while non-TDM
        segments still batch together. Non-fp32 ``precision`` appends the
        precision string to the weight-bearing (layers/tdm) stage keys
        (after the soft marker) — different weights and kernels, so
        precisions never share an encoder tile and the cost model prices
        them at their own throughput; embed/head keys stay unmarked (those
        tiles run fp32 at every tier and batch across precisions), and
        fp32 keys are byte-identical to the pre-quantization ones. Offsets
        align with engine steps, which is what the planner's fusion and
        deadline logic rely on."""
        mark = () if precision == "fp32" else (precision,)
        entries = []
        n = n_tokens
        ti = self._tdm_before[seg_idx]
        for si in range(seg_idx, len(self.segments.plan)):
            seg = self.segments.plan[si]
            if seg[0] == "tdm":
                r = schedule[ti]
                if soft:
                    k = PR.tdm_soft_keep_count(n, r, has_pkg=ti > 0)
                    entries.append(((si, seg, k, "soft") + mark, n))
                else:
                    k = PR.tdm_keep_count(n, r)
                    entries.append(((si, seg, k) + mark, n))
                n = k + 2
                ti += 1
            elif seg[0] == "layers":
                entries.append(((si, seg, None) + mark, n))
            else:
                entries.append(((si, seg, None), n))
                if seg[0] == "embed":
                    n += 1  # + CLS
        return tuple(entries)

    def _resolve_schedule(self, st: _Live, now: float) -> Tuple[float, ...]:
        """The EFFECTIVE keep schedule for this staging pass: the request's
        base schedule run through the planner's QualityController with the
        current queue pressure and deadline slack. Pure (controller
        counters fold in at dispatch) — safe under staging drop/replan,
        and an exact identity when the controller is off."""
        q = self.planner.quality
        if not q.enabled:
            return st.schedule
        done = self._tdm_before[st.seg_idx]
        left = rem = None
        if st.req.deadline_ms is not None:
            left = st.req.deadline_ms - (now - st.admit_t) * 1e3
            cm = self.planner.cost_model

            def rem(sched, _st=st, _cm=cm):
                return _cm.ms(_cm.trajectory_cycles(self._traj_from(
                    _st.seg_idx, _st.n_tokens, sched, _st.soft,
                    precision=_st.precision)))

        # backlog pressure comes from the Scheduler's first-class counter —
        # the same number its stats() block (and the traffic harness) report
        return q.resolve(st.schedule, done=done,
                         preference=st.req.quality,
                         queue_depth=self.scheduler.queue_depth,
                         deadline_left_ms=left, remaining_ms=rem)

    def _plan_item(self, st: _Live, now: float,
                   schedule: Sequence[float]) -> PlanItem:
        traj = self._traj_from(st.seg_idx, st.n_tokens, schedule, st.soft,
                               precision=st.precision)
        left = None
        if st.req.deadline_ms is not None:
            left = st.req.deadline_ms - (now - st.admit_t) * 1e3
        return PlanItem(stage=traj[0][0], n_tokens=st.n_tokens,
                        cap=self._token_cap(st), trajectory=traj,
                        deadline_left_ms=left)

    @staticmethod
    def _parse_stage(stage) -> Tuple[Tuple, Optional[int], bool, str]:
        """Decompose an engine stage key into ``(segment, k, soft,
        precision)`` — the inverse of ``_traj_from``'s key construction:
        ``(si, segment, k[, "soft"][, precision])`` with both trailing
        markers optional ("soft" is not a precision string, so membership
        in ``Q.PRECISIONS`` disambiguates)."""
        seg, k = stage[1], stage[2]
        rest = stage[3:]
        soft = "soft" in rest
        precision = next((m for m in rest if m in Q.PRECISIONS), "fp32")
        return seg, k, soft, precision

    def _token_cap(self, st: _Live) -> Optional[int]:
        """Hard bound on the padded token tile: the embed stage indexes the
        position table, so its tile must never quantize past the table's
        patch capacity (later stages have no positional shape bound)."""
        if self.segments.plan[st.seg_idx][0] == "embed":
            return self._n_patches_max
        return None

    def step(self, out: Dict[int, np.ndarray]) -> None:
        """Synchronously advance the in-flight population one step
        (compat wrapper: stage + dispatch + complete + retire in one
        call). The serve loop goes through the pipeline instead, where
        stage/dispatch/complete are allowed to overlap across steps."""
        self.pipeline.submit(self._stage_step(out))
        self.pipeline.flush()
        self._retire_finished()

    def _next_plan(self, items: List[PlanItem]):
        """This step's ExecutionPlan, via the plan-ahead cache when the
        population matches the prediction (the common case between
        admissions at depth >= 2): plans are deterministic values of the
        item population, so the speculative plan IS the plan a fresh
        ``plan_ahead(items, 1)[0]`` would build — bit-identical behavior,
        planning cost hidden behind the previous step's device work."""
        key = self._items_fingerprint(items)
        cached, self._plan_cache = self._plan_cache, None
        if cached is not None:
            ckey, cplan = cached
            if key is not None and ckey == key:
                self.plan_ahead_hits += 1
                return cplan
            self.plan_ahead_drops += 1
        plans = self.planner.plan_ahead(items, self.pipeline.depth)
        if len(plans) > 1 and key is not None:
            nxt = self.planner.advance_items(items, plans[0])
            if nxt:
                self._plan_cache = (self._items_fingerprint(nxt), plans[1])
        return plans[0]

    @staticmethod
    def _items_fingerprint(items: List[PlanItem]):
        """Population identity the plan cache keys on; ``None`` (never
        cache) when any item carries a deadline — urgency depends on the
        wall clock, so deadline plans must be rebuilt at dispatch time."""
        if any(it.deadline_left_ms is not None for it in items):
            return None
        return tuple((it.stage, it.n_tokens, it.cap, it.trajectory)
                     for it in items)

    def _stage_step(self, out: Dict[int, np.ndarray]) -> StagedStep:
        """Stage one engine step: plan the population, build every tile's
        padded input batch and every lane's entry activation, and close
        over them in a :class:`StagedStep`. Staging mutates NO engine
        state (plans fold into the ledgers only at dispatch, via
        ``planner.commit``) — a staged step can be dropped for a replan
        and leaks nothing.

        Exactness: padding and stacking are pure data movement, so the
        staged buffers are bitwise the batches the synchronous path
        built host-side; the same jitted segment bodies then make the
        logits independent of pipeline depth."""
        slots = sorted(self._live)
        now = time.monotonic()
        tr = self.tracer
        if tr.enabled:
            tr.begin("plan", track="engine", step=self.steps,
                     population=len(slots))
        # quality resolution happens ONCE per staging pass, before planning:
        # the effective schedules shape the trajectories the planner prices,
        # so the plan, the stage keys and the dispatched k values all agree
        eff = {s: self._resolve_schedule(self._live[s], now) for s in slots}
        items = [self._plan_item(self._live[s], now, eff[s]) for s in slots]
        plan = self._next_plan(items)
        if tr.enabled:
            tr.end("plan", track="engine")
        n_urgent = plan.urgent_tile_count()
        n_segs = len(self.segments.plan)

        # controller accounting for this step (folded in at dispatch only —
        # a dropped staging pass leaves no trace)
        q_dec = q_tight = q_dl = 0
        q_levels: List[float] = []
        q = self.planner.quality
        if q.enabled:
            depth = self.scheduler.queue_depth
            for s in slots:
                st = self._live[s]
                done = self._tdm_before[st.seg_idx]
                pairs = list(zip(st.schedule[done:], eff[s][done:]))
                q_dec += len(pairs)
                hit = [e for b, e in pairs if e < b - 1e-12]
                q_tight += len(hit)
                q_levels.extend(hit)
                if st.req.deadline_ms is not None and hit:
                    # how much of the tightening came from the deadline
                    # loop (vs queue pressure alone)
                    e0 = q.resolve(st.schedule, done=done,
                                   preference=st.req.quality,
                                   queue_depth=depth)
                    q_dl += sum(1 for a, b in zip(e0[done:], eff[s][done:])
                                if b < a - 1e-12)

        if tr.enabled:
            tr.begin("stage", track="engine", step=self.steps,
                     tiles=len(plan.tiles), lanes=len(plan.lanes))
        tile_runs = []
        for tile in plan.tiles:
            member_slots = [slots[i] for i in tile.members]
            states = [self._live[s] for s in member_slots]
            # the tile's stage key is the source of truth for what runs:
            # (si, segment, k[, "soft"][, precision]) — states[0] only
            # supplies data
            seg, k, soft, prec = self._parse_stage(tile.stage)
            # token/batch padding is exactness-neutral; building the batch
            # from device handles (pad + stack) keeps staging async — the
            # old host-side scatter would block on the previous step
            feat = states[0].x.shape[-1]
            rows = [jnp.pad(jnp.asarray(st.x, jnp.float32),
                            ((0, tile.n_tile - st.n_tokens), (0, 0)))
                    for st in states]
            if tile.b_tile > len(states):
                zero = jnp.zeros((tile.n_tile, feat), jnp.float32)
                rows += [zero] * (tile.b_tile - len(states))
            batch = jnp.stack(rows)
            n_valid = None
            if tile.needs_mask and seg[0] in ("layers", "tdm"):
                n_valid = np.fromiter(
                    (st.n_tokens for st in states), np.int32, len(states))
                n_valid = np.concatenate(
                    [n_valid, np.full(tile.b_tile - len(states), tile.n_tile,
                                      np.int32)])
            pkg_mass = None
            if soft and self._tdm_before[tile.stage[0]] > 0:
                # every member past its first soft TDM carries a package
                # mass; batch-pad rows get 0 (their packages are don't-care)
                pkg_mass = jnp.stack(
                    [jnp.asarray(st.pkg_mass, jnp.float32).reshape(())
                     for st in states]
                    + [jnp.zeros((), jnp.float32)]
                    * (tile.b_tile - len(states)))
            tile_runs.append((tile, member_slots, seg, k, soft, prec, batch,
                              n_valid, pkg_mass))

        lane_runs = []
        for lane in plan.lanes:
            slot = slots[lane.member]
            st = self._live[slot]
            steps = []
            for stage, _ in lane.trajectory:
                seg, k, soft, _prec = self._parse_stage(stage)
                steps.append((seg, k, True) if soft else (seg, k))
            steps = tuple(steps)
            seed = None
            if st.pkg_mass is not None:
                seed = jnp.asarray(st.pkg_mass, jnp.float32).reshape(1)
            lane_runs.append((slot, steps, jnp.asarray(st.x,
                                                       jnp.float32)[None],
                              seed))
        if tr.enabled:
            tr.end("stage", track="engine")

        produced: List[Any] = []  # (req, y handle, row) head/lane outputs

        def run_tile(tr):
            (tile, member_slots, seg, k, soft, prec, batch, n_valid,
             pkg_mass) = tr
            self.precision_dispatches[prec] += 1
            if prec == "int8":
                self.dequant_dispatches += 1
            mass = None
            if soft:
                y, mass = self.segments.run(seg, batch, n_valid=n_valid,
                                            k=k, soft=True,
                                            pkg_mass=pkg_mass,
                                            precision=prec)
            else:
                y = self.segments.run(seg, batch, n_valid=n_valid, k=k,
                                      precision=prec)
            kind = seg[0]
            for b, slot in enumerate(member_slots):
                st = self._live[slot]
                if kind == "embed":
                    st.n_tokens += 1          # + CLS
                    st.x = y[b, : st.n_tokens]
                elif kind == "layers":
                    st.x = y[b, : st.n_tokens]
                elif kind == "tdm":
                    st.n_tokens = k + 2       # CLS + k kept + fused/package
                    st.x = y[b, : st.n_tokens]
                    if soft:
                        st.pkg_mass = mass[b]
                else:  # head
                    produced.append((st.req, y, b))
                st.seg_idx += 1
            return y

        def dispatch():
            # urgent tiles (the plan's leading tiles) dispatch BEFORE
            # lanes: a fused lane is the most expensive single dispatch of
            # the step and must not sit on a deadline-urgent request's
            # critical path
            handles = [run_tile(tr) for tr in tile_runs[:n_urgent]]
            for slot, steps, x1, seed in lane_runs:
                st = self._live[slot]
                self.precision_dispatches[st.precision] += 1
                if st.precision == "int8":
                    self.dequant_dispatches += 1
                y = self.segments.run_fused(steps, x1, pkg_mass=seed,
                                            precision=st.precision)
                produced.append((st.req, y, 0))
                st.seg_idx = n_segs
                handles.append(y)
            handles += [run_tile(tr) for tr in tile_runs[n_urgent:]]
            self.planner.commit(plan)
            if q.enabled:
                q.record(q_dec, q_tight, q_levels,
                         deadline_tightened=q_dl)
            self.steps += 1
            return handles

        def complete(handles):
            for req, y, row in produced:
                req.logits = np.asarray(y[row])
                req.done = True
                out[req.uid] = req.logits

        return StagedStep(dispatch=dispatch, complete=complete,
                          label=f"vit-step-{self.steps}",
                          modeled_ms=self.planner.cost_model.ms(
                              plan.stats.modeled_cycles))

    def _retire_finished(self) -> None:
        """Free slots whose trajectory completed. Host-deterministic given
        the dispatched plans, so it runs at the NEXT step's build even
        while the finishing step is still on the device; the logits
        materialize in that step's pipeline completion."""
        n_segs = len(self.segments.plan)
        for slot in sorted(self._live):
            st = self._live[slot]
            if st.seg_idx >= n_segs:
                self.scheduler.retire(slot)
                del self._live[slot]
                self.images_served += 1
