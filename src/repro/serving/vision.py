"""VisionEngine — continuous-batching inference for the packed, pruned ViT.

The paper's headline system claim is an accelerator that *serves* the
simultaneously-pruned ViT: multi-level parallelism plus load balancing for
the irregular work left by block-pruned weights and on-the-fly token
pruning. This engine is the software twin of that serving layer:

* Admission rides the same ``Scheduler`` as the LM path (one unified
  admit/retire/degrade event stream, policy-pluggable — FIFO,
  shortest-prompt-first, prune-pressure-aware).
* Execution walks the per-stage segmentation of ``forward_vit_packed``
  (``core.packed_runner.vit_segments``): prune boundaries are batching
  boundaries. Each engine step advances every in-flight image one segment.
* Between segments the ``TilePlanner`` (``serving.planner``) prices the
  ragged population with the accelerator cost model and emits an
  ``ExecutionPlan``: dense token-count tiles (grouped by the
  ``RaggedBatcher``, optionally bin-packed/merged when the modeled padding
  cost is below the dispatch saving), express-lane fused trajectories for
  bucket-singleton requests, and deadline-driven tile splits/ordering for
  requests carrying a ``deadline_ms``. Jit recompiles are bounded by the
  bucket ∪ trajectory set. ``VisionEngineConfig.planner="off"`` (default)
  is the identity plan — exactly PR 4's ``RaggedBatcher.plan`` behavior.

Bit-exactness: in the default ``balanced`` mode with ``token_tile=1``,
buckets hold requests at *identical* token counts, the batch dimension is
padded with don't-care rows (rows are computationally independent), and the
jitted segment bodies are the same pure functions the offline
single-request path composes — so every request's logits are bit-exact
against ``forward_vit_packed`` regardless of batch composition
(tests/test_vision_engine.py). ``token_tile > 1`` and ``naive`` mode
token-pad rows inside masked kernels: same math, FP reduction order may
differ.

Requests may carry per-request keep rates (``r_t``) and arbitrary patch
counts (images of different resolutions) — both are sources of raggedness;
``arrival_step`` staggers admission so the population mixes stages, the
continuous-batching scenario.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packed_runner as PR
from repro.serving.planner import (PLANNER_MODES, PlanItem, TileCostModel,
                                   TilePlanner)
from repro.serving.ragged_batcher import RaggedBatcher
from repro.serving.scheduler import Scheduler

__all__ = ["VisionRequest", "VisionEngineConfig", "VisionEngine"]


@dataclasses.dataclass
class VisionRequest:
    uid: int
    patches: np.ndarray              # [n_patches, patch²·3] float32
    r_t: Optional[float] = None      # per-request TDM keep rate (None = cfg)
    arrival_step: int = 0            # engine step at which it may be admitted
    deadline_ms: Optional[float] = None  # wall-clock SLO from admission; the
    # planner carves the request into smaller, first-dispatched tiles when
    # its modeled slack runs out, and the admission annotation below shrinks
    # so prune_pressure_aware admits tight-deadline requests earlier
    logits: Optional[np.ndarray] = None
    done: bool = False
    prune_load: Optional[float] = None   # predicted post-prune token load
    # (sum of the per-segment token counts, deadline-discounted; set at
    # submit — the prune_pressure_aware admission policy reads it)

    @property
    def n_patches(self) -> int:
        return int(self.patches.shape[0])


@dataclasses.dataclass
class VisionEngineConfig:
    max_batch: int = 8        # in-flight image slots
    token_tile: int = 1       # bucket quantization (1 = exact, bit-exact)
    mode: str = "balanced"    # 'balanced' buckets | 'naive' pad-to-max
    planner: str = "off"      # TilePlanner mode: off|merge|fuse|full
    use_tdm: Optional[bool] = None   # None = cfg.pruning.token_pruning_enabled

    def __post_init__(self):
        if self.max_batch <= 0:
            raise ValueError(f"VisionEngineConfig.max_batch must be a "
                             f"positive slot count, got {self.max_batch}")
        if self.token_tile <= 0:
            raise ValueError(f"VisionEngineConfig.token_tile must be "
                             f"positive, got {self.token_tile}")
        if self.mode not in ("balanced", "naive"):
            raise ValueError(f"VisionEngineConfig.mode must be 'balanced' "
                             f"or 'naive', got {self.mode!r}")
        if self.planner not in PLANNER_MODES:
            raise ValueError(f"VisionEngineConfig.planner must be one of "
                             f"{PLANNER_MODES}, got {self.planner!r}")
        if self.planner != "off" and self.mode != "balanced":
            raise ValueError(f"planner {self.planner!r} requires "
                             f"mode='balanced' (got {self.mode!r})")


@dataclasses.dataclass
class _Live:
    """Per-slot in-flight state: the request, its current activation
    (unpadded — padding is a per-tile concern) and where it is in the
    segment plan."""
    req: VisionRequest
    seg_idx: int
    x: Any               # patches (pre-embed) or [n_tokens, D] activations
    n_tokens: int        # real rows of x (grouping key)
    r_t: float
    admit_t: float = 0.0  # monotonic admission time (deadline slack base)


class VisionEngine:
    """Single-host reference engine for packed-ViT serving. Exposes the
    layers as ``.scheduler`` / ``.planner`` (owning ``.batcher``) /
    ``.segments`` for tests, policies, and telemetry (mirroring
    ``ServeEngine``'s three layers)."""

    def __init__(self, cfg: ModelConfig, params: Dict, packed: Dict,
                 vc: Optional[VisionEngineConfig] = None,
                 policy: "str | Callable" = "fifo",
                 cost_model: Optional[TileCostModel] = None):
        if cfg.family != "vit":
            raise ValueError(f"VisionEngine serves the 'vit' family, "
                             f"got {cfg.family!r}")
        self.cfg = cfg
        self.vc = vc if vc is not None else VisionEngineConfig()
        self.segments = PR.PackedVitSegments(cfg, params, packed,
                                             use_tdm=self.vc.use_tdm)
        self.scheduler = Scheduler(self.vc.max_batch, policy=policy)
        self.batcher = RaggedBatcher(token_tile=self.vc.token_tile,
                                     mode=self.vc.mode,
                                     max_batch=self.vc.max_batch)
        self.planner = TilePlanner(
            self.batcher,
            cost_model if cost_model is not None else TileCostModel(cfg),
            mode=self.vc.planner)
        self._live: Dict[int, _Live] = {}   # slot -> state
        # not-yet-arrived requests as (absolute arrival step, request):
        # arrival_step is relative to the serve() call that submitted it,
        # so identical request streams replay identically (warmup == run)
        self._pending: List[Any] = []
        self.steps = 0
        self.images_served = 0
        self._n_patches_max = (cfg.image_size // cfg.patch_size) ** 2
        self._use_tdm = (cfg.pruning.token_pruning_enabled
                         if self.vc.use_tdm is None else self.vc.use_tdm)

    @classmethod
    def from_pruned(cls, cfg: ModelConfig, params: Dict, scores: Dict,
                    vc: Optional[VisionEngineConfig] = None,
                    policy: "str | Callable" = "fifo") -> "VisionEngine":
        """Harden the pruning and build the engine: masks the dense params
        (the DBMM path) and SBMM-packs the attention weights."""
        from repro.models import pruning_glue as PG
        masked = PG.apply_pruning(cfg, params, scores)
        packed = PR.pack_model(cfg, params, scores)
        return cls(cfg, masked, packed, vc=vc, policy=policy)

    # -- events / compat ---------------------------------------------------
    @property
    def events(self):
        return self.scheduler.events

    # -- public API --------------------------------------------------------
    def serve(self, requests: Sequence[VisionRequest]
              ) -> Dict[int, np.ndarray]:
        """Serve ``requests`` to completion; returns {uid: logits}. Requests
        with ``arrival_step > 0`` join the waiting queue only once the
        engine has taken that many steps (staggered admission — the
        continuous-batching scenario)."""
        base = self.steps
        for r in requests:  # validate ALL before enqueueing ANY: a bad
            self._validate(r)  # request must not leak its siblings into
        for r in requests:     # the engine (they'd surface next serve())
            if r.prune_load is None:
                traj = PR.token_trajectory(
                    self.cfg, r.n_patches,
                    r_t=r.r_t, use_tdm=self._use_tdm)
                r.prune_load = float(sum(traj))
                if r.deadline_ms is not None:
                    # deadline-aware admission annotation: discount the
                    # post-prune load by how tight the deadline is relative
                    # to the request's modeled solo latency, so the SAME
                    # prune_pressure_aware policy admits urgent requests
                    # earlier (no new policy needed)
                    cm = self.planner.cost_model
                    r_t = self.cfg.pruning.r_t if r.r_t is None else r.r_t
                    solo_ms = cm.ms(cm.trajectory_cycles(
                        self._traj_from(0, r.n_patches, r_t)))
                    r.prune_load *= min(1.0, r.deadline_ms
                                        / max(solo_ms, 1e-9))
            self._pending.append((base + r.arrival_step, r))
        self._pending.sort(key=lambda ar: ar[0])
        out: Dict[int, np.ndarray] = {}
        while self._pending or self.scheduler.has_work():
            self._admit_arrivals()
            self.scheduler.schedule()
            self._sync_admissions()
            if not self._live:
                # nothing admitted yet (future arrivals): advance time
                self.steps += 1
                continue
            self.step(out)
        return out

    def stats(self) -> Dict[str, Any]:
        buckets = self.batcher.bucket_count
        trajectories = self.planner.trajectory_count
        return {
            "images_served": self.images_served,
            "steps": self.steps,
            "admissions": self.scheduler.num_admissions,
            "compile_count": self.segments.compile_count,
            "jit_compile_count": self.segments.jit_compile_count(),
            "bucket_count": buckets,
            "trajectory_count": trajectories,
            # the recompile bound: jit_compile_count <= compile_budget
            "compile_budget": buckets + trajectories,
            **{f"batcher_{k}": v for k, v in self.batcher.stats().items()},
            **{f"plan_{k}": v for k, v in self.planner.stats().items()},
        }

    # -- engine internals --------------------------------------------------
    def _validate(self, r: VisionRequest) -> None:
        n = r.n_patches
        if not 1 <= n <= self._n_patches_max:
            raise ValueError(
                f"request {r.uid}: {n} patches outside "
                f"[1, {self._n_patches_max}] (pos-table capacity for "
                f"image_size={self.cfg.image_size}, "
                f"patch_size={self.cfg.patch_size})")
        pdim = self.cfg.patch_size ** 2 * 3
        if r.patches.shape[-1] != pdim:
            raise ValueError(f"request {r.uid}: patch dim "
                             f"{r.patches.shape[-1]} != {pdim}")
        r_t = self.cfg.pruning.r_t if r.r_t is None else r.r_t
        if not 0.0 < r_t <= 1.0:
            raise ValueError(f"request {r.uid}: r_t must be in (0, 1], "
                             f"got {r_t}")
        if r.deadline_ms is not None and r.deadline_ms <= 0.0:
            raise ValueError(f"request {r.uid}: deadline_ms must be "
                             f"positive, got {r.deadline_ms}")

    def _admit_arrivals(self) -> None:
        arrived = [r for at, r in self._pending if at <= self.steps]
        if arrived:
            self._pending = [(at, r) for at, r in self._pending
                             if at > self.steps]
            self.scheduler.submit(arrived)

    def _sync_admissions(self) -> None:
        """Initialize in-flight state for slots the Scheduler filled."""
        for slot, req in self.scheduler.running.items():
            if slot in self._live:
                continue
            self._live[slot] = _Live(
                req=req, seg_idx=0,
                x=np.asarray(req.patches, np.float32),
                n_tokens=req.n_patches,
                r_t=self.cfg.pruning.r_t if req.r_t is None else req.r_t,
                admit_t=time.monotonic())

    def _traj_from(self, seg_idx: int, n_tokens: int, r_t: float):
        """Remaining (stage key, entry token count) trajectory from segment
        ``seg_idx`` at ``n_tokens`` real tokens. A stage key is the batcher
        grouping identity — the segment (weights + static layer range)
        plus, at TDM segments, the static keep count (tiles must be
        k-uniform because k is a compile-time top-k width). Offsets align
        with engine steps, which is what the planner's fusion and deadline
        logic rely on."""
        entries = []
        n = n_tokens
        for si in range(seg_idx, len(self.segments.plan)):
            seg = self.segments.plan[si]
            if seg[0] == "tdm":
                k = PR.tdm_keep_count(n, r_t)
                entries.append(((si, seg, k), n))
                n = k + 2
            else:
                entries.append(((si, seg, None), n))
                if seg[0] == "embed":
                    n += 1  # + CLS
        return tuple(entries)

    def _stage_key(self, st: _Live):
        """Current batcher grouping identity (= trajectory offset 0)."""
        seg = self.segments.plan[st.seg_idx]
        if seg[0] == "tdm":
            return (st.seg_idx, seg, PR.tdm_keep_count(st.n_tokens, st.r_t))
        return (st.seg_idx, seg, None)

    def _plan_item(self, st: _Live, now: float) -> PlanItem:
        traj = self._traj_from(st.seg_idx, st.n_tokens, st.r_t)
        left = None
        if st.req.deadline_ms is not None:
            left = st.req.deadline_ms - (now - st.admit_t) * 1e3
        return PlanItem(stage=traj[0][0], n_tokens=st.n_tokens,
                        cap=self._token_cap(st), trajectory=traj,
                        deadline_left_ms=left)

    def _token_cap(self, st: _Live) -> Optional[int]:
        """Hard bound on the padded token tile: the embed stage indexes the
        position table, so its tile must never quantize past the table's
        patch capacity (later stages have no positional shape bound)."""
        if self.segments.plan[st.seg_idx][0] == "embed":
            return self._n_patches_max
        return None

    def step(self, out: Dict[int, np.ndarray]) -> None:
        """Advance the in-flight population: ask the planner for an
        ``ExecutionPlan`` over the ragged population, run its fused express
        lanes (whole remaining trajectories, one dispatch each) and tiles
        (one segment each, planner-ordered so deadline-urgent tiles go
        first), scatter results, retire finished images (freeing their
        slots for the next admissions)."""
        slots = sorted(self._live)
        now = time.monotonic()
        items = [self._plan_item(self._live[s], now) for s in slots]
        plan = self.planner.plan(items)
        # urgent tiles (the plan's leading tiles) dispatch BEFORE lanes: a
        # fused lane is the most expensive single dispatch of the step and
        # must not sit on a deadline-urgent request's critical path
        n_urgent = plan.urgent_tile_count()
        for tile in plan.tiles[:n_urgent]:
            self._run_tile(tile, [slots[i] for i in tile.members])
        for lane in plan.lanes:
            self._run_lane(lane, slots[lane.member])
        for tile in plan.tiles[n_urgent:]:
            self._run_tile(tile, [slots[i] for i in tile.members])
        self.steps += 1
        self._retire(out)

    def _run_lane(self, lane, slot: int) -> None:
        """Run one express lane: the request's whole remaining trajectory
        as a single fused program (engine trajectories always end at the
        head, so the result is the logits)."""
        st = self._live[slot]
        steps = tuple((stage[1], stage[2]) for stage, _ in lane.trajectory)
        y = self.segments.run_fused(steps, st.x[None])
        st.req.logits = np.asarray(y)[0]
        st.seg_idx = len(self.segments.plan)

    def _run_tile(self, tile, member_slots: List[int]) -> None:
        states = [self._live[s] for s in member_slots]
        seg = self.segments.plan[states[0].seg_idx]
        kind = seg[0]
        k = self._stage_key(states[0])[2]

        # stage the tile on the host: token/batch padding and the member
        # scatter are pure data movement (no FP ops — exactness-neutral),
        # and one host->device transfer per tile beats per-member pad/stack
        # dispatches
        feat = states[0].x.shape[-1]
        batch = np.zeros((tile.b_tile, tile.n_tile, feat), np.float32)
        for b, st in enumerate(states):
            batch[b, : st.n_tokens] = st.x

        n_valid = None
        if tile.needs_mask and kind in ("layers", "tdm"):
            n_valid = np.fromiter(
                (st.n_tokens for st in states), np.int32, len(states))
            n_valid = np.concatenate(
                [n_valid, np.full(tile.b_tile - len(states), tile.n_tile,
                                  np.int32)])
        y = np.asarray(self.segments.run(seg, jnp.asarray(batch),
                                         n_valid=n_valid, k=k))

        for b, st in enumerate(states):
            if kind == "embed":
                st.n_tokens += 1          # + CLS
                st.x = y[b, : st.n_tokens]
            elif kind == "layers":
                st.x = y[b, : st.n_tokens]
            elif kind == "tdm":
                st.n_tokens = k + 2       # CLS + k kept + fused
                st.x = y[b, : st.n_tokens]
            else:  # head
                st.req.logits = y[b]
            st.seg_idx += 1

    def _retire(self, out: Dict[int, np.ndarray]) -> None:
        n_segs = len(self.segments.plan)
        for slot in sorted(self._live):
            st = self._live[slot]
            if st.seg_idx >= n_segs:
                st.req.done = True
                out[st.req.uid] = st.req.logits
                self.scheduler.retire(slot)
                del self._live[slot]
                self.images_served += 1
