"""repro.traffic — trace-driven load, SLO accounting, admission control.

Three modules, one pipeline: :mod:`~repro.traffic.workload` synthesizes
seeded, replayable request traces (Poisson / bursty / diurnal arrivals ×
request mixes, versioned JSONL); :mod:`~repro.traffic.harness` replays a
trace against either serving engine on a deterministic virtual clock and
reports latency percentiles, time-to-first-dispatch, goodput and
deadline-miss rate; :mod:`~repro.traffic.admission` gates submission with
the calibrated tile cost model — degrading quality (via the
``QualityController``) before rejecting, so the queue stays bounded and
goodput survives past the saturation knee.
"""
from repro.traffic.admission import (ADMISSION_ACTIONS, AdmissionController,
                                     AdmissionDecision)
from repro.traffic.harness import (LMDriver, RequestRecord, TrafficHarness,
                                   VisionDriver, outputs_digest, percentile)
from repro.traffic.workload import (ARRIVAL_PROCESSES, TRACE_SCHEMA_VERSION,
                                    Trace, TraceRequest, TraceSpec,
                                    bursty_arrivals, diurnal_arrivals,
                                    load_trace, make_trace, poisson_arrivals,
                                    save_trace, trace_fingerprint)

__all__ = [
    "ADMISSION_ACTIONS", "AdmissionController", "AdmissionDecision",
    "LMDriver", "RequestRecord", "TrafficHarness", "VisionDriver",
    "outputs_digest", "percentile",
    "ARRIVAL_PROCESSES", "TRACE_SCHEMA_VERSION", "Trace", "TraceRequest",
    "TraceSpec", "bursty_arrivals", "diurnal_arrivals", "load_trace",
    "make_trace", "poisson_arrivals", "save_trace", "trace_fingerprint",
]
