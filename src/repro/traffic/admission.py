"""Cost-model admission control — degrade before you reject.

The third leg of the traffic subsystem: a controller that decides, per
submitted request, whether the engine should take the work at all. The
decision is priced with the same calibrated ``TileCostModel`` the
``TilePlanner`` plans with — offered work and remaining capacity are
measured in the same modeled milliseconds, so admission, planning and the
harness's virtual clock all agree on what a request costs.

The policy is **degrade-then-reject**, composing with PR 7's
``QualityController`` rather than duplicating it:

1. *accept* — the request's marginal modeled cost fits the capacity left
   under ``limit_ms`` (modeled backlog drain time, live + waiting).
2. *degrade* — it does not fit at its own keep schedule, but WOULD fit at
   the quality floor: the controller stamps the request's ``quality``
   preference to ``"degrade"`` (the engine's QualityController then runs
   it at the tightest usable grid level) and admits it. Quality degrades
   before goodput does.
3. *reject* — even the floored schedule does not fit; the request is
   refused at submit (``("reject", uid)`` scheduler event) and never
   consumes a slot. Under sustained overload this is what keeps the queue
   — and therefore every accepted request's latency — bounded.

Every verdict is recorded as a typed :class:`AdmissionDecision`;
decisions are a pure function of (trace, seed, limit) because every input
is modeled, not measured — the determinism the traffic tests assert.

The controller is engine-agnostic: it sees three callables (price a
request, price its degraded variant, probe the backlog). ``for_vision``
wires them to a ``VisionEngine``; ``repro.traffic.harness`` does the
per-token equivalent for the LM path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional

__all__ = ["ADMISSION_ACTIONS", "AdmissionDecision", "AdmissionController"]

ADMISSION_ACTIONS = ("accept", "degrade", "reject")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """One admission verdict, in modeled milliseconds.

    ``cost_ms`` is the marginal modeled cost the verdict was priced at —
    the request's own schedule for accept/reject, the floored schedule
    for degrade. ``backlog_ms`` is the modeled drain time of everything
    already admitted (live + waiting) at decision time; ``limit_ms`` the
    capacity bound they were compared against."""

    uid: int
    action: str
    cost_ms: float
    backlog_ms: float
    limit_ms: float

    def __post_init__(self):
        if self.action not in ADMISSION_ACTIONS:
            raise ValueError(f"admission action must be one of "
                             f"{ADMISSION_ACTIONS}, got {self.action!r}")


class AdmissionController:
    """Marginal-cost admission gate over a modeled-capacity budget.

    ``limit_ms``      — max modeled backlog (committed + marginal work, in
                        cost-model ms) the engine may hold. The knee of
                        the goodput curve: below it requests drain inside
                        their SLOs, above it unbounded queueing turns
                        every completion into a deadline miss.
    ``cost_ms``       — callable pricing a request's full modeled cost.
    ``backlog_ms``    — callable probing the modeled drain time of work
                        already admitted (live slots + waiting queue).
    ``degraded_cost_ms`` / ``degrade`` — optional degrade arm: the price
                        of the request at the quality floor, and the
                        mutation that opts the request into it (stamps
                        its ``quality`` preference). Omit either and the
                        controller is accept-or-reject only.

    Install via :meth:`install` (sets ``scheduler.admission_control``) or
    pass :meth:`gate` yourself. The gate may mutate the request (degrade
    arm) — by design, and only before acceptance."""

    def __init__(self, limit_ms: float,
                 cost_ms: Callable[[Any], float],
                 backlog_ms: Callable[[], float],
                 degraded_cost_ms: Optional[Callable[[Any], float]] = None,
                 degrade: Optional[Callable[[Any], None]] = None):
        if not (math.isfinite(limit_ms) and limit_ms > 0.0):
            raise ValueError(f"limit_ms must be finite and positive, "
                             f"got {limit_ms}")
        self.limit_ms = float(limit_ms)
        self._cost_ms = cost_ms
        self._backlog_ms = backlog_ms
        self._degraded_cost_ms = degraded_cost_ms
        self._degrade = degrade
        self.decisions: List[AdmissionDecision] = []

    @classmethod
    def for_vision(cls, engine, limit_ms: float) -> "AdmissionController":
        """Wire the controller to a ``VisionEngine``: marginal cost from
        ``modeled_request_ms``, backlog from ``modeled_backlog_ms``, and —
        when the engine's QualityController is enabled — a degrade arm
        that prices the request at the controller's quality floor (every
        keep rate tightened the full usable grid, exactly what a
        ``"degrade"`` preference resolves to) before stamping the
        preference on."""
        q = engine.planner.quality
        degraded_cost = degrade = None
        if q.enabled:
            max_steps = len(q.config.usable_levels)

            def degraded_cost(req, _e=engine, _q=q, _n=max_steps):
                floor = tuple(_q.tighten(r, _n)
                              for r in _e._base_schedule(req))
                return _e.modeled_request_ms(req, schedule=floor)

            def degrade(req):
                req.quality = "degrade"

        return cls(limit_ms, cost_ms=engine.modeled_request_ms,
                   backlog_ms=engine.modeled_backlog_ms,
                   degraded_cost_ms=degraded_cost, degrade=degrade)

    # -- the gate ----------------------------------------------------------
    def gate(self, req: Any) -> bool:
        """``Scheduler.admission_control``-shaped verdict for ``req``.
        Probes the backlog fresh per request — within one submit batch,
        each acceptance raises the backlog the next request is priced
        against."""
        backlog = float(self._backlog_ms())
        budget = self.limit_ms - backlog
        cost = float(self._cost_ms(req))
        if cost <= budget:
            self._record(req, "accept", cost, backlog)
            return True
        if self._degraded_cost_ms is not None and self._degrade is not None:
            dcost = float(self._degraded_cost_ms(req))
            if dcost <= budget:
                self._degrade(req)
                self._record(req, "degrade", dcost, backlog)
                return True
        self._record(req, "reject", cost, backlog)
        return False

    def install(self, scheduler) -> "AdmissionController":
        scheduler.admission_control = self.gate
        return self

    def _record(self, req: Any, action: str, cost: float,
                backlog: float) -> None:
        self.decisions.append(AdmissionDecision(
            uid=req.uid, action=action, cost_ms=cost,
            backlog_ms=backlog, limit_ms=self.limit_ms))

    # -- observability -----------------------------------------------------
    def counts(self) -> Dict[str, int]:
        out = {a: 0 for a in ADMISSION_ACTIONS}
        for d in self.decisions:
            out[d.action] += 1
        return out

    def stats(self) -> Dict[str, Any]:
        return {"limit_ms": self.limit_ms, "decisions": len(self.decisions),
                **{f"{a}s": n for a, n in self.counts().items()}}
