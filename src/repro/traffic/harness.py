"""Trace-replay harness — SLO accounting on a virtual clock.

Replays a :class:`~repro.traffic.workload.Trace` against either serving
engine and reports the numbers an operator actually cares about: p50/p95/
p99 latency, time-to-first-dispatch, goodput vs offered load, deadline-
miss rate, rejection rate, queue-depth peaks.

**The clock is virtual.** Each engine tick returns a host-deterministic
:class:`~repro.serving.pipeline.StepReport`; the harness advances ``now``
by the *modeled* price of the dispatched step — the vision engine's
committed ``ExecutionPlan`` cycles through the calibrated
``TileCostModel`` (``modeled_ms``), the LM engine's dispatched token
count priced at configured per-token rates (``work_tokens``). When the
engine is idle, time jumps straight to the next trace arrival. Two
consequences, both load-bearing:

* Every timestamp — and therefore every SLO verdict — is a deterministic
  function of (trace, engine config, admission limit). Same seed, same
  numbers, on any machine, at any real-time speed.
* Pipeline depth changes WALL time but not VIRTUAL time: PR 6 guarantees
  identical plans at any depth, so the same trace yields byte-identical
  lifecycle records at depth 1 and depth 2 (tests assert this).

Deadlines are accounted HERE, not inside the engines: the engines'
deadline logic (`deadline_ms` on requests) is wall-clock-driven and would
couple the verdicts to real time. The harness keeps each trace request's
``deadline_ms`` as a virtual-clock SLO: a request meets its deadline iff
``retire_ms - arrival_ms <= deadline_ms``.

Drivers adapt the two engines' incremental APIs (``enqueue`` / ``tick`` /
``finish``) behind one interface; :class:`TrafficHarness` owns the replay
loop, the lifecycle records, and the report.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, Tracer
from repro.traffic.admission import AdmissionController
from repro.traffic.workload import Trace, TraceRequest

__all__ = ["RequestRecord", "VisionDriver", "LMDriver", "TrafficHarness",
           "outputs_digest", "percentile"]


def outputs_digest(out: Dict[int, Any]) -> str:
    """Order-independent sha256 over per-uid outputs (float32 logits or
    int64 token lists) — equal digests mean bit-identical serving results
    (the harness-vs-direct-serve equivalence check compares these)."""
    h = hashlib.sha256()
    for uid in sorted(out):
        v = np.asarray(out[uid])
        v = v.astype(np.float32) if np.issubdtype(v.dtype, np.floating) \
            else v.astype(np.int64)
        h.update(v.tobytes())
    return h.hexdigest()


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not values:
        return float("nan")
    v = sorted(values)
    idx = max(0, math.ceil(q / 100.0 * len(v)) - 1)
    return float(v[idx])


@dataclasses.dataclass
class RequestRecord:
    """One request's lifecycle on the virtual clock (all ms)."""

    uid: int
    arrival_ms: float
    deadline_ms: Optional[float] = None
    submit_ms: Optional[float] = None          # handed to the engine
    first_dispatch_ms: Optional[float] = None  # entered a slot (step start)
    retire_ms: Optional[float] = None          # final segment/token step end
    rejected: bool = False

    @property
    def latency_ms(self) -> Optional[float]:
        if self.retire_ms is None:
            return None
        return self.retire_ms - self.arrival_ms

    @property
    def ttfd_ms(self) -> Optional[float]:
        if self.first_dispatch_ms is None:
            return None
        return self.first_dispatch_ms - self.arrival_ms

    @property
    def deadline_met(self) -> Optional[bool]:
        """True/False once retired (no deadline = met); None while open
        or rejected."""
        lat = self.latency_ms
        if lat is None:
            return None
        return self.deadline_ms is None or lat <= self.deadline_ms


# ===========================================================================
# Engine drivers
# ===========================================================================
class VisionDriver:
    """Adapts :class:`~repro.serving.vision.VisionEngine`. Patch tensors
    are materialized deterministically from each trace record's
    ``content_seed`` (standard-normal pixels — the same distribution the
    launch generators use), so a replayed trace reproduces byte-identical
    inputs without storing pixels."""

    kind = "vision"

    def __init__(self, engine):
        self.engine = engine
        self._pdim = engine.cfg.patch_size ** 2 * 3

    @property
    def scheduler(self):
        return self.engine.scheduler

    def materialize(self, tr: TraceRequest):
        from repro.serving.vision import VisionRequest
        rng = np.random.default_rng(tr.content_seed)
        patches = rng.standard_normal(
            (tr.n_patches, self._pdim)).astype(np.float32)
        # NOTE: tr.deadline_ms stays harness-side (virtual-clock SLO);
        # the engine's own deadline logic is wall-clock-driven and would
        # make the replay nondeterministic.
        return VisionRequest(uid=tr.uid, patches=patches, r_t=tr.r_t,
                             keep_schedule=tr.keep_schedule,
                             quality=tr.quality, soft_prune=tr.soft_prune)

    def start(self) -> None:
        pass

    def enqueue(self, reqs: Sequence[Any]) -> None:
        self.engine.enqueue(reqs)

    def tick(self, out: Dict[int, Any]):
        return self.engine.tick(out)

    def busy(self) -> bool:
        return bool(self.engine._pending) or self.scheduler.has_work()

    def finish(self) -> None:
        self.engine.finish()

    def price_ms(self, report) -> float:
        return report.modeled_ms

    def make_admission(self, limit_ms: float) -> AdmissionController:
        return AdmissionController.for_vision(self.engine, limit_ms)


class LMDriver:
    """Adapts :class:`~repro.serving.engine.ServeEngine` (continuous
    path). The LM engines carry no accelerator cost model, so steps are
    priced at configured per-token rates: ``overhead_ms`` per dispatched
    step plus ``per_token_ms`` per prefilled/decoded token — the
    ``work_tokens`` the StepReport counts."""

    kind = "lm"

    def __init__(self, engine, per_token_ms: float = 1.0,
                 overhead_ms: float = 0.0):
        if per_token_ms <= 0.0:
            raise ValueError(f"per_token_ms must be positive, "
                             f"got {per_token_ms}")
        self.engine = engine
        self.per_token_ms = float(per_token_ms)
        self.overhead_ms = float(overhead_ms)

    @property
    def scheduler(self):
        return self.engine.scheduler

    def materialize(self, tr: TraceRequest):
        from repro.serving.engine import Request
        rng = np.random.default_rng(tr.content_seed)
        vocab = self.engine.cfg.vocab_size
        prompt = rng.integers(0, vocab, size=max(tr.prompt_tokens, 1),
                              dtype=np.int32)
        return Request(uid=tr.uid, prompt=prompt,
                       max_new_tokens=tr.max_new_tokens)

    def start(self) -> None:
        self.engine.start_continuous()

    def enqueue(self, reqs: Sequence[Any]) -> None:
        self.engine.enqueue(reqs)

    def tick(self, out: Dict[int, Any]):
        return self.engine.tick_continuous(out)

    def busy(self) -> bool:
        return self.scheduler.has_work()

    def finish(self) -> None:
        self.engine.pipeline.flush()

    def price_ms(self, report) -> float:
        return self.overhead_ms + self.per_token_ms * report.work_tokens

    def request_ms(self, req) -> float:
        """Modeled full cost of an LM request under the per-token rates
        (the admission pricer)."""
        n = len(req.prompt) + req.max_new_tokens
        return self.overhead_ms + self.per_token_ms * n

    def make_admission(self, limit_ms: float) -> AdmissionController:
        def backlog_ms(_e=self.engine):
            ms = sum(self.request_ms(r) for r in _e.scheduler.waiting)
            for uid, req in ((r.uid, r)
                             for r in _e.scheduler.running.values()):
                left = req.max_new_tokens - _e._scheduled.get(uid, 0)
                ms += self.per_token_ms * max(left, 0)
            return ms

        return AdmissionController(limit_ms, cost_ms=self.request_ms,
                                   backlog_ms=backlog_ms)


# ===========================================================================
# Harness
# ===========================================================================
class TrafficHarness:
    """Replays a trace through a driver on the virtual clock.

    ``admission_limit_ms`` (optional) builds + installs the driver's
    :class:`AdmissionController` on the engine's Scheduler before the
    replay; pass ``controller`` instead to install a pre-built one. With
    neither, admission is unbounded — the pre-PR behavior, byte-for-byte
    (``outputs_digest`` equality with a direct ``serve()`` call on the
    same requests is tested).

    ``tracer`` (optional, ``repro.obs``) makes the replay emit
    virtual-clock spans: per dispatched step, nested
    ``plan``/``stage``/``dispatch``/``complete`` spans keyed by the
    :class:`StepReport` on a shared ``steps`` track (host work is free on
    the virtual clock, so plan/stage are zero-width and dispatch spans
    the step's modeled price), and — after the replay — one lifecycle
    track per request (``enqueue``/``queued``/``serve`` spans, rejects as
    instants) stitched from the same records the report is built from.
    Every timestamp is virtual, so the exported Chrome trace is
    byte-identical at any pipeline depth (tests assert this).

    ``metrics`` (optional) records the SLO distributions into
    fixed-bucket histograms (``traffic.latency_ms`` / ``traffic.ttfd_ms``
    / ``traffic.queue_depth`` — their snapshot p50/p95/p99 are histogram
    reads of the same data the report's exact nearest-rank percentiles
    summarize) plus offered/completed/rejected counters and the
    admission/scheduler stats."""

    def __init__(self, driver, admission_limit_ms: Optional[float] = None,
                 controller: Optional[AdmissionController] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if admission_limit_ms is not None and controller is not None:
            raise ValueError("pass admission_limit_ms or controller, "
                             "not both")
        self.driver = driver
        self.controller = controller
        if admission_limit_ms is not None:
            self.controller = driver.make_admission(admission_limit_ms)
        if self.controller is not None:
            self.controller.install(driver.scheduler)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self.records: Dict[int, RequestRecord] = {}
        self.outputs: Dict[int, Any] = {}
        self.queue_depth_samples: List[int] = []
        self.virtual_ms = 0.0

    # -- replay ------------------------------------------------------------
    def run(self, trace: Trace) -> Dict[str, Any]:
        if trace.kind != self.driver.kind:
            raise ValueError(f"trace kind {trace.kind!r} does not match "
                             f"driver kind {self.driver.kind!r}")
        drv, sched = self.driver, self.driver.scheduler
        reqs = trace.requests
        self.records = {tr.uid: RequestRecord(
            uid=tr.uid, arrival_ms=tr.arrival_ms,
            deadline_ms=tr.deadline_ms) for tr in reqs}
        out: Dict[int, Any] = {}
        drv.start()
        now = 0.0
        i = 0            # next not-yet-submitted trace index
        ev_mark = len(sched.events)
        while i < len(reqs) or drv.busy():
            if not drv.busy() and i < len(reqs):
                # idle: jump the clock to the next arrival
                now = max(now, reqs[i].arrival_ms)
            due = []
            while i < len(reqs) and reqs[i].arrival_ms <= now + 1e-9:
                due.append(reqs[i])
                i += 1
            if due:
                batch = [drv.materialize(tr) for tr in due]
                for tr in due:
                    self.records[tr.uid].submit_ms = now
                drv.enqueue(batch)
            report = drv.tick(out)
            # rejects surface as scheduler events (LM: at enqueue; vision:
            # inside the tick's admission pass) — scan incrementally
            for kind, payload in sched.events[ev_mark:]:
                if kind == "reject":
                    rec = self.records[payload]
                    rec.rejected = True
            ev_mark = len(sched.events)
            if report.dispatched:
                for uid in report.admitted:
                    self.records[uid].first_dispatch_ms = now
                price = drv.price_ms(report)
                if self.tracer.enabled:
                    self._trace_step(len(self.queue_depth_samples), now,
                                     price, report)
                now += price
                for uid in report.completed:
                    self.records[uid].retire_ms = now
                self.queue_depth_samples.append(sched.queue_depth)
        drv.finish()
        self.outputs = out
        self.virtual_ms = now
        if self.tracer.enabled:
            self._trace_lifecycles()
        if self.metrics is not None:
            self._record_metrics()
        return self.report(trace)

    # -- observability export ----------------------------------------------
    def _trace_step(self, idx: int, t0: float, price_ms: float,
                    report: Any) -> None:
        """One dispatched step's virtual-clock spans. Host-side phases are
        free on the virtual clock by construction (only modeled device
        cost advances it), so ``plan``/``stage`` are zero-width markers at
        the step start, ``dispatch`` spans the modeled price, and
        ``complete`` closes at the step end — all nested in a ``step``
        span carrying the StepReport facts."""
        tr = self.tracer
        t1 = t0 + price_ms
        tr.begin("step", track="steps", t_ms=t0, step=idx,
                 modeled_ms=price_ms, admitted=list(report.admitted),
                 completed=list(report.completed))
        tr.begin("plan", track="steps", t_ms=t0)
        tr.end("plan", track="steps", t_ms=t0)
        tr.begin("stage", track="steps", t_ms=t0)
        tr.end("stage", track="steps", t_ms=t0)
        tr.begin("dispatch", track="steps", t_ms=t0)
        tr.end("dispatch", track="steps", t_ms=t1)
        tr.begin("complete", track="steps", t_ms=t1)
        tr.end("complete", track="steps", t_ms=t1)
        tr.end("step", track="steps", t_ms=t1)

    def _trace_lifecycles(self) -> None:
        """Per-request lifecycle spans on per-uid tracks, from the same
        records the report reads (themselves stitched from the
        Scheduler's unified event stream via the StepReports):
        ``enqueue`` (arrival -> handed to the engine), ``queued`` (waiting
        for a slot), ``serve`` (first dispatch -> retire); rejected
        requests get a ``reject`` instant. Unfinished phases (still-open
        requests) emit nothing — the trace stays balanced."""
        tr = self.tracer
        for r in sorted(self.records.values(), key=lambda r: r.uid):
            track = f"req {r.uid}"
            if r.rejected:
                tr.instant("reject", track=track, uid=r.uid,
                           t_ms=(r.submit_ms if r.submit_ms is not None
                                 else r.arrival_ms))
                continue
            if r.submit_ms is not None:
                tr.begin("enqueue", track=track, t_ms=r.arrival_ms,
                         uid=r.uid, deadline_ms=r.deadline_ms)
                tr.end("enqueue", track=track, t_ms=r.submit_ms)
                if r.first_dispatch_ms is not None:
                    tr.begin("queued", track=track, t_ms=r.submit_ms)
                    tr.end("queued", track=track, t_ms=r.first_dispatch_ms)
            if r.first_dispatch_ms is not None and r.retire_ms is not None:
                tr.begin("serve", track=track, t_ms=r.first_dispatch_ms,
                         uid=r.uid, latency_ms=r.latency_ms,
                         deadline_met=r.deadline_met)
                tr.end("serve", track=track, t_ms=r.retire_ms)

    def _record_metrics(self) -> None:
        """Fold the replay's SLO data into the metrics registry (called
        once, at the end of :meth:`run`)."""
        mx = self.metrics
        lat = mx.histogram("traffic.latency_ms")
        ttfd = mx.histogram("traffic.ttfd_ms")
        for r in self.records.values():
            if r.rejected:
                mx.counter("traffic.rejected").inc()
                continue
            if r.ttfd_ms is not None:
                ttfd.record(r.ttfd_ms)
            if r.latency_ms is not None:
                lat.record(r.latency_ms)
                mx.counter("traffic.completed").inc()
                if r.deadline_met is False:
                    mx.counter("traffic.deadline_missed").inc()
        qd = mx.histogram("traffic.queue_depth",
                          buckets=tuple(float(d) for d in range(65)))
        for d in self.queue_depth_samples:
            qd.record(d)
        mx.counter("traffic.offered").inc(len(self.records))
        mx.absorb("traffic.sched", self.driver.scheduler.stats())
        if self.controller is not None:
            mx.absorb("traffic.admission", self.controller.stats())

    # -- reporting ---------------------------------------------------------
    def report(self, trace: Trace) -> Dict[str, Any]:
        recs = list(self.records.values())
        done = [r for r in recs if r.retire_ms is not None]
        lat = [r.latency_ms for r in done]
        ttfd = [r.ttfd_ms for r in recs if r.ttfd_ms is not None]
        met = [r for r in done if r.deadline_met]
        with_dl = [r for r in done if r.deadline_ms is not None]
        missed = [r for r in with_dl if not r.deadline_met]
        span_s = max(self.virtual_ms, 1e-9) * 1e-3
        sched_stats = self.driver.scheduler.stats()
        rep: Dict[str, Any] = {
            "offered": len(recs),
            "offered_rps": trace.offered_load_rps,
            "completed": len(done),
            "rejected": sum(1 for r in recs if r.rejected),
            "virtual_ms": self.virtual_ms,
            "throughput_rps": len(done) / span_s,
            # goodput counts only deadline-MET completions: under
            # unbounded queueing past the knee it collapses even though
            # throughput holds, which is the whole point of admission
            "goodput_rps": len(met) / span_s,
            "deadline_total": len(with_dl),
            "deadline_missed": len(missed),
            "deadline_miss_rate": (len(missed) / len(with_dl)
                                   if with_dl else 0.0),
            "latency_p50_ms": percentile(lat, 50),
            "latency_p95_ms": percentile(lat, 95),
            "latency_p99_ms": percentile(lat, 99),
            "ttfd_p50_ms": percentile(ttfd, 50),
            "ttfd_p95_ms": percentile(ttfd, 95),
            "peak_queue_depth": sched_stats["peak_queue_depth"],
            "mean_queue_depth": (float(np.mean(self.queue_depth_samples))
                                 if self.queue_depth_samples else 0.0),
            "outputs_digest": outputs_digest(self.outputs),
        }
        if self.controller is not None:
            rep["admission"] = self.controller.stats()
        return rep

    def lifecycle(self) -> List[Tuple[Any, ...]]:
        """Per-request lifecycle tuples, uid-sorted — the cross-depth
        determinism tests compare these wholesale."""
        return [(r.uid, r.arrival_ms, r.submit_ms, r.first_dispatch_ms,
                 r.retire_ms, r.rejected)
                for r in sorted(self.records.values(), key=lambda r: r.uid)]
