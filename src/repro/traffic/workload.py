"""Trace workloads — seeded arrival processes + request mixes, replayable.

The benches so far replay tiny uniform request mixes; nothing in the stack
referees behavior under the traffic regime the paper's dynamic token
pruning is FOR — bursty, heavy-tailed, diurnal load where the latency
budget binds (SPViT/HeatViT both take that budget as the first-class
input). This module is the workload half of the traffic subsystem: it
synthesizes request streams as *traces* — plain data, serializable to
JSONL — that the harness (``traffic.harness``) replays against either
serving engine on a virtual clock.

Design rules:

* **Everything is seeded and replayable.** A trace is a pure function of
  ``(TraceSpec, seed)``; request *content* (patch pixels, prompt tokens)
  is NOT stored in the trace — each record carries a ``content_seed`` and
  the harness's drivers materialize tensors from it deterministically, so
  a few-KB JSONL file replays byte-for-byte.
* **The schema is versioned.** The JSONL header line carries
  ``trace_schema``; :func:`load_trace` refuses versions it does not know.
  :func:`trace_fingerprint` (sha256 over the canonical serialization) is
  what bench artifacts record for provenance.
* **Arrival processes are explicit.** ``poisson`` (memoryless baseline),
  ``bursty`` (two-state Markov-modulated Poisson — the heavy-tailed
  production shape), ``diurnal`` (sinusoidally ramped rate via Lewis
  thinning). All return absolute arrival times in virtual milliseconds.

Only numpy is imported here — the workload layer knows nothing about
engines or JAX, so traces can be generated/inspected anywhere.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["TRACE_SCHEMA_VERSION", "ARRIVAL_PROCESSES", "TraceRequest",
           "Trace", "TraceSpec", "poisson_arrivals", "bursty_arrivals",
           "diurnal_arrivals", "make_trace", "save_trace", "load_trace",
           "trace_fingerprint"]

TRACE_SCHEMA_VERSION = 1

ARRIVAL_PROCESSES = ("poisson", "bursty", "diurnal")

TRACE_KINDS = ("vision", "lm")


# ===========================================================================
# Records
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One trace line: when a request arrives and what it asks for.

    Vision fields (``kind == "vision"``): ``n_patches`` (resolution),
    ``r_t`` / ``keep_schedule`` (TDM keep rates; ``None`` = engine
    default), ``quality`` (per-request accuracy/latency preference),
    ``soft_prune``. LM fields (``kind == "lm"``): ``prompt_tokens``,
    ``max_new_tokens``. ``deadline_ms`` is the request's SLO measured
    from arrival on the harness's virtual clock; ``content_seed`` is the
    RNG seed the drivers materialize tensors from (replayability without
    storing pixels)."""

    uid: int
    arrival_ms: float
    kind: str = "vision"
    n_patches: int = 0
    r_t: Optional[float] = None
    keep_schedule: Optional[Tuple[float, ...]] = None
    quality: Optional[str] = None
    soft_prune: bool = False
    deadline_ms: Optional[float] = None
    prompt_tokens: int = 0
    max_new_tokens: int = 0
    content_seed: int = 0

    def __post_init__(self):
        if self.kind not in TRACE_KINDS:
            raise ValueError(f"trace request kind must be one of "
                             f"{TRACE_KINDS}, got {self.kind!r}")
        if not (math.isfinite(self.arrival_ms) and self.arrival_ms >= 0.0):
            raise ValueError(f"uid {self.uid}: arrival_ms must be finite "
                             f"and >= 0, got {self.arrival_ms}")
        if self.deadline_ms is not None and not (
                math.isfinite(self.deadline_ms) and self.deadline_ms > 0.0):
            raise ValueError(f"uid {self.uid}: deadline_ms must be finite "
                             f"and positive, got {self.deadline_ms}")
        if self.keep_schedule is not None:
            object.__setattr__(self, "keep_schedule",
                               tuple(float(v) for v in self.keep_schedule))

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if d["keep_schedule"] is not None:
            d["keep_schedule"] = list(d["keep_schedule"])
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "TraceRequest":
        d = dict(d)
        if d.get("keep_schedule") is not None:
            d["keep_schedule"] = tuple(d["keep_schedule"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class Trace:
    """An ordered request stream plus the metadata that regenerates it."""

    meta: Dict[str, Any]
    requests: Tuple[TraceRequest, ...]

    def __post_init__(self):
        reqs = tuple(self.requests)
        if any(b.arrival_ms < a.arrival_ms
               for a, b in zip(reqs, reqs[1:])):
            raise ValueError("trace requests must be sorted by arrival_ms")
        if len({r.uid for r in reqs}) != len(reqs):
            raise ValueError("trace request uids must be unique")
        object.__setattr__(self, "requests", reqs)

    @property
    def kind(self) -> str:
        return self.meta.get("kind", "vision")

    @property
    def span_ms(self) -> float:
        """First-to-last arrival span (the offered-load denominator)."""
        if not self.requests:
            return 0.0
        return self.requests[-1].arrival_ms - self.requests[0].arrival_ms

    @property
    def offered_load_rps(self) -> float:
        """Offered load in requests per (virtual) second over the span."""
        if len(self.requests) < 2 or self.span_ms <= 0.0:
            return 0.0
        return (len(self.requests) - 1) / (self.span_ms * 1e-3)

    def fingerprint(self) -> str:
        return trace_fingerprint(self)


# ===========================================================================
# Arrival processes (virtual milliseconds)
# ===========================================================================
def poisson_arrivals(n: int, rate_rps: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson process: exponential inter-arrivals at
    ``rate_rps`` requests per virtual second."""
    if rate_rps <= 0.0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    gaps_ms = rng.exponential(1e3 / rate_rps, size=n)
    return np.cumsum(gaps_ms)


def bursty_arrivals(n: int, rate_rps: float, rng: np.random.Generator,
                    burst_factor: float = 8.0, p_enter: float = 0.08,
                    p_exit: float = 0.25) -> np.ndarray:
    """Two-state Markov-modulated Poisson process (per-arrival chain): a
    calm state and a burst state whose rate is ``burst_factor`` times
    hotter; after each arrival the state flips with probability
    ``p_enter`` (calm -> burst) / ``p_exit`` (burst -> calm). The calm
    rate is chosen so the long-run mean rate is ``rate_rps`` — same
    offered load as the Poisson baseline, very different tail."""
    if rate_rps <= 0.0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1, got {burst_factor}")
    # stationary occupancy of the per-arrival chain; the mean inter-
    # arrival weights each state's gap (1/rate) by its ARRIVAL share, so
    # matching the long-run rate solves 1/rate_rps =
    # (pi_calm + pi_burst/burst_factor) / calm_rate
    pi_burst = p_enter / max(p_enter + p_exit, 1e-12)
    calm_rate = rate_rps * ((1.0 - pi_burst) + pi_burst / burst_factor)
    t = 0.0
    out = np.empty(n, np.float64)
    burst = False
    for i in range(n):
        rate = calm_rate * (burst_factor if burst else 1.0)
        t += rng.exponential(1e3 / rate)
        out[i] = t
        if rng.random() < (p_exit if burst else p_enter):
            burst = not burst
    return out


def diurnal_arrivals(n: int, rate_rps: float, rng: np.random.Generator,
                     period_s: float = 60.0,
                     depth: float = 0.8) -> np.ndarray:
    """Nonhomogeneous Poisson with a sinusoidal rate —
    ``rate(t) = rate_rps * (1 + depth * sin(2*pi*t / period))`` — sampled
    by Lewis thinning against the peak rate. ``depth`` in [0, 1): 0 is
    flat, near 1 swings between ~2x and ~0x the mean (the ramp the
    admission controller must ride)."""
    if rate_rps <= 0.0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"depth must be in [0, 1), got {depth}")
    peak = rate_rps * (1.0 + depth)
    t = 0.0
    out = np.empty(n, np.float64)
    i = 0
    while i < n:
        t += rng.exponential(1e3 / peak)
        rate_t = rate_rps * (1.0 + depth * math.sin(
            2.0 * math.pi * (t * 1e-3) / period_s))
        if rng.random() * peak <= rate_t:
            out[i] = t
            i += 1
    return out


_ARRIVALS = {"poisson": poisson_arrivals, "bursty": bursty_arrivals,
             "diurnal": diurnal_arrivals}


# ===========================================================================
# Trace synthesis
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything that determines a synthetic trace (with the seed).

    The mix samplers draw independently per request: ``sizes`` /
    ``size_weights`` choose the vision patch count (LM: ``prompt_sizes``
    choose the prompt length), ``r_ts`` the keep rate (``None`` entries =
    engine default), ``deadlines_ms`` the SLO (``None`` entries = no
    deadline), ``qualities`` the per-request preference. ``process_args``
    passes through to the arrival process (burst_factor, period_s, ...).
    """

    n: int = 32
    rate_rps: float = 50.0
    process: str = "bursty"
    kind: str = "vision"
    sizes: Tuple[int, ...] = (16, 9, 4)
    size_weights: Optional[Tuple[float, ...]] = None
    r_ts: Tuple[Optional[float], ...] = (None,)
    deadlines_ms: Tuple[Optional[float], ...] = (None,)
    qualities: Tuple[Optional[str], ...] = (None,)
    soft_prob: float = 0.0
    prompt_sizes: Tuple[int, ...] = (8, 16, 32)
    max_new_tokens: int = 8
    process_args: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if self.n <= 0:
            raise ValueError(f"n must be positive, got {self.n}")
        if self.process not in ARRIVAL_PROCESSES:
            raise ValueError(f"process must be one of {ARRIVAL_PROCESSES}, "
                             f"got {self.process!r}")
        if self.kind not in TRACE_KINDS:
            raise ValueError(f"kind must be one of {TRACE_KINDS}, "
                             f"got {self.kind!r}")
        if not 0.0 <= self.soft_prob <= 1.0:
            raise ValueError(f"soft_prob must be in [0, 1], "
                             f"got {self.soft_prob}")

    def to_json(self) -> Dict[str, Any]:
        return {k: (list(v) if isinstance(v, tuple) else v)
                for k, v in dataclasses.asdict(self).items()}


def _choice(rng: np.random.Generator, options: Sequence,
            weights: Optional[Sequence[float]] = None):
    """Index-based choice so ``None`` entries survive (np.random.choice
    would coerce a mixed option list to object/str dtype)."""
    if weights is None:
        return options[int(rng.integers(len(options)))]
    p = np.asarray(weights[:len(options)], np.float64)
    return options[int(rng.choice(len(options), p=p / p.sum()))]


def make_trace(spec: TraceSpec, seed: int = 0) -> Trace:
    """Synthesize the trace for ``(spec, seed)`` — pure and replayable:
    the same pair always yields the identical trace (and therefore the
    identical fingerprint)."""
    rng = np.random.default_rng(seed)
    arrivals = _ARRIVALS[spec.process](
        spec.n, spec.rate_rps, rng, **dict(spec.process_args))
    reqs: List[TraceRequest] = []
    for uid in range(spec.n):
        deadline = _choice(rng, spec.deadlines_ms)
        quality = _choice(rng, spec.qualities)
        content_seed = int(rng.integers(2 ** 31 - 1))
        if spec.kind == "vision":
            reqs.append(TraceRequest(
                uid=uid, arrival_ms=float(arrivals[uid]), kind="vision",
                n_patches=int(_choice(rng, spec.sizes, spec.size_weights)),
                r_t=_choice(rng, spec.r_ts),
                quality=quality,
                soft_prune=bool(rng.random() < spec.soft_prob),
                deadline_ms=deadline, content_seed=content_seed))
        else:
            reqs.append(TraceRequest(
                uid=uid, arrival_ms=float(arrivals[uid]), kind="lm",
                prompt_tokens=int(_choice(rng, spec.prompt_sizes)),
                max_new_tokens=spec.max_new_tokens,
                quality=quality, deadline_ms=deadline,
                content_seed=content_seed))
    meta = {"trace_schema": TRACE_SCHEMA_VERSION, "kind": spec.kind,
            "seed": seed, "spec": spec.to_json()}
    return Trace(meta=meta, requests=tuple(reqs))


# ===========================================================================
# Serialization + provenance
# ===========================================================================
def _canonical_lines(trace: Trace) -> List[str]:
    """Header line + one canonical JSON line per request. Canonical =
    sorted keys, no whitespace — the serialization IS the fingerprint
    domain, so save/load round-trips preserve the fingerprint exactly."""
    lines = [json.dumps(trace.meta, sort_keys=True,
                        separators=(",", ":"))]
    lines += [json.dumps(r.to_json(), sort_keys=True,
                         separators=(",", ":"))
              for r in trace.requests]
    return lines


def trace_fingerprint(trace: Trace) -> str:
    """sha256 over the canonical JSONL serialization — the replayability
    token bench artifacts record (same fingerprint == byte-for-byte the
    same workload)."""
    h = hashlib.sha256()
    for line in _canonical_lines(trace):
        h.update(line.encode())
        h.update(b"\n")
    return h.hexdigest()


def save_trace(path: str, trace: Trace) -> str:
    """Write the JSONL trace file; returns its fingerprint."""
    with open(path, "w") as f:
        for line in _canonical_lines(trace):
            f.write(line + "\n")
    return trace_fingerprint(trace)


def load_trace(path: str) -> Trace:
    """Read a JSONL trace, validating the schema version."""
    with open(path) as f:
        lines = [ln for ln in (l.strip() for l in f) if ln]
    if not lines:
        raise ValueError(f"{path}: empty trace file")
    meta = json.loads(lines[0])
    version = meta.get("trace_schema")
    if version != TRACE_SCHEMA_VERSION:
        raise ValueError(f"{path}: trace_schema {version!r} != supported "
                         f"{TRACE_SCHEMA_VERSION}")
    reqs = tuple(TraceRequest.from_json(json.loads(ln))
                 for ln in lines[1:])
    return Trace(meta=meta, requests=reqs)
