import os
import sys

# src layout — tests run via ``PYTHONPATH=src pytest tests/`` but make it
# work standalone too. NOTE: never set xla_force_host_platform_device_count
# here — smoke tests and benches must see 1 device (the dry-run sets its own
# flags in a subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
