"""Unit tests for static block weight pruning (paper §IV-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_pruning as bp


def test_ste_mask_keeps_exactly_k():
    s = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    for k in [1, 7, 32, 64]:
        m = bp.ste_topk_mask(s, k)
        assert int(m.sum()) == k


def test_ste_mask_selects_largest():
    s = jnp.asarray([[1.0, 5.0], [3.0, -2.0]])
    m = bp.ste_topk_mask(s, 2)
    assert m.tolist() == [[0.0, 1.0], [1.0, 0.0]]


def test_ste_gradient_is_identity():
    s = jax.random.normal(jax.random.PRNGKey(1), (4, 4))
    g = jax.grad(lambda s: (bp.ste_topk_mask(s, 8) * 3.0).sum())(s)
    assert bool((g == 3.0).all())


def test_masked_weight_gradient_reaches_scores():
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (64, 64))
    s = bp.init_scores_for(w, 16, "block", key)
    g = jax.grad(lambda s: (bp.masked_weight(w, s, 0.5, 16) ** 2).sum())(s)
    assert float(jnp.abs(g).sum()) > 0
    # movement-pruning semantics: dL/dS_ij aggregates dL/dW ⊙ W per block
    assert g.shape == bp.score_shape(w.shape, 16)


def test_masked_weight_density():
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (64, 128))
    s = bp.init_scores_for(w, 16, "block", key)
    for rb in (0.25, 0.5, 0.75):
        wm = bp.masked_weight(w, s, rb, 16)
        blocks_total = 4 * 8
        kept = np.ceil(blocks_total * rb)
        nz_blocks = 0
        wn = np.asarray(wm)
        for i in range(4):
            for j in range(8):
                if np.abs(wn[i*16:(i+1)*16, j*16:(j+1)*16]).sum() > 0:
                    nz_blocks += 1
        assert nz_blocks == kept


def test_masked_weight_vector_cols_rows():
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (32, 48))
    s_col = bp.init_scores_for(w, 16, "col", key)
    s_row = bp.init_scores_for(w, 16, "row", key)
    wc = bp.masked_weight_vector(w, s_col, 0.5, axis=1)
    wr = bp.masked_weight_vector(w, s_row, 0.5, axis=0)
    assert int((np.abs(np.asarray(wc)).sum(0) > 0).sum()) == 24
    assert int((np.abs(np.asarray(wr)).sum(1) > 0).sum()) == 16


def test_rb_one_is_identity():
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 32))
    s = bp.init_scores_for(w, 16, "block", jax.random.PRNGKey(6))
    assert bool((bp.masked_weight(w, s, 1.0, 16) == w).all())


def test_alternate_tie_mask():
    bm = jnp.asarray([[1, 0, 0], [0, 0, 1]], jnp.float32)
    tie = bp.alternate_tie_mask(bm)
    assert tie.tolist() == [1.0, 0.0, 1.0]


def test_head_retained_ratio():
    # 2 heads, 2 block-cols each; kill all blocks of head 1
    bm = jnp.asarray([[1, 1, 0, 0], [1, 0, 0, 0]], jnp.float32)
    assert float(bp.head_retained_ratio(bm, heads=2)) == 0.5


def test_sparsity_regularizer_positive_and_monotone():
    s1 = {"a": jnp.zeros((4, 4))}
    s2 = {"a": jnp.ones((4, 4)) * 5}
    r1 = float(bp.sparsity_regularizer(s1))
    r2 = float(bp.sparsity_regularizer(s2))
    assert 0 < r1 < r2


def test_density_stats():
    bm = jnp.asarray([[1, 0], [1, 1]], jnp.float32)
    st = bp.density_stats(bm)
    assert st["density"] == pytest.approx(0.75)
    assert st["max_col"] == 2 and st["min_col"] == 1
