"""Analytic-model tests: Tables I/II/VI and the Table III cycle model must
track the paper's published numbers."""
import pytest

from repro.configs import DEIT_SMALL, PruningConfig
from repro.core import complexity as C
from repro.core import perf_model as PM


# Paper Table VI rows: (block, r_b, r_t, MACs G, model size M-params, latency ms)
TABLE_VI = [
    (16, 1.0, 1.0, 4.27, 22.0, 3.19),
    (16, 0.5, 0.5, 1.32, 14.29, 0.868),
    (16, 0.5, 0.7, 1.79, 14.29, 1.169),
    (16, 0.5, 0.9, 2.43, 14.39, 1.479),
    (16, 0.7, 0.5, 1.62, 17.63, 1.140),
    (16, 0.7, 0.7, 2.20, 17.63, 1.553),
    (16, 0.7, 0.9, 2.98, 17.63, 1.953),
    (32, 0.5, 0.5, 1.25, 13.80, 1.621),
    (32, 0.7, 0.9, 2.93, 17.33, 2.590),
]


def _pcfg(b, rb, rt):
    return PruningConfig(block_size=b, r_b=rb, r_t=rt,
                         tdm_layers=(2, 6, 9) if rt < 1 else ())


def test_dense_encoder_matches_table_i():
    d = C.EncoderDims(B=1, N=197, H=6, Dp=64, D=384, Dmlp=1536)
    m = C.dense_encoder_macs(d)
    assert m["msa"] == 4 * 197 * 6 * 384 * 64 + 2 * 6 * 197 ** 2 * 64
    assert m["mlp"] == 2 * 197 * 384 * 1536
    assert m["layernorm"] == 2 * 197 * 384


@pytest.mark.parametrize("b,rb,rt,macs,size,lat", TABLE_VI)
def test_table_vi_macs_within_tolerance(b, rb, rt, macs, size, lat):
    """Our analytic MACs track the paper within 15% (documented deltas:
    the paper's α is measured post-training; ours uses E[α]=r_b)."""
    m = C.model_macs(DEIT_SMALL, 1, _pcfg(b, rb, rt))
    rel = abs(m["total"] / 1e9 - macs) / macs
    assert rel < 0.16, f"{m['total']/1e9:.2f}G vs paper {macs}G"


def test_macs_reduction_reaches_paper_claim():
    """Headline claim: up to 3.4× computation reduction."""
    dense = C.model_macs(DEIT_SMALL, 1, _pcfg(16, 1.0, 1.0))["total"]
    pruned = C.model_macs(DEIT_SMALL, 1, _pcfg(32, 0.5, 0.5))["total"]
    assert dense / pruned > 3.3


def test_compression_ratio_reaches_paper_claim():
    """Headline claim: model compression up to 1.6×. Our analytic size
    model compresses MORE aggressively (1.9×) because the paper's reported
    sizes retain ~64% of MSA+MLP at r_b=0.5 (α measured post-training,
    plus never-pruned residual structure); we bound from both sides."""
    ratio = C.compression_ratio(DEIT_SMALL, _pcfg(16, 0.5, 0.5))
    assert 1.5 < ratio < 2.2
    # the paper's own Table VI ratios (22 / 13.7..17.6) fall in [1.25, 1.61]
    paper_best = 22.0 / 13.70
    assert paper_best < ratio  # ours is an upper bound on achievable


def test_pruned_macs_monotone_in_rates():
    vals = []
    for rb, rt in [(0.5, 0.5), (0.5, 0.9), (0.7, 0.9), (1.0, 1.0)]:
        vals.append(C.model_macs(DEIT_SMALL, 1, _pcfg(16, rb, rt))["total"])
    assert vals == sorted(vals)


# ---------------------------------------------------------------------------
# Table III cycle model
# ---------------------------------------------------------------------------
def test_cycle_model_dense_brackets_paper():
    """Paper dense latency 3.19 ms must lie between the work-conserving
    (pipelined) bound and pipelined + full DDR stall."""
    lat = PM.model_latency_ms(DEIT_SMALL, _pcfg(16, 1.0, 1.0))
    assert lat["latency_ms"] < 3.19 < lat["latency_noverlap_ms"] + 0.1


@pytest.mark.parametrize("b,rb,rt,macs,size,lat", TABLE_VI[1:7])
def test_cycle_model_pruned_brackets_paper_b16(b, rb, rt, macs, size, lat):
    m = PM.model_latency_ms(DEIT_SMALL, _pcfg(b, rb, rt))
    assert m["latency_ms"] * 0.95 < lat < m["latency_noverlap_ms"] * 1.35


def test_strict_mode_upper_bounds_pipelined():
    p = _pcfg(16, 0.5, 0.5)
    lo = PM.model_latency_ms(DEIT_SMALL, p, mode="pipelined")["latency_ms"]
    hi = PM.model_latency_ms(DEIT_SMALL, p, mode="strict")["latency_ms"]
    assert hi > lo


def test_sbmm_cycles_scale_with_sparsity():
    acc = PM.PAPER_U250
    dense = PM.sbmm_cycles(192, 384, 1152, 6, 16, acc, 1.0)
    half = PM.sbmm_cycles(192, 384, 1152, 6, 16, acc, 0.5)
    assert 0.4 < half / dense < 0.6
