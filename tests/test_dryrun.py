"""Dry-run integration tests: the launcher must lower + compile cells on a
multi-axis mesh. Runs in a SUBPROCESS so the forced device count never
leaks into other tests (jax locks device count at first init)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_dryrun(arch, shape=None, timeout=520):
    with tempfile.TemporaryDirectory() as out:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--mesh", "tiny", "--out", out]
        if shape:
            cmd += ["--shape", shape]
        env = dict(os.environ,
                   PYTHONPATH=SRC,
                   REPRO_DRYRUN_DEVICES="8")
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        results = []
        for f in sorted(os.listdir(out)):
            with open(os.path.join(out, f)) as fh:
                results.append(json.load(fh))
        return proc, results


@pytest.mark.slow
def test_dryrun_dense_all_shapes():
    proc, results = _run_dryrun("stablelm-1.6b")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    shapes = {r["shape"] for r in results}
    assert shapes == {"train_4k", "prefill_32k", "decode_32k"}
    for r in results:
        assert r["status"] == "ok"
        roof = r["roofline"]
        assert roof["hlo_flops"] > 0
        assert roof["hlo_bytes"] > 0
        assert roof["dominant"] in ("compute", "memory", "collective")
        assert 0 < roof["useful_ratio"]


@pytest.mark.slow
def test_dryrun_ssm_long_context():
    proc, results = _run_dryrun("rwkv6-1.6b", "long_500k")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert results[0]["status"] == "ok"


def test_grid_cells_skip_rules():
    from repro.configs import grid_cells, get_config
    cells = grid_cells()
    # 10 archs x 4 shapes - 8 long_500k skips = 32
    assert len(cells) == 32
    names = {(c.name, s.name) for c, s in cells}
    assert ("rwkv6-1.6b", "long_500k") in names
    assert ("zamba2-1.2b", "long_500k") in names
    assert ("command-r-plus-104b", "long_500k") not in names
    assert ("whisper-base", "decode_32k") in names  # enc-dec has a decoder


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collectives, _shape_bytes
    hlo = """
ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %ar = f32[8,16] all-reduce(f32[8,16] %a), replica_groups={}
  %ag = bf16[4,32]{1,0} all-gather(bf16[2,32] %b), dimensions={0}
}
%loop_body.1 (x: f32[4]) -> f32[4] {
  %rs = f32[2] reduce-scatter(f32[4] %x), dimensions={0}
}
"""
    st = parse_collectives(hlo, default_trip_count=10)
    assert st.bytes_by_kind["all-reduce"] == 8 * 16 * 4
    assert st.bytes_by_kind["all-gather"] == 4 * 32 * 2
    assert st.bytes_by_kind["reduce-scatter"] == 2 * 4 * 10  # body x trips
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4


def test_mesh_plan_constants():
    from repro.launch.mesh import required_devices
    assert required_devices(False) == 256
    assert required_devices(True) == 512
