"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_pruning as bp
from repro.core import packing
from repro.kernels.sbmm import sbmm, sbmm_raw, sbmm_ref
from repro.kernels.token_drop import token_drop, token_drop_ref
from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.core.token_pruning import tdm


# ---------------------------------------------------------------------------
# SBMM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N,b,rb", [
    (32, 32, 32, 16, 0.5),
    (64, 64, 128, 16, 0.3),
    (100, 96, 80, 16, 0.7),   # non-multiples: padding path
    (128, 128, 256, 32, 0.5),
    (48, 64, 64, 32, 0.9),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sbmm_vs_masked_dense(M, K, N, b, rb, dtype):
    key = jax.random.PRNGKey(hash((M, K, N, b)) % 2**31)
    w = np.asarray(jax.random.normal(key, (K, N)), np.float32)
    sc = np.asarray(jax.random.normal(jax.random.fold_in(key, 1),
                                      bp.score_shape((K, N), b)))
    n_blocks = sc.size
    keep = max(1, int(np.ceil(n_blocks * rb)))
    mask = np.asarray(bp._hard_topk(jnp.asarray(sc), keep))
    pk = packing.pack_weight(w.astype(dtype), mask, b)
    x = jax.random.normal(jax.random.fold_in(key, 2), (M, K), dtype)
    y = sbmm(x, pk, tm=32)
    y_ref = (x.astype(jnp.float32) @ pk.to_dense().astype(jnp.float32)
             ).astype(dtype)
    tol = 1e-3 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


def test_sbmm_raw_vs_ref_oracle():
    key = jax.random.PRNGKey(7)
    w = np.asarray(jax.random.normal(key, (64, 96)), np.float32)
    sc = np.asarray(jax.random.normal(key, bp.score_shape((64, 96), 16)))
    mask = np.asarray(bp._hard_topk(jnp.asarray(sc), 12))
    pk = packing.pack_weight(w, mask, 16)
    x = jax.random.normal(key, (64, 64), jnp.float32)
    y = sbmm_raw(x, pk.blocks, pk.header, tm=32)
    y_ref = sbmm_ref(x, pk.blocks, pk.header)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_sbmm_empty_column():
    """A fully pruned block-column must produce zeros."""
    w = np.ones((32, 32), np.float32)
    mask = np.zeros((2, 2))
    mask[0, 0] = 1  # only block (0,0) survives
    pk = packing.pack_weight(w, mask, 16)
    x = jnp.ones((32, 32))
    y = np.asarray(sbmm(x, pk, tm=32))
    dense = np.asarray(pk.to_dense())
    np.testing.assert_allclose(y, np.ones((32, 32)) @ dense, atol=1e-4)
    assert np.abs(y[:, 16:]).sum() == 0


# ---------------------------------------------------------------------------
# token_drop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,N,D,rt", [
    (1, 17, 32, 0.5), (2, 197, 384, 0.7), (3, 33, 130, 0.9), (1, 9, 64, 0.25),
])
def test_token_drop_matches_tdm(B, N, D, rt):
    key = jax.random.PRNGKey(B * N)
    z = jax.random.normal(key, (B, N, D), jnp.float32)
    s = jax.random.uniform(jax.random.fold_in(key, 1), (B, N))
    out_k = token_drop(z, s, rt, td=32)
    out_j, _ = tdm(z, s, rt, has_cls=True)
    assert out_k.shape == out_j.shape
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                               atol=1e-4)


def test_token_drop_ref_oracle():
    key = jax.random.PRNGKey(11)
    z = jax.random.normal(key, (9, 16))
    keep_idx = jnp.asarray([0, 3, 7], jnp.int32)
    w = jnp.zeros((9,)).at[jnp.asarray([1, 2])].set(0.5)
    from repro.kernels.token_drop.token_drop import token_drop_pallas
    out = token_drop_pallas(z, keep_idx, w, td=16)
    ref = token_drop_ref(z, keep_idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Nq,Nk,Hq,KV,Dh,causal,qoff", [
    (2, 64, 64, 4, 4, 32, True, 0),
    (1, 197, 197, 6, 6, 64, False, 0),   # ViT shape, padding path
    (2, 128, 128, 8, 2, 64, True, 0),    # GQA 4:1
    (1, 1, 96, 4, 4, 32, True, 95),      # decode
    (1, 16, 48, 4, 2, 16, True, 32),     # chunked prefill continuation
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(B, Nq, Nk, Hq, KV, Dh, causal, qoff, dtype):
    key = jax.random.PRNGKey(Nq * Nk)
    q = jax.random.normal(key, (B, Nq, Hq, Dh), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Nk, KV, Dh), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Nk, KV, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal, q_offset=qoff,
                          tq=64, tk=32)
    per = Hq // KV
    ke = jnp.repeat(k, per, axis=2)
    ve = jnp.repeat(v, per, axis=2)
    ref = jnp.moveaxis(jax.vmap(
        lambda qq, kk, vv: attention_ref(
            jnp.moveaxis(qq, 1, 0), jnp.moveaxis(kk, 1, 0),
            jnp.moveaxis(vv, 1, 0), causal=causal, q_offset=qoff))(
                q, ke, ve), 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_bounded_equals_unbounded():
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 128, 4, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 4, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 4, 32))
    a = flash_attention(q, k, v, causal=True, tq=32, tk=32, bounded=True)
    b = flash_attention(q, k, v, causal=True, tq=32, tk=32, bounded=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# Backend selection (kernels.backend): interpret on CPU CI, compiled on
# real TPU, env-overridable
# ---------------------------------------------------------------------------
def test_backend_auto_detection(monkeypatch):
    from repro.kernels import backend

    monkeypatch.delenv(backend.ENV_VAR, raising=False)
    # this suite runs on the CPU host platform -> interpreter by default
    assert backend.default_interpret() == (jax.default_backend() != "tpu")
    assert backend.resolve_interpret(None) == backend.default_interpret()
    # explicit values pass through untouched
    assert backend.resolve_interpret(True) is True
    assert backend.resolve_interpret(False) is False


def test_backend_env_override(monkeypatch):
    from repro.kernels import backend

    monkeypatch.setenv(backend.ENV_VAR, "interpret")
    assert backend.default_interpret() is True
    monkeypatch.setenv(backend.ENV_VAR, "compiled")
    assert backend.default_interpret() is False
    monkeypatch.setenv(backend.ENV_VAR, "auto")
    assert backend.default_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv(backend.ENV_VAR, "sideways")
    with pytest.raises(ValueError, match="REPRO_KERNEL_INTERPRET"):
        backend.default_interpret()


def test_kernels_honor_env_interpret(monkeypatch):
    """The non-jitted entry points resolve the env override per call (the
    resolved value is a static jit arg, so flipping the env re-dispatches
    instead of reusing a stale trace)."""
    from repro.kernels import backend

    monkeypatch.setenv(backend.ENV_VAR, "interpret")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    w = rng.standard_normal((32, 32)).astype(np.float32)
    mask = np.ones(bp.score_shape(w.shape, 16), bool)
    pw = packing.pack_weight(w, mask, 16)
    out = sbmm(x, pw, tm=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) @ w,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# token_package (soft-pruning TDM)
# ---------------------------------------------------------------------------
def test_token_package_ref_oracle_edge_k():
    """Kernel vs jnp reference at the k extremes: k=1 (drop almost
    everything into the package) and k=n (keep every row; the package is
    an empty weighted sum)."""
    from repro.kernels.token_package import (token_package_pallas,
                                             token_package_ref)

    key = jax.random.PRNGKey(3)
    n, d = 9, 32
    z = jax.random.normal(key, (n, d), jnp.float32)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    for keep in (jnp.asarray([4], jnp.int32),
                 jnp.arange(n, dtype=jnp.int32)):
        wk = jnp.where(jnp.isin(jnp.arange(n), keep), 0.0, w)
        out = token_package_pallas(z, keep, wk, td=16)
        ref = token_package_ref(z, keep, wk)
        assert out.shape == (len(keep) + 1, d)
        assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,N,D,k", [(2, 17, 64, 1), (1, 9, 32, 7),
                                     (3, 33, 128, 10)])
def test_token_package_matches_tdm_soft(B, N, D, k):
    """The batched wrapper (padding + mass substitution + vmap) agrees
    with the pure-jnp soft TDM, including the accumulated masses across a
    chained second application."""
    from repro.core.token_pruning import tdm_soft
    from repro.kernels.token_package import token_package

    key = jax.random.PRNGKey(B * N + k)
    z = jax.random.normal(key, (B, N, D), jnp.float32)
    s = jax.random.uniform(jax.random.fold_in(key, 1), (B, N))
    out_k, mass_k = token_package(z, s, k=k, td=32)
    out_j, mass_j = tdm_soft(z, s, k=k)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(mass_k), np.asarray(mass_j),
                               rtol=1e-5)
    # chained: the package row participates at its accumulated mass
    s2 = jax.random.uniform(jax.random.fold_in(key, 2), out_k.shape[:2])
    k2 = max(1, k - 1)
    out_k2, mass_k2 = token_package(out_k, s2, k=k2, pkg_mass=mass_k, td=32)
    out_j2, mass_j2 = tdm_soft(out_j, s2, k=k2, pkg_mass=mass_j)
    np.testing.assert_allclose(np.asarray(out_k2), np.asarray(out_j2),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(mass_k2), np.asarray(mass_j2),
                               rtol=1e-4)
