"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs; plus
prefill→decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, DEIT_SMALL
from repro.models import model as M
from repro.models import steps as ST
from repro.optim import AdamW


def _batch(cfg, key, B=2, S=16):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["vision_embeds"] = jax.random.normal(
            key, (B, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        b["audio_frames"] = jax.random.normal(
            key, (B, cfg.num_audio_frames, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, rng_key)
    b = _batch(cfg, rng_key)
    out = M.forward_lm(cfg, params, b["tokens"], mode="train",
                       vision_embeds=b.get("vision_embeds"),
                       audio_frames=b.get("audio_frames"), remat=False)
    assert out.logits.shape == (*b["tokens"].shape, cfg.vocab_size)
    assert bool(jnp.isfinite(out.logits).all())


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_decreases_loss(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, rng_key)
    opt = AdamW(lr=3e-3)
    step = jax.jit(ST.make_train_step(cfg, opt, with_pruning=False))
    opt_state = opt.init(params)
    b = _batch(cfg, rng_key, B=4, S=16)
    losses = []
    for _ in range(3):
        params, _, opt_state, metrics = step(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ["qwen3-14b", "stablelm-1.6b",
                                  "rwkv6-1.6b", "zamba2-1.2b",
                                  "qwen2-moe-a2.7b"])
def test_prefill_decode_matches_full_forward(arch, rng_key):
    """Token t+1's logits from incremental decode must match the full
    forward over the whole sequence (cache correctness)."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, rng_key)
    B, S = 1, 8
    toks = jax.random.randint(rng_key, (B, S + 1), 0, cfg.vocab_size)

    full = M.forward_lm(cfg, params, toks, mode="train", remat=False)
    full_logits_last = np.asarray(full.logits[:, -1])

    caches = ST.init_caches(cfg, B, 32)
    out_pre = M.forward_lm(cfg, params, toks[:, :S], mode="prefill",
                           caches=caches)
    out_dec = M.forward_lm(cfg, params, toks[:, S:S + 1], mode="decode",
                           caches=out_pre.caches)
    dec_logits = np.asarray(out_dec.logits[:, -1])
    np.testing.assert_allclose(dec_logits, full_logits_last,
                               atol=0.15, rtol=0.05)  # bf16 activations


def test_vit_forward_tdm_shapes(rng_key):
    cfg = DEIT_SMALL.reduced()
    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(rng_key, (2, n, cfg.patch_size ** 2 * 3))
    out = M.forward_vit(cfg, M.init_params(cfg, rng_key), patches)
    assert out.logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(out.logits).all())


def test_vit_tdm_changes_compute_not_shape(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(rng_key, (2, n, cfg.patch_size ** 2 * 3))
    with_tdm = M.forward_vit(cfg, params, patches, use_tdm=True)
    without = M.forward_vit(cfg, params, patches, use_tdm=False)
    assert with_tdm.logits.shape == without.logits.shape
    # different compute paths -> different (finite) logits
    assert bool(jnp.isfinite(with_tdm.logits).all())
    assert not np.allclose(np.asarray(with_tdm.logits),
                           np.asarray(without.logits))


def test_unrolled_forward_matches_scan(rng_key):
    cfg = get_config("minitron-4b").reduced()
    params = M.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
    o1 = M.forward_lm(cfg, params, toks, remat=False)
    o2 = M.forward_lm(cfg, params, toks, remat=False, unroll=True)
    np.testing.assert_allclose(np.asarray(o1.logits), np.asarray(o2.logits),
                               atol=0.08)  # bf16 reassociation


def test_rwkv_chunked_wkv_matches_sequential(rng_key):
    """flash-linear-attention chunking (§Perf C2) must equal the
    sequential recurrence, end-to-end through the full model."""
    cfg = get_config("rwkv6-1.6b").reduced()
    params = M.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (2, 32), 0, cfg.vocab_size)
    seq = M.forward_lm(cfg, params, toks, mode="train", remat=False)
    chk = M.forward_lm(cfg.replace(rwkv_chunk=8), params, toks,
                       mode="train", remat=False)
    np.testing.assert_allclose(np.asarray(seq.logits),
                               np.asarray(chk.logits), atol=0.08)
