"""repro.obs: span tracer, metrics registry, bounded event log.

The acceptance properties of the observability layer:

* spans nest LIFO per track and the exported Chrome trace satisfies the
  trace_event contract (validate_chrome_trace: well-formed envelope,
  monotonic per-track timestamps, balanced B/E pairs);
* a disabled tracer (NullTracer or Tracer(enabled=False)) records
  nothing and costs the hot path one attribute check — and enabling it
  never changes served outputs (digest-neutral);
* virtual-clock traces from the traffic harness are byte-identical at
  pipeline depths 1 and 2 (the PR-8 timestamp-equality guarantee carries
  over to the exported timeline);
* metrics are deterministic: fixed bucket edges, nearest-rank percentile
  reads, same sample stream -> byte-identical snapshots;
* the scheduler's EventLog is a bounded ring with ABSOLUTE indices, so
  the existing ``mark = len(events)`` / ``events[mark:]`` incremental
  consumption pattern survives eviction, and ``drain()`` hands the
  buffer over without disturbing the total.
"""
import json
import math

import jax
import pytest

from repro.configs import DEIT_SMALL
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.obs import (EventLog, MetricsRegistry, NULL_TRACER, NullTracer,
                       Tracer, log_buckets, validate_chrome_trace)
from repro.serving import Scheduler, VisionEngine, VisionEngineConfig
from repro.traffic import TraceSpec, TrafficHarness, VisionDriver, make_trace


# ===========================================================================
# tracer: span discipline + chrome export
# ===========================================================================
def test_span_nesting_and_ordering():
    tr = Tracer()
    tr.begin("outer", t_ms=1.0)
    tr.begin("inner", t_ms=2.0, depth=1)
    tr.end("inner", t_ms=3.0)
    tr.end("outer", t_ms=5.0)
    with tr.span("ctx", track="wall"):   # wall clock on its own track
        pass
    # closed spans appear innermost-first within a nest
    names = [s["name"] for s in tr.span_log]
    assert names == ["inner", "outer", "ctx"]
    inner, outer = tr.span_log[0], tr.span_log[1]
    assert inner["ts_ms"] == 2.0 and inner["dur_ms"] == 1.0
    assert outer["ts_ms"] == 1.0 and outer["dur_ms"] == 4.0
    assert inner["attrs"] == {"depth": 1}
    doc = tr.chrome_trace()
    info = validate_chrome_trace(doc)
    assert info["spans"] == 3
    # B/E events come out in chronological order per track
    bes = [(e["ph"], e["name"]) for e in doc["traceEvents"]
           if e["ph"] in "BE"]
    assert bes[:4] == [("B", "outer"), ("B", "inner"),
                       ("E", "inner"), ("E", "outer")]


def test_mismatched_end_raises():
    tr = Tracer()
    tr.begin("a", t_ms=0.0)
    with pytest.raises(ValueError, match="does not match"):
        tr.end("b", t_ms=1.0)
    tr.end("a", t_ms=1.0)
    with pytest.raises(ValueError, match="no open span"):
        tr.end("a", t_ms=2.0)


def test_chrome_trace_refuses_open_spans():
    tr = Tracer()
    tr.begin("dangling", t_ms=0.0)
    assert tr.open_spans() == ["dangling"]
    with pytest.raises(ValueError, match="open span"):
        tr.chrome_trace()


def test_tracks_get_distinct_tids_and_metadata():
    tr = Tracer()
    tr.begin("a", track="engine", t_ms=0.0)
    tr.end("a", track="engine", t_ms=1.0)
    tr.instant("mark", track="pipeline", t_ms=0.5)
    doc = tr.chrome_trace()
    meta = {e["args"]["name"]: e["tid"] for e in doc["traceEvents"]
            if e["ph"] == "M"}
    assert set(meta) == {"engine", "pipeline"}
    assert meta["engine"] != meta["pipeline"]
    info = validate_chrome_trace(doc)
    assert info["tracks"] == 2


def test_disabled_tracer_records_nothing():
    for tr in (NullTracer(), Tracer(enabled=False), NULL_TRACER):
        assert not tr.enabled
        tr.begin("x", t_ms=0.0)
        with tr.span("y", t_ms=1.0):
            tr.instant("z", t_ms=1.5)
        tr.end("x", t_ms=2.0)
        assert tr.event_count == 0
        assert tr.span_log == []
        doc = tr.chrome_trace()
        assert doc["traceEvents"] == []
        assert validate_chrome_trace(doc)["events"] == 0


def test_write_chrome_trace_and_jsonl(tmp_path):
    tr = Tracer()
    with tr.span("s", t_ms=0.0, k=1):
        pass
    p = str(tmp_path / "t.json")
    tr.write_chrome_trace(p)
    validate_chrome_trace(json.load(open(p)))
    pj = str(tmp_path / "t.jsonl")
    tr.write_jsonl(pj)
    rows = [json.loads(l) for l in open(pj)]
    assert rows[0]["name"] == "s" and rows[0]["attrs"] == {"k": 1}


def test_validator_rejects_malformed_traces():
    ok = {"displayTimeUnit": "ms", "traceEvents": [
        {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 0.0},
        {"ph": "E", "name": "a", "pid": 1, "tid": 1, "ts": 1.0}]}
    validate_chrome_trace(ok)
    bad_order = {"traceEvents": [
        {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 5.0},
        {"ph": "E", "name": "a", "pid": 1, "tid": 1, "ts": 1.0}]}
    with pytest.raises(ValueError, match="decreases"):
        validate_chrome_trace(bad_order)
    unbalanced = {"traceEvents": [
        {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 0.0}]}
    with pytest.raises(ValueError, match="unbalanced"):
        validate_chrome_trace(unbalanced)
    crossed = {"traceEvents": [
        {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 0.0},
        {"ph": "E", "name": "b", "pid": 1, "tid": 1, "ts": 1.0}]}
    with pytest.raises(ValueError, match="does not match"):
        validate_chrome_trace(crossed)
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})


# ===========================================================================
# metrics: determinism, histogram reads, absorb
# ===========================================================================
def test_log_buckets_deterministic_and_ascending():
    a = log_buckets(1e-3, 1e5, 4)
    assert a == log_buckets(1e-3, 1e5, 4)
    assert all(x < y for x, y in zip(a, a[1:]))
    assert a[0] <= 1e-3 and a[-1] >= 1e5
    with pytest.raises(ValueError, match="lo"):
        log_buckets(0.0, 1.0)


def test_counter_gauge_semantics():
    mx = MetricsRegistry()
    c = mx.counter("c")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError, match="monotone"):
        c.inc(-1)
    mx.gauge("g").set(2.5)
    assert mx.gauge("g").value == 2.5
    with pytest.raises(TypeError, match="counter"):
        mx.gauge("c")


def test_histogram_percentile_nearest_rank():
    mx = MetricsRegistry()
    h = mx.histogram("h", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 3.0, 3.5, 100.0):
        h.record(v)
    assert h.count == 5 and h.max == 100.0
    assert h.percentile(50) == 4.0     # rank 3 -> bucket edge 4.0
    assert h.percentile(99) == 100.0   # overflow bucket reads as max
    snap = h.snapshot()
    assert snap["counts"] == [1, 1, 2, 0, 1]
    assert math.isnan(mx.histogram("empty").percentile(50))


def test_same_stream_gives_identical_snapshots():
    def fill(mx):
        mx.counter("n").inc(7)
        h = mx.histogram("lat")
        for v in (0.01, 0.5, 3.0, 42.0):
            h.record(v)
        mx.absorb("s", {"a": 1, "b": 2.5, "mode": "full",
                        "flag": True, "tup": (1, 2)})
    m1, m2 = MetricsRegistry(), MetricsRegistry()
    fill(m1)
    fill(m2)
    assert (json.dumps(m1.snapshot(), sort_keys=True)
            == json.dumps(m2.snapshot(), sort_keys=True))
    # absorb: numerics become gauges, bools/strings/tuples are skipped
    assert m1.names() == ["lat", "n", "s.a", "s.b"]


def test_registry_write_json(tmp_path):
    mx = MetricsRegistry()
    mx.counter("x").inc()
    p = str(tmp_path / "m.json")
    mx.write_json(p)
    assert json.load(open(p))["x"] == {"type": "counter", "value": 1.0}


# ===========================================================================
# event log: bounded ring with absolute indices
# ===========================================================================
def test_eventlog_absolute_indexing_survives_eviction():
    log = EventLog(capacity=4)
    for i in range(3):
        log.append(("ev", i))
    mark = len(log)                      # the harness's consumption pattern
    for i in range(3, 10):
        log.append(("ev", i))
    assert len(log) == 10                # total ever, not buffered
    assert log.buffered == 4 and log.dropped == 6
    # absolute slice: evicted entries silently absent, live ones correct
    assert log[mark:] == [("ev", i) for i in range(6, 10)]
    assert log[0:] == [("ev", i) for i in range(6, 10)]
    assert log[7] == ("ev", 7)
    with pytest.raises(IndexError, match="evicted"):
        log[2]
    with pytest.raises(IndexError):
        log[10]
    assert list(log) == [("ev", i) for i in range(6, 10)]


def test_eventlog_drain_preserves_total():
    log = EventLog(capacity=8)
    for i in range(5):
        log.append(i)
    out = log.drain()
    assert out == [0, 1, 2, 3, 4]
    assert len(log) == 5 and log.buffered == 0
    log.append(5)
    assert log[5] == 5 and len(log) == 6


def test_scheduler_event_ring_keeps_counters_exact():
    class _R:
        def __init__(self, uid):
            self.uid = uid

    sched = Scheduler(2, event_capacity=4)
    sched.submit([_R(i) for i in range(6)])
    for _ in range(3):
        for slot, _req in sched.schedule():
            sched.retire(slot)
    st = sched.stats()
    # the ring evicted early events, but lifecycle counters are exact
    assert st["admitted_total"] == st["retired_total"] == 6
    assert sched.num_admissions == sched.num_retirements == 6
    assert st["events_dropped"] > 0
    assert len(sched.events) > sched.events.buffered
    drained = sched.drain_events()
    assert drained and sched.events.buffered == 0
    assert sched.stats()["admitted_total"] == 6   # drain changes nothing


# ===========================================================================
# harness traces: virtual clock, cross-depth byte-identity
# ===========================================================================
@pytest.fixture(scope="module")
def packed_vit(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)
    return cfg, masked, packed


def _vision_engine(packed_vit, depth=1):
    cfg, masked, packed = packed_vit
    return VisionEngine(cfg, masked, packed, VisionEngineConfig(
        max_batch=2, planner="full", pipeline_depth=depth))


def _spec(n=6):
    return TraceSpec(n=n, rate_rps=60000.0, process="bursty", sizes=(9, 4),
                     r_ts=(None, 0.7), deadlines_ms=(0.05, None))


def test_virtual_traces_identical_across_depths(packed_vit):
    trace = make_trace(_spec(), seed=9)
    docs, metrics, digests = [], [], []
    for depth in (1, 2):
        tr, mx = Tracer(), MetricsRegistry()
        h = TrafficHarness(VisionDriver(_vision_engine(packed_vit, depth)),
                           tracer=tr, metrics=mx)
        rep = h.run(trace)
        digests.append(rep["outputs_digest"])
        docs.append(json.dumps(tr.chrome_trace(), sort_keys=True))
        metrics.append(json.dumps(mx.snapshot(), sort_keys=True))
        info = validate_chrome_trace(tr.chrome_trace())
        assert info["spans"] > 0
        # per-step spans + per-request lifecycle spans are both present
        names = {s["name"] for s in tr.span_log}
        assert {"step", "plan", "stage", "dispatch", "complete",
                "enqueue", "serve"} <= names
        # lifecycle span timestamps match the records (virtual clock)
        for s in tr.span_log:
            if s["name"] == "serve":
                rec = h.records[s["attrs"]["uid"]]
                assert s["ts_ms"] == rec.first_dispatch_ms
                assert s["ts_ms"] + s["dur_ms"] == rec.retire_ms
    # pipeline depth changes wall time, never the virtual timeline:
    # byte-identical trace documents, metrics snapshots, and outputs
    assert docs[0] == docs[1]
    assert metrics[0] == metrics[1]
    assert digests[0] == digests[1]


def test_harness_tracing_is_digest_neutral(packed_vit):
    trace = make_trace(_spec(), seed=4)
    plain = TrafficHarness(VisionDriver(_vision_engine(packed_vit)))
    rep_plain = plain.run(trace)
    tr = Tracer()
    traced = TrafficHarness(VisionDriver(_vision_engine(packed_vit)),
                            tracer=tr)
    rep_traced = traced.run(trace)
    assert rep_plain["outputs_digest"] == rep_traced["outputs_digest"]
    assert rep_plain == rep_traced      # the report itself is unchanged
    assert tr.event_count > 0
    # disabled tracer through the same path records nothing
    off = TrafficHarness(VisionDriver(_vision_engine(packed_vit)),
                         tracer=NULL_TRACER)
    rep_off = off.run(trace)
    assert rep_off == rep_plain


def test_engine_wallclock_spans_balanced(packed_vit):
    # engine + pipeline tracks (plan/stage/dispatch/complete) on the real
    # clock: the export must validate with no dangling spans after serve
    cfg, masked, packed = packed_vit
    tr = Tracer()
    eng = VisionEngine(cfg, masked, packed,
                       VisionEngineConfig(max_batch=2, planner="full"),
                       tracer=tr)
    from repro.launch.serve_vision import make_requests
    out = eng.serve(make_requests(cfg, 4, 2, 0))
    assert len(out) == 4
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc)["spans"] > 0
    names = {s["name"] for s in tr.span_log}
    assert {"plan", "stage", "dispatch", "complete"} <= names
    mx = eng.export_metrics(MetricsRegistry())
    assert mx.gauge("vision.jit_compile_count").value > 0
    assert "vision.plan_cost_error" in mx.names()


# ===========================================================================
# schema v4: metrics block in the bench envelope
# ===========================================================================
def test_artifact_metrics_block_roundtrip(tmp_path):
    from repro.bench import load_bench_artifact, write_bench_artifact
    mx = MetricsRegistry()
    mx.counter("vision.recompiles").inc(3)
    path = str(tmp_path / "a.json")
    write_bench_artifact(path, "vision", {"k": 1}, {"r": 2},
                         metrics=mx.snapshot())
    art = load_bench_artifact(path, expect_kind="vision")
    assert art["schema_version"] == 4
    assert art["metrics"]["vision.recompiles"]["value"] == 3.0
    # metrics omitted -> key present, null (always-present envelope field)
    path2 = str(tmp_path / "b.json")
    write_bench_artifact(path2, "vision", {}, {})
    assert load_bench_artifact(path2)["metrics"] is None
