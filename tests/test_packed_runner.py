"""End-to-end packed-model execution: the SBMM-kernel ViT must match the
masked-dense oracle — the accelerator-vs-software parity check."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DEIT_SMALL
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG


def test_packed_vit_matches_masked_dense(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    packed = PR.pack_model(cfg, params, scores)
    assert len(packed) == cfg.num_layers * 4  # wq,wk,wv,wo per layer

    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(rng_key, (2, n, cfg.patch_size ** 2 * 3))

    # the deployment path runs MLPs masked-dense (DBMM analog) and
    # attention through SBMM; pass the masked tree for the dense parts
    masked = PG.apply_pruning(cfg, params, scores)
    out_kernel = PR.forward_vit_packed(cfg, masked, packed, patches,
                                       use_tdm=False)
    out_oracle = PR.masked_dense_reference(cfg, params, scores, patches,
                                           use_tdm=False)
    np.testing.assert_allclose(np.asarray(out_kernel.logits),
                               np.asarray(out_oracle.logits),
                               atol=2e-3, rtol=2e-3)


def test_packed_vit_with_tdm_runs(rng_key):
    """Both prunings simultaneously active on the kernel execution path —
    the full deployment configuration of the paper."""
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    packed = PR.pack_model(cfg, params, scores)
    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(rng_key, (2, n, cfg.patch_size ** 2 * 3))
    out = PR.forward_vit_packed(cfg, params, packed, patches, use_tdm=True)
    assert out.logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(out.logits).all())
