"""End-to-end packed-model execution: the SBMM-kernel ViT must match the
masked-dense oracle — the accelerator-vs-software parity check."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DEIT_SMALL
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG


def test_packed_vit_matches_masked_dense(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    packed = PR.pack_model(cfg, params, scores)
    assert len(packed) == cfg.num_layers * 4  # wq,wk,wv,wo per layer

    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(rng_key, (2, n, cfg.patch_size ** 2 * 3))

    # the deployment path runs MLPs masked-dense (DBMM analog) and
    # attention through SBMM; pass the masked tree for the dense parts
    masked = PG.apply_pruning(cfg, params, scores)
    out_kernel = PR.forward_vit_packed(cfg, masked, packed, patches,
                                       use_tdm=False)
    out_oracle = PR.masked_dense_reference(cfg, params, scores, patches,
                                           use_tdm=False)
    np.testing.assert_allclose(np.asarray(out_kernel.logits),
                               np.asarray(out_oracle.logits),
                               atol=2e-3, rtol=2e-3)


def test_packed_vit_with_tdm_runs(rng_key):
    """Both prunings simultaneously active on the kernel execution path —
    the full deployment configuration of the paper."""
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    packed = PR.pack_model(cfg, params, scores)
    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(rng_key, (2, n, cfg.patch_size ** 2 * 3))
    out = PR.forward_vit_packed(cfg, params, packed, patches, use_tdm=True)
    assert out.logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(out.logits).all())


# ---------------------------------------------------------------------------
# keep-count rules and per-step keep schedules (quality-elastic serving)
# ---------------------------------------------------------------------------
def test_tdm_keep_count_agrees_with_num_kept_tokens():
    """``tdm_keep_count`` is derived from ``TP.num_kept_tokens`` (the one
    source of truth for the ceil/clamp rule): output = CLS + k + fused."""
    from repro.core import token_pruning as TP

    for n in (2, 3, 5, 17, 50, 197):
        for r in (1e-9, 0.1, 0.25, 0.5, 0.7, 0.99, 1.0):
            k = PR.tdm_keep_count(n, r)
            assert k + 2 == TP.num_kept_tokens(n, r, has_cls=True)
            assert k >= 1  # the max(1, ceil(...)) floor


def test_trajectory_monotone_in_keep_rate():
    """Pointwise monotone: a tighter keep rate never carries MORE tokens
    through any segment; r_t -> 0 bottoms out at the 1-token floor and
    r_t = 1 keeps every body token (hard TDM even grows by the fused
    slot)."""
    cfg = DEIT_SMALL.reduced()
    n = 16
    rates = (1.0, 0.7, 0.5, 0.25, 0.1, 1e-9)
    trajs = [PR.token_trajectory(cfg, n, r_t=r, use_tdm=True)
             for r in rates]
    for hi, lo in zip(trajs, trajs[1:]):
        assert all(a >= b for a, b in zip(hi, lo)), (hi, lo)
    # r_t -> 0: every TDM collapses to the floor count CLS + 1 + fused
    assert min(trajs[-1]) == 3
    # r_t = 1: the hard TDM appends the fused slot on top of a full keep
    full = PR.token_trajectory(cfg, n, r_t=1.0, use_tdm=True)
    assert max(full) == n + 2  # CLS + n kept + fused


def test_keep_schedule_broadcast_equivalence():
    """A scalar r_t is exactly its broadcast schedule — the frozen-scalar
    path is a special case of the per-step machinery, not a twin."""
    cfg = DEIT_SMALL.reduced()
    sched = PR.keep_schedule(cfg, r_t=0.6, use_tdm=True)
    assert sched == (0.6,) * len(sched) and len(sched) >= 1
    assert (PR.token_trajectory(cfg, 16, r_t=0.6, use_tdm=True)
            == PR.token_trajectory(cfg, 16, schedule=sched, use_tdm=True))


def test_soft_keep_count_clamps_at_package_row():
    """Once a package row exists, soft top-k draws from n-2 real body rows:
    k clamps there (binds only as r_t -> 1), so the soft output count
    never exceeds the input count."""
    for n in (5, 18, 50):
        assert PR.tdm_soft_keep_count(n, 1.0, has_pkg=True) == n - 2
        assert (PR.tdm_soft_keep_count(n, 1.0, has_pkg=False)
                == PR.tdm_keep_count(n, 1.0))
        # away from r=1 the clamp is inactive: same k as the hard rule
        assert (PR.tdm_soft_keep_count(n, 0.5, has_pkg=True)
                == PR.tdm_keep_count(n, 0.5))
    soft = PR.token_trajectory(DEIT_SMALL.reduced(), 16, r_t=1.0,
                               use_tdm=True, soft=True)
    assert all(c <= 16 + 2 for c in soft)


def test_forward_soft_matches_fused_soft_lane(rng_key):
    """The fused express-lane program threads the package mass across soft
    steps in-program — it must agree with the per-segment soft path."""
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)
    n = 16
    patches = jax.random.normal(rng_key, (1, n, cfg.patch_size ** 2 * 3))
    seq = PR.forward_vit_packed(cfg, masked, packed, patches, use_tdm=True,
                                soft=True)
    sched = PR.keep_schedule(cfg, use_tdm=True)
    traj = PR.token_trajectory(cfg, n, use_tdm=True, soft=True)
    steps = []
    cur, ordinal = n, 0
    for seg, after in zip(PR.vit_segments(cfg, True), traj):
        if seg[0] == "tdm":
            k = PR.tdm_soft_keep_count(cur, sched[ordinal],
                                       has_pkg=ordinal > 0)
            steps.append((seg, k, True))
            ordinal += 1
        else:
            steps.append((seg, None))
        cur = after
    fused = PR.run_fused_steps(cfg, masked, packed, patches, tuple(steps))
    np.testing.assert_allclose(np.asarray(fused),
                               np.asarray(seq.logits), atol=1e-5)
