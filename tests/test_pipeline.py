"""StepPipeline + pipelined step loops: depth semantics, staged-state
rollback on drop (the replan-between-stage-and-dispatch regression),
mid-step admission landing in the NEXT plan, plan_ahead memoization
equivalence, and cross-depth bit-exactness for both engines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DEIT_SMALL, get_config
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import (EngineConfig, PlanItem, Request, ServeEngine,
                           StagedStep, StepPipeline, VisionEngine,
                           VisionEngineConfig, VisionRequest)
from repro.serving.planner import TileCostModel, TilePlanner
from repro.serving.ragged_batcher import RaggedBatcher


# ---------------------------------------------------------------------------
# StepPipeline unit semantics
# ---------------------------------------------------------------------------
def _step(i, done):
    return StagedStep(dispatch=lambda: jnp.full((2,), i),
                      complete=lambda h: done.append(i), label=f"s{i}")


def test_depth_one_completes_inside_submit():
    done = []
    p = StepPipeline(1)
    p.submit(_step(0, done))
    assert done == [0] and p.in_flight == 0
    assert p.stats()["steps"] == 1


def test_depth_two_keeps_one_step_in_flight():
    done = []
    p = StepPipeline(2)
    p.submit(_step(0, done))
    assert done == [] and p.in_flight == 1
    p.submit(_step(1, done))  # dispatch 1 completes 0
    assert done == [0] and p.in_flight == 1
    p.flush()
    assert done == [0, 1] and p.in_flight == 0


def test_drop_runs_rollback_and_dispatched_steps_cannot_drop():
    rolled, done = [], []
    p = StepPipeline(2)
    s = StagedStep(dispatch=lambda: jnp.zeros(()), complete=lambda h: None,
                   rollback=lambda: rolled.append(True))
    p.drop(s)
    assert rolled == [True] and p.stats()["drops"] == 1
    live = _step(7, done)
    p.submit(live)
    with pytest.raises(RuntimeError, match="dispatched"):
        p.drop(live)
    p.flush()


def test_starvation_counts_empty_queue_gaps_only():
    """starved_s accumulates host time spent while NOTHING is in flight
    (depth 1: every inter-step gap) and skips gaps covered by an
    in-flight step (depth 2) — the bench's device_idle_s column."""
    import time as _time

    gap = 0.03
    done = []
    p1 = StepPipeline(1)
    p1.submit(_step(0, done))      # completes inside submit -> queue empty
    _time.sleep(gap)               # host "staging" with the device starved
    p1.submit(_step(1, done))
    assert p1.stats()["starved_s"] >= gap

    p2 = StepPipeline(2)
    p2.submit(_step(0, done))      # stays in flight
    base = p2.stats()["starved_s"]
    _time.sleep(gap)               # device has queued work the whole gap
    p2.submit(_step(1, done))
    assert p2.stats()["starved_s"] - base < gap / 2
    p2.flush()


def test_depth_validation():
    with pytest.raises(ValueError, match="depth"):
        StepPipeline(0)
    with pytest.raises(ValueError, match="pipeline_depth"):
        EngineConfig(pipeline_depth=0)
    with pytest.raises(ValueError, match="pipeline_depth"):
        VisionEngineConfig(pipeline_depth=0)


# ---------------------------------------------------------------------------
# plan_ahead: memoized fusibility == exact per-horizon scan, and purity
# ---------------------------------------------------------------------------
def _planner(mode="fuse"):
    return TilePlanner(RaggedBatcher(token_tile=1, max_batch=8),
                       TileCostModel(dispatch_overhead_cycles=1000.0),
                       mode=mode)


def _traj_items(*trajs):
    return [PlanItem(stage=t[0][0], n_tokens=t[0][1], trajectory=t)
            for t in trajs]


@pytest.mark.parametrize("mode", ["off", "merge", "fuse", "full"])
def test_plan_ahead_matches_exact_replan_per_horizon(mode):
    """Every speculative plan must equal what a from-scratch ``_build``
    (exact pairwise fusion scan) produces on the advanced population —
    the memoized last-collision offsets are an optimization, never a
    semantic change."""
    p = _planner(mode)
    items = _traj_items(
        (("a", 16), ("b", 12), ("c", 8), ("d", 6), ("e", 4), ("f", 2)),
        (("a", 16), ("b", 11), ("c", 8), ("d", 5), ("e", 3), ("f", 1)),
        (("x", 9), ("y", 7), ("z", 5)),
    )
    plans = p.plan_ahead(items, 5)
    assert len(plans) > 1
    cur = list(items)
    for h in range(1, len(plans)):
        cur = p.advance_items(cur, plans[h - 1])
        exact = p._build(cur)
        assert plans[h].tiles == exact.tiles, f"horizon {h}"
        assert plans[h].lanes == exact.lanes, f"horizon {h}"
    if mode in ("fuse", "full"):
        # items 0/1 last collide at offset 2 -> both go solo at horizon 3
        assert any(pl.lanes for pl in plans[1:])


def test_plan_ahead_is_pure_and_commit_folds_ledgers():
    p = _planner("full")
    items = _traj_items((("a", 16), ("b", 12), ("c", 8)),
                        (("a", 16), ("b", 11), ("c", 8)))
    plans = p.plan_ahead(items, 3)
    assert p.plans == 0 and p.batcher.tiles_planned == 0
    p.commit(plans[0])
    assert p.plans == 1 and p.batcher.tiles_planned == len(plans[0].tiles)


# ---------------------------------------------------------------------------
# LM engine: rollback regression + cross-depth bit-exactness
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _lm_reqs():
    return [Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                    max_new_tokens=10),
            Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 7,
                    max_new_tokens=12)]


def _kvm_state(kvm):
    return (kvm.caches, kvm.lengths.copy(), kvm.starts.copy(),
            kvm.active.copy(), kvm.steps_since_prune, kvm.prune_events)


def _assert_kvm_equal(kvm, pre):
    assert kvm.caches is pre[0]  # handle identity: nothing was dispatched
    assert np.array_equal(kvm.lengths, pre[1])
    assert np.array_equal(kvm.starts, pre[2])
    assert np.array_equal(kvm.active, pre[3])
    assert kvm.steps_since_prune == pre[4]
    assert kvm.prune_events == pre[5]


def test_lm_stage_then_drop_leaves_no_trace(lm_setup):
    """Replan between stage and dispatch: dropping a staged admission or
    decode step must restore every KVCacheManager counter/mirror and the
    cache handle, and the restaged step must still produce tokens."""
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, EngineConfig(
        max_batch=2, max_len=24, kv_prune_interval=2, kv_prune_keep=0.5,
        pipeline_depth=2))
    sched, kvm = eng.scheduler, eng.cache
    reqs = _lm_reqs()
    eng._annotate_prune_load(reqs)
    sched.submit(reqs)
    kvm.reset()
    eng._toks = np.zeros((2,), np.int64)
    eng._scheduled = {}
    admitted = sched.schedule()
    out = {}

    pre = _kvm_state(kvm)
    apt = eng.admission_prefill_tokens
    staged = eng._stage_admissions(admitted, out)
    assert kvm.active.any()  # staging DID mutate the mirrors
    eng.pipeline.drop(staged)
    _assert_kvm_equal(kvm, pre)
    assert eng.admission_prefill_tokens == apt  # counter is dispatch-side
    assert eng._scheduled == {}

    eng.pipeline.submit(eng._stage_admissions(admitted, out))
    pre2 = _kvm_state(kvm)
    staged2 = eng._stage_decode(out)
    assert kvm.steps_since_prune != pre2[4]  # prune cadence ticked in stage
    assert not np.array_equal(kvm.lengths, pre2[1])  # on_decode advanced
    eng.pipeline.drop(staged2)
    _assert_kvm_equal(kvm, pre2)
    assert eng.pipeline.stats()["drops"] == 2

    eng.pipeline.submit(eng._stage_decode(out))
    eng.pipeline.flush()
    for _, req in admitted:
        assert len(req.generated) == 2  # prefill token + one decode token


@pytest.mark.parametrize("depth", [2, 3])
def test_lm_continuous_depth_bitexact(lm_setup, depth):
    """Pipelined depths must reproduce the depth-1 (synchronous) path
    exactly: same tokens, same admit/retire event stream, same prune
    count — with KV pruning firing mid-stream and slot churn."""
    cfg, params = lm_setup

    def run(d):
        eng = ServeEngine(cfg, params, EngineConfig(
            max_batch=2, max_len=24, kv_prune_interval=2,
            kv_prune_keep=0.5, pipeline_depth=d))
        out = eng.serve(_lm_reqs(), continuous=True)
        return out, list(eng.events), eng.prune_events, eng

    base, base_ev, base_pr, _ = run(1)
    got, got_ev, got_pr, eng = run(depth)
    assert got == base
    assert got_ev == base_ev
    assert got_pr == base_pr and base_pr > 0
    st = eng.stats()
    assert st["pipeline_steps"] > 0
    assert st["pipeline_drops"] == 0  # no mid-step submissions here


# ---------------------------------------------------------------------------
# Vision engine: stage/drop leak audit + mid-step admission
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def packed_vit():
    cfg = DEIT_SMALL.reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)
    return cfg, masked, packed


def _vision_reqs(cfg, mixes, seed=0):
    rng = np.random.default_rng(seed)
    pdim = cfg.patch_size ** 2 * 3
    return [VisionRequest(
        uid=i, patches=rng.standard_normal((n, pdim)).astype(np.float32),
        r_t=r_t, arrival_step=arr)
        for i, (n, r_t, arr) in enumerate(mixes)]


def test_vision_stage_then_drop_leaves_no_trace(packed_vit):
    """Vision staging is mutation-free by construction: a staged-then-
    dropped step must leave the planner/batcher ledgers, the step
    counter, and every in-flight request exactly as they were — and the
    restaged steps must still serve the requests to completion."""
    cfg, masked, packed = packed_vit
    eng = VisionEngine(cfg, masked, packed,
                       VisionEngineConfig(max_batch=3, planner="full",
                                          pipeline_depth=2))
    reqs = _vision_reqs(cfg, [(16, None, 0), (9, 0.5, 0)])
    for r in reqs:
        eng._validate(r)
    eng.scheduler.submit(reqs)
    eng.scheduler.schedule()
    eng._sync_admissions()

    pre_live = {s: (lv.seg_idx, lv.n_tokens, lv.x)
                for s, lv in eng._live.items()}
    pre = (eng.planner.plans, eng.batcher.tiles_planned,
           eng.batcher.padded_cells, eng.steps)
    out = {}
    staged = eng._stage_step(out)
    eng.pipeline.drop(staged)
    assert (eng.planner.plans, eng.batcher.tiles_planned,
            eng.batcher.padded_cells, eng.steps) == pre
    for s, (seg, n, x) in pre_live.items():
        lv = eng._live[s]
        assert (lv.seg_idx, lv.n_tokens) == (seg, n) and lv.x is x
    assert eng.pipeline.stats()["drops"] == 1 and out == {}

    while eng.scheduler.has_work():
        eng.pipeline.submit(eng._stage_step(out))
        eng.pipeline.flush()
        eng._retire_finished()
    assert sorted(out) == [0, 1]
    for r in reqs:  # bit-exact against the offline oracle
        c = cfg if r.r_t is None else cfg.replace(
            pruning=dataclasses.replace(cfg.pruning, r_t=r.r_t))
        ref = np.asarray(PR.forward_vit_packed(
            c, masked, packed, r.patches[None]).logits[0])
        assert np.array_equal(out[r.uid], ref)


def test_vision_midstep_submission_lands_in_next_plan(packed_vit):
    """A request submitted while a step is being staged must trigger a
    drop + replan: it joins the REBUILT plan for this step (never mutates
    the staged one), and the whole serve is bit-exact — logits and event
    stream — against submitting it at the step boundary."""
    cfg, masked, packed = packed_vit
    mixes = [(16, None, 0), (9, 0.5, 0), (4, 0.7, 0)]

    def run(hook):
        eng = VisionEngine(cfg, masked, packed,
                           VisionEngineConfig(max_batch=4, planner="full",
                                              pipeline_depth=2))
        reqs = _vision_reqs(cfg, mixes)
        late = _vision_reqs(cfg, [(9, None, 0)], seed=1)[0]
        late = dataclasses.replace(late, uid=3)
        populations = []
        if hook:
            real, fired = eng.planner.plan_ahead, []

            def spy(items, horizon):
                populations.append(len(items))
                if not fired:  # submit mid-staging, exactly once
                    fired.append(True)
                    eng._validate(late)
                    eng.scheduler.submit([late])
                return real(items, horizon)

            eng.planner.plan_ahead = spy
            out = eng.serve(reqs)
        else:
            out = eng.serve(reqs + [late])
        return out, list(eng.events), populations, eng

    ref, ref_ev, _, _ = run(hook=False)
    got, got_ev, pops, eng = run(hook=True)

    # staged plan N covered 3 items; the replanned step covers all 4
    assert pops[:2] == [3, 4]
    assert eng.pipeline.stats()["drops"] == 1
    # only dispatched plans reached the ledgers
    assert eng.planner.plans == eng.pipeline.stats()["steps"]
    assert sorted(got) == [0, 1, 2, 3]
    assert got_ev == ref_ev
    for uid in ref:
        assert np.array_equal(got[uid], ref[uid])


@pytest.mark.parametrize("planner", ["off", "full"])
def test_vision_depth_bitexact(packed_vit, planner):
    """Depth 2 reproduces depth 1 logits bit-for-bit under staggered
    arrivals and slot churn, and the speculative plan cache actually
    gets consulted (identical concurrent trajectories never fuse away,
    so populations persist across steps in every planner mode)."""
    cfg, masked, packed = packed_vit
    mixes = [(16, None, 0), (16, None, 0), (9, 0.5, 1), (9, 0.5, 2),
             (16, None, 3)]

    def run(d):
        eng = VisionEngine(cfg, masked, packed,
                           VisionEngineConfig(max_batch=2, planner=planner,
                                              pipeline_depth=d))
        return eng.serve(_vision_reqs(cfg, mixes)), list(eng.events), eng

    base, base_ev, _ = run(1)
    got, got_ev, eng = run(2)
    assert got_ev == base_ev
    assert sorted(got) == sorted(base)
    for uid in base:
        assert np.array_equal(base[uid], got[uid])
    st = eng.stats()
    assert st["pipeline_steps"] == st["steps"]
    assert st["plan_ahead_hits"] + st["plan_ahead_drops"] > 0
