"""TilePlanner / TileCostModel unit tests: identity-plan equivalence in
mode ``off``, cost-model-gated bucket merging, express-lane fusion of
forever-singletons, deadline splits/ordering, calibration fitting, and
ExecutionPlan hashability/determinism."""
import pytest

from repro.serving.planner import (PLANNER_MODES, ExecutionPlan, PlanItem,
                                   TileCostModel, TilePlanner)
from repro.serving.ragged_batcher import RaggedBatcher


def _items(*specs):
    """specs: (stage, n) or (stage, n, traj) or dict kwargs."""
    out = []
    for s in specs:
        if isinstance(s, dict):
            out.append(PlanItem(**s))
        elif len(s) == 2:
            out.append(PlanItem(stage=s[0], n_tokens=s[1]))
        else:
            out.append(PlanItem(stage=s[0], n_tokens=s[1], trajectory=s[2]))
    return out


def _planner(mode="full", overhead=1000.0, **kw):
    b = RaggedBatcher(token_tile=1, max_batch=8)
    cm = TileCostModel(dispatch_overhead_cycles=overhead)
    return TilePlanner(b, cm, mode=mode, **kw)


# -- identity mode ---------------------------------------------------------
def test_off_mode_is_the_ragged_batcher_identity_plan():
    """Mode 'off' must reproduce RaggedBatcher.plan tile-for-tile — the
    trivial cost model's special case (PR 4's bit-exact balanced path)."""
    specs = [("a", 17), ("a", 17), ("a", 10), ("b", 17), ("a", 5)]
    ref = RaggedBatcher(token_tile=1, max_batch=8).plan(specs)
    p = _planner(mode="off")
    plan = p.plan(_items(*specs))
    assert list(plan.tiles) == ref
    assert plan.lanes == ()
    assert plan.covered_members() == list(range(len(specs)))
    assert plan.stats.merges == plan.stats.lanes == 0
    assert plan.stats.modeled_saving_cycles == 0.0
    # off-mode identity also records into the batcher stats, like plan()
    assert p.batcher.tiles_planned == len(ref)


def test_off_mode_ignores_deadlines():
    p = _planner(mode="off")
    plan = p.plan(_items({"stage": "a", "n_tokens": 4,
                          "deadline_left_ms": -5.0}))
    assert plan.stats.deadline_urgent == 0


# -- merging ---------------------------------------------------------------
def test_merge_when_dispatch_overhead_dominates():
    """Two under-full neighboring buckets of one stage merge into one
    masked tile when overhead > modeled padding cost."""
    p = _planner(mode="merge", overhead=1e6)
    plan = p.plan(_items(("s", 8), ("s", 9)))
    assert len(plan.tiles) == 1 and plan.stats.merges == 1
    (t,) = plan.tiles
    assert sorted(t.members) == [0, 1]
    assert t.n_tile == 9 and t.needs_mask
    assert plan.stats.modeled_saving_cycles > 0
    assert plan.covered_members() == [0, 1]


def test_no_merge_when_padding_costs_more_than_dispatch():
    p = _planner(mode="merge", overhead=0.0)
    plan = p.plan(_items(("s", 8), ("s", 9)))
    assert len(plan.tiles) == 2 and plan.stats.merges == 0


def test_merge_never_crosses_stages():
    p = _planner(mode="merge", overhead=1e9)
    plan = p.plan(_items(("s", 8), ("t", 9)))
    assert len(plan.tiles) == 2 and plan.stats.merges == 0


def test_merge_respects_token_cap():
    """A hard cap (the embed stage's position table) blocks a merge that
    would pad a member past it."""
    p = _planner(mode="merge", overhead=1e9)
    plan = p.plan(_items({"stage": "s", "n_tokens": 8, "cap": 8},
                         {"stage": "s", "n_tokens": 9, "cap": 9}))
    assert len(plan.tiles) == 2 and plan.stats.merges == 0
    # without the cap the same population merges
    p2 = _planner(mode="merge", overhead=1e9)
    assert p2.plan(_items(("s", 8), ("s", 9))).stats.merges == 1


def test_merge_chains_neighboring_buckets():
    p = _planner(mode="merge", overhead=1e9)
    plan = p.plan(_items(("s", 4), ("s", 5), ("s", 6)))
    assert len(plan.tiles) == 1 and plan.stats.merges == 2
    (t,) = plan.tiles
    assert t.n_tile == 6 and len(t.members) == 3


# -- express lanes ---------------------------------------------------------
def _traj(stage_seq):
    return tuple(stage_seq)


def test_fusion_requires_singleton_in_every_bucket():
    """An item fuses only when no other live item can ever share a bucket
    with it — including collisions at FUTURE trajectory offsets (two
    different-size requests converging to the same post-TDM count)."""
    # item 0 and 1 differ now but collide at offset 1 -> neither fuses
    t0 = _traj(((("L0",), 8), (("L1",), 6), (("H",), 6)))
    t1 = _traj(((("L0",), 9), (("L1",), 6), (("H",), 6)))
    p = _planner(mode="fuse")
    plan = p.plan(_items((("L0",), 8, t0), (("L0",), 9, t1)))
    assert plan.lanes == ()
    # truly disjoint trajectories -> both fuse, no tiles remain
    t1b = _traj(((("L0",), 9), (("L1",), 7), (("H",), 7)))
    p2 = _planner(mode="fuse")
    plan2 = p2.plan(_items((("L0",), 8, t0), (("L0",), 9, t1b)))
    assert len(plan2.lanes) == 2 and plan2.tiles == ()
    assert plan2.covered_members() == [0, 1]
    assert plan2.stats.fused_segments == 6
    assert p2.trajectory_count == 2


def test_fusion_skips_items_sharing_current_bucket():
    t = _traj(((("L0",), 8), (("H",), 8)))
    p = _planner(mode="fuse")
    plan = p.plan(_items((("L0",), 8, t), (("L0",), 8, t)))
    assert plan.lanes == ()
    assert len(plan.tiles) == 1  # they batch instead


def test_fusion_needs_min_segments():
    p = _planner(mode="fuse", fuse_min_segments=2)
    plan = p.plan(_items((("H",), 8, _traj(((("H",), 8),)))))
    assert plan.lanes == ()  # one remaining segment: nothing to fuse


# -- deadlines -------------------------------------------------------------
def test_deadline_urgent_split_and_dispatch_order():
    """An urgent member is carved out of its shared tile into a singleton
    tile dispatched FIRST; the remainder keeps its bucket shape."""
    p = _planner(mode="merge", overhead=0.0)  # no merging interference
    plan = p.plan(_items(
        {"stage": "s", "n_tokens": 8, "deadline_left_ms": -1.0},
        {"stage": "s", "n_tokens": 8},
        {"stage": "s", "n_tokens": 8}))
    assert plan.stats.deadline_urgent == 1
    assert plan.stats.deadline_splits == 1
    assert plan.covered_members() == [0, 1, 2]
    first = plan.tiles[0]
    assert first.members == (0,) and first.b_tile == 1
    rest = plan.tiles[1]
    assert sorted(rest.members) == [1, 2]
    # the engine dispatches plan.tiles[:urgent_tile_count()] before lanes
    assert plan.urgent == (0,)
    assert plan.urgent_tile_count() == 1


def test_deadline_urgent_members_never_merge():
    p = _planner(mode="merge", overhead=1e9)
    plan = p.plan(_items(
        {"stage": "s", "n_tokens": 8, "deadline_left_ms": -1.0},
        {"stage": "s", "n_tokens": 9}))
    assert plan.stats.merges == 0
    assert plan.stats.deadline_urgent == 1
    assert plan.tiles[0].members == (0,)  # urgent first
    assert plan.urgent == (0,) and plan.urgent_tile_count() == 1


def test_slack_uses_modeled_remaining_work():
    """Urgency is (time left) - (modeled remaining trajectory ms): a
    generous deadline is not urgent, one below the modeled work is."""
    cm = TileCostModel(dispatch_overhead_cycles=0.0, seconds_per_cycle=1e-3)
    b = RaggedBatcher(token_tile=1, max_batch=8)
    p = TilePlanner(b, cm, mode="full")
    traj = _traj((("s", 4), ("t", 4)))
    remaining_ms = cm.ms(cm.trajectory_cycles(traj))
    assert remaining_ms > 0
    mk = lambda left: _items({"stage": "s", "n_tokens": 4,
                              "trajectory": traj,
                              "deadline_left_ms": left})
    # fusible singleton: urgent or not, it still fuses; urgency is counted
    assert p.plan(mk(remaining_ms * 100)).stats.deadline_urgent == 0
    assert p.plan(mk(remaining_ms * 0.5)).stats.deadline_urgent == 1


# -- cost model ------------------------------------------------------------
def test_cost_model_calibrate_recovers_linear_fit():
    cm = TileCostModel()
    a, b = 2e-4, 3e-9  # 200us overhead, ~3ns/cycle
    samples = [(w, a + b * w) for w in (1e3, 1e4, 1e5, 1e6)]
    fit = cm.calibrate(samples)
    assert fit["seconds_per_cycle"] == pytest.approx(b, rel=1e-6)
    assert fit["dispatch_overhead_cycles"] == pytest.approx(a / b, rel=1e-6)
    assert fit["r2"] == pytest.approx(1.0, abs=1e-9)
    assert cm.calibrated
    assert cm.seconds_per_cycle == pytest.approx(b, rel=1e-6)


def test_cost_model_calibrate_validates_samples():
    cm = TileCostModel()
    with pytest.raises(ValueError, match="2 samples"):
        cm.calibrate([(1e3, 1e-3)])
    with pytest.raises(ValueError, match="distinct"):
        cm.calibrate([(1e3, 1e-3), (1e3, 2e-3)])
    assert not cm.calibrated


def test_cost_model_prices_engine_stage_keys():
    """Engine stage keys (seg_idx, segment, k) route through the paper's
    cycle model; opaque keys fall back to the quadratic proxy."""
    from repro.configs import DEIT_SMALL
    cfg = DEIT_SMALL.reduced()
    cm = TileCostModel(cfg)
    lay = cm.stage_row_cycles((1, ("layers", 0, 2), None), 16)
    one = cm.stage_row_cycles((1, ("layers", 0, 1), None), 16)
    assert lay == pytest.approx(2 * one)
    assert cm.stage_row_cycles((2, ("tdm", 1), 5), 16) > 0
    assert cm.stage_row_cycles((0, ("embed",), None), 16) > 0
    assert cm.stage_row_cycles((4, ("head",), None), 16) > 0
    assert cm.stage_row_cycles("opaque", 10) == 10 * 10 + 8 * 10


# -- plan object -----------------------------------------------------------
def test_execution_plan_hashable_and_deterministic():
    specs = [("s", 8, _traj((("s", 8), ("t", 9)))), ("s", 9), ("u", 3)]
    p1, p2 = _planner(mode="full"), _planner(mode="full")
    a, b = p1.plan(_items(*specs)), p2.plan(_items(*specs))
    assert a == b
    assert hash(a) == hash(b)
    assert isinstance(a, ExecutionPlan)
    assert {a, b} == {a}


def test_plan_item_validates_trajectory_head():
    with pytest.raises(ValueError, match="restate"):
        PlanItem(stage="s", n_tokens=4, trajectory=(("s", 5),))


def test_planner_validation():
    b = RaggedBatcher(token_tile=1, max_batch=4)
    with pytest.raises(ValueError, match="mode"):
        TilePlanner(b, mode="aggressive")
    naive = RaggedBatcher(mode="naive", max_batch=4)
    with pytest.raises(ValueError, match="balanced"):
        TilePlanner(naive, mode="full")
    TilePlanner(naive, mode="off")  # identity over naive is fine
    with pytest.raises(ValueError, match="fuse_min_segments"):
        TilePlanner(b, fuse_min_segments=0)
    assert PLANNER_MODES == ("off", "merge", "fuse", "full")


def test_cumulative_stats_and_trajectory_ledger():
    p = _planner(mode="full", overhead=1e9)
    t0 = _traj(((("L0",), 8), (("H",), 9)))
    for _ in range(3):  # same population re-planned: ledger must not grow
        p.plan(_items((("L0",), 8, t0), (("L1",), 4), (("L1",), 5)))
    st = p.stats()
    assert st["plans"] == 3
    assert st["lanes"] == 3 and st["trajectory_count"] == 1
    assert st["merges"] == 3  # one merge per plan
    assert st["lane_cells"] == 3 * (8 + 9)
    assert st["modeled_saving_cycles"] > 0
    assert st["modeled_saving_ms"] > 0
    assert not st["calibrated"]
