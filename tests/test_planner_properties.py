"""Property-based TilePlanner invariants (hypothesis): in every mode, an
ExecutionPlan covers each request exactly once across tiles ∪ lanes; mode
``off`` is tile-for-tile the RaggedBatcher identity plan; merged tiles
respect caps and batch bounds; and the recompile ledger (bucket ∪
trajectory keys) is bounded by the distinct shapes actually planned."""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.planner import (PlanItem, TileCostModel,  # noqa: E402
                                   TilePlanner)
from repro.serving.ragged_batcher import RaggedBatcher  # noqa: E402

_fast = settings(max_examples=50, deadline=None)

# Trajectory-shaped populations: each item walks a shared 4-stage pipeline
# (stage identity = (step index, label)) shedding tokens, mirroring how the
# engine's trajectories align offsets with steps.
_item = st.tuples(
    st.integers(0, 3),             # current step in the pipeline
    st.integers(1, 64),            # current token count
    st.sampled_from(["a", "b"]),   # per-item pipeline flavour
    st.floats(0.25, 1.0),          # per-step keep fraction
)


def _build_items(raw, n_steps=4):
    items = []
    for step, n, flavour, keep in raw:
        traj = []
        cur = n
        for s in range(step, n_steps):
            traj.append(((s, flavour), cur))
            cur = max(1, int(cur * keep))
        items.append(PlanItem(stage=traj[0][0], n_tokens=traj[0][1],
                              trajectory=tuple(traj)))
    return items


@_fast
@given(raw=st.lists(_item, min_size=1, max_size=16),
       mode=st.sampled_from(["off", "merge", "fuse", "full"]),
       overhead=st.sampled_from([0.0, 1e3, 1e9]),
       max_batch=st.integers(1, 8),
       deadline=st.sampled_from([None, -1.0, 1e12]))
def test_plan_covers_each_item_exactly_once(raw, mode, overhead, max_batch,
                                            deadline):
    """The zero-drop guarantee under merging, fusion, and deadline splits:
    tiles ∪ lanes partition the population for ANY item stream."""
    items = _build_items(raw)
    if deadline is not None:
        items = [PlanItem(stage=i.stage, n_tokens=i.n_tokens,
                          trajectory=i.trajectory,
                          deadline_left_ms=deadline) for i in items]
    b = RaggedBatcher(token_tile=1, max_batch=max_batch)
    p = TilePlanner(b, TileCostModel(dispatch_overhead_cycles=overhead),
                    mode=mode)
    plan = p.plan(items)
    assert plan.covered_members() == list(range(len(items)))
    # per-tile sanity: members' real counts ride along, padding bounded
    for t in plan.tiles:
        assert t.n_tokens == tuple(items[m].n_tokens for m in t.members)
        assert all(n <= t.n_tile for n in t.n_tokens)
        assert len(t.members) <= t.b_tile
        if b.max_batch:
            assert len(t.members) <= b.max_batch
    for lane in plan.lanes:
        assert lane.trajectory == items[lane.member].trajectory


@_fast
@given(raw=st.lists(_item, min_size=1, max_size=16),
       tile=st.sampled_from([1, 4]), max_batch=st.integers(1, 8))
def test_off_mode_is_identity_for_any_population(raw, tile, max_batch):
    """Mode 'off' == RaggedBatcher.plan, tile-for-tile (the preserved PR-4
    bit-exact balanced path), for arbitrary populations."""
    items = _build_items(raw)
    specs = [(i.stage, i.n_tokens) for i in items]
    ref = RaggedBatcher(token_tile=tile, max_batch=max_batch).plan(specs)
    p = TilePlanner(RaggedBatcher(token_tile=tile, max_batch=max_batch),
                    TileCostModel(), mode="off")
    plan = p.plan(items)
    assert list(plan.tiles) == ref and plan.lanes == ()


@_fast
@given(raw=st.lists(_item, min_size=1, max_size=12),
       rounds=st.integers(1, 4),
       mode=st.sampled_from(["merge", "fuse", "full"]))
def test_recompile_ledger_bounded_by_bucket_union_trajectory(raw, rounds,
                                                             mode):
    """Replanning identical populations must not grow the ledger: the
    distinct compile identities are exactly the bucket keys of dispatched
    tiles plus the trajectory keys of dispatched lanes."""
    items = _build_items(raw)
    b = RaggedBatcher(token_tile=1, max_batch=8)
    p = TilePlanner(b, TileCostModel(), mode=mode)
    plans = [p.plan(items) for _ in range(rounds)]
    tile_keys = {t.bucket_key for pl in plans for t in pl.tiles}
    traj_keys = {l.traj_key for pl in plans for l in pl.lanes}
    assert b.bucket_keys == tile_keys
    assert p.trajectory_keys == traj_keys
    assert p.trajectory_count == len(traj_keys)
    # determinism: same items + same planner state -> identical plans
    assert all(pl == plans[0] for pl in plans[1:])
