"""Property-based tests (hypothesis) on the system's invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import block_pruning as bp
from repro.core import packing
from repro.core import token_pruning as tp
from repro.core.schedule import cubic_keep_rate
from repro.dist.elastic import MeshPlan, replan

_fast = settings(max_examples=25, deadline=None)


@_fast
@given(m=st.integers(1, 8), n=st.integers(1, 8),
       rb=st.floats(0.05, 1.0), seed=st.integers(0, 2**16))
def test_mask_count_invariant(m, n, rb, seed):
    """top-k mask always keeps exactly ceil(m·n·rb) blocks (>=1)."""
    s = np.random.default_rng(seed).standard_normal((m, n)).astype(np.float32)
    keep = max(1, math.ceil(m * n * rb))
    mask = bp.ste_topk_mask(jnp.asarray(s), keep)
    assert int(mask.sum()) == min(keep, m * n)


@_fast
@given(n=st.integers(3, 64), rt=st.floats(0.05, 1.0))
def test_tdm_token_count_formula(n, rt):
    k = tp.num_kept_tokens(n, rt)
    assert 3 <= k <= n + 2  # cls + >=1 kept + fused
    assert k == 1 + max(1, math.ceil((n - 1) * rt)) + 1


@_fast
@given(rows=st.integers(1, 6), cols=st.integers(1, 6),
       density=st.floats(0.1, 1.0), seed=st.integers(0, 2**16),
       b=st.sampled_from([8, 16]))
def test_packing_roundtrip(rows, cols, density, seed, b):
    """pack→to_dense == mask⊙w for arbitrary masks (the packing oracle)."""
    g = np.random.default_rng(seed)
    w = g.standard_normal((rows * b, cols * b)).astype(np.float32)
    mask = (g.random((rows, cols)) < density).astype(np.float32)
    pk = packing.pack_weight(w, mask, b)
    dense = np.asarray(pk.to_dense())
    expected = w * np.kron(mask, np.ones((b, b), np.float32))
    np.testing.assert_allclose(dense, expected, atol=0)


@_fast
@given(counts=st.lists(st.integers(0, 50), min_size=1, max_size=64),
       lanes=st.integers(1, 8))
def test_balance_columns_is_lpt(counts, lanes):
    """The permutation is a valid permutation and the round-robin lane loads
    satisfy the LPT bound vs the perfectly balanced load."""
    c = np.asarray(counts)
    perm = packing.balance_columns(c, lanes)
    assert sorted(perm.tolist()) == list(range(len(c)))
    loads = packing.lane_loads(c, perm, lanes)
    ideal = c.sum() / lanes
    if c.sum() > 0:
        assert loads.max() <= ideal + c.max()


@_fast
@given(step=st.integers(0, 1000), total=st.integers(10, 1000),
       final=st.floats(0.1, 0.95))
def test_cubic_schedule_bounds(step, total, final):
    r = float(cubic_keep_rate(step, total, final, warmup_steps=total // 10,
                              cooldown_steps=total // 10))
    assert final - 1e-6 <= r <= 1.0 + 1e-6
    # monotone non-increasing over time
    r2 = float(cubic_keep_rate(min(step + 10, total), total, final,
                               warmup_steps=total // 10,
                               cooldown_steps=total // 10))
    assert r2 <= r + 1e-6


@_fast
@given(devices=st.integers(1, 1024))
def test_elastic_replan_valid(devices):
    plan = replan(devices, MeshPlan((16, 16), ("data", "model")))
    assert plan.num_devices <= devices
    assert all(s >= 1 for s in plan.shape)
    # model axis never grows beyond the original
    if "model" in plan.axes:
        assert plan.shape[plan.axes.index("model")] <= 16


@_fast
@given(b=st.integers(1, 4), n=st.integers(4, 32), keep=st.integers(1, 8),
       seed=st.integers(0, 2**16))
def test_kv_keep_sorted_and_unique(b, n, keep, seed):
    keep = min(keep, n)
    mass = jnp.asarray(
        np.random.default_rng(seed).random((b, n)).astype(np.float32))
    idx = np.asarray(tp.select_kv_keep(mass, keep))
    for row in idx:
        assert len(set(row.tolist())) == keep
        assert (np.diff(row) > 0).all()


@_fast
@given(seed=st.integers(0, 2**16), m=st.integers(2, 5), n=st.integers(2, 5))
def test_ste_grad_shape_matches_scores(seed, m, n):
    s = jnp.asarray(
        np.random.default_rng(seed).standard_normal((m, n)).astype(np.float32))
    g = jax.grad(lambda s: bp.ste_topk_mask(s, (m * n) // 2 + 1).sum())(s)
    assert g.shape == s.shape
    assert bool(jnp.isfinite(g).all())
