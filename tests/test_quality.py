"""QualityController: keep rates as a runtime load-control surface.

Two halves. Unit: the controller's grid algebra — resolution is pure,
tightening moves down the quantized grid only, never loosens, never
crosses the floor, and a ``strict`` controller is an exact identity.
Integration: through the ``VisionEngine`` — controller-off is bit-exact
with the fixed-rate path across planner modes and pipeline depths,
``degrade`` serves exactly the floor schedule, preferences override the
engine mode, and recompiles stay inside the grid-bounded budget.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import DEIT_SMALL
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import (QUALITY_MODES, QualityConfig, QualityController,
                           VisionEngine, VisionEngineConfig, VisionRequest)


# ---------------------------------------------------------------------------
# unit: config + grid algebra
# ---------------------------------------------------------------------------
def test_quality_config_validation():
    with pytest.raises(ValueError, match="mode"):
        QualityConfig(mode="fastest")
    with pytest.raises(ValueError, match="descending"):
        QualityConfig(keep_levels=(0.4, 0.7, 1.0))
    with pytest.raises(ValueError, match="descending"):
        QualityConfig(keep_levels=(1.0, 0.7, 0.7))
    with pytest.raises(ValueError, match="non-empty"):
        QualityConfig(keep_levels=())
    with pytest.raises(ValueError, match="finite"):
        QualityConfig(keep_levels=(1.0, float("nan")))
    with pytest.raises(ValueError, match="keep_floor"):
        QualityConfig(keep_floor=float("nan"))
    with pytest.raises(ValueError, match="no usable grid"):
        QualityConfig(keep_levels=(0.5, 0.4), keep_floor=0.9)
    with pytest.raises(ValueError, match="backlog_per_level"):
        QualityConfig(backlog_per_level=0)
    cfgq = QualityConfig(keep_levels=(1.0, 0.7, 0.4, 0.2), keep_floor=0.4)
    assert cfgq.usable_levels == (1.0, 0.7, 0.4)


def test_tighten_moves_down_grid_only():
    q = QualityController(QualityConfig(
        mode="auto", keep_levels=(1.0, 0.8, 0.6, 0.4), keep_floor=0.4))
    assert q.tighten(0.9, 0) == 0.9          # no pressure: untouched
    assert q.tighten(0.9, 1) == 0.8
    assert q.tighten(0.9, 2) == 0.6
    assert q.tighten(0.9, 99) == 0.4         # clamps at the floor level
    assert q.tighten(0.8, 1) == 0.6          # strictly below, not equal
    assert q.tighten(0.3, 99) == 0.3         # below every level: NEVER
    assert q.tighten(0.4, 99) == 0.4         # loosened or touched


def test_pressure_steps_scales_with_slots():
    q = QualityController(QualityConfig(mode="auto", backlog_per_level=2),
                          num_slots=4)
    assert q.pressure_steps(0) == 0
    assert q.pressure_steps(7) == 0          # less than one backlog unit
    assert q.pressure_steps(8) == 1
    assert q.pressure_steps(17) == 2
    assert q.pressure_steps(-3) == 0


def test_resolve_strict_controller_is_identity():
    """Controller off: every schedule untouched — even for requests that
    ASK for degradation (bit-exactness with the pre-controller engine
    cannot depend on request payloads)."""
    q = QualityController()  # default strict
    assert not q.enabled
    base = (0.9, 0.5)
    assert q.resolve(base, queue_depth=10 ** 6) == base
    assert q.resolve(base, preference="degrade", queue_depth=10 ** 6) == base


def test_resolve_degrade_and_done_prefix():
    q = QualityController(QualityConfig(
        mode="degrade", keep_levels=(1.0, 0.7, 0.5), keep_floor=0.5))
    assert q.resolve((0.9, 0.9)) == (0.5, 0.5)
    # executed entries are history: never rewritten
    assert q.resolve((0.9, 0.9), done=1) == (0.9, 0.5)
    # per-request strict preference pins the base schedule under load
    assert q.resolve((0.9, 0.9), preference="strict") == (0.9, 0.9)
    with pytest.raises(ValueError, match="preference"):
        q.resolve((0.9,), preference="turbo")


def test_resolve_auto_queue_and_deadline_pressure():
    q = QualityController(QualityConfig(
        mode="auto", keep_levels=(1.0, 0.8, 0.6, 0.4), keep_floor=0.4),
        num_slots=2)
    base = (0.9,)
    assert q.resolve(base, queue_depth=0) == base
    assert q.resolve(base, queue_depth=2) == (0.8,)
    assert q.resolve(base, queue_depth=4) == (0.6,)
    # deadline loop: keep tightening until the modeled remainder fits
    cost = {(0.9,): 10.0, (0.8,): 8.0, (0.6,): 5.0, (0.4,): 2.0}
    out = q.resolve(base, queue_depth=0, deadline_left_ms=4.0,
                    remaining_ms=lambda s: cost[s])
    assert out == (0.4,)
    out = q.resolve(base, queue_depth=0, deadline_left_ms=6.0,
                    remaining_ms=lambda s: cost[s])
    assert out == (0.6,)
    # slack already fits: queue pressure alone decides
    assert q.resolve(base, queue_depth=0, deadline_left_ms=100.0,
                     remaining_ms=lambda s: cost[s]) == base


def test_record_and_stats_accounting():
    q = QualityController(QualityConfig(mode="auto"))
    q.record(3, 2, levels=(0.7, 0.55), deadline_tightened=1)
    q.record(1, 0)
    st = q.stats()
    assert st["decisions"] == 4 and st["tightened"] == 2
    assert st["deadline_tightened"] == 1
    assert st["levels_used"] == (0.55, 0.7)
    assert tuple(QUALITY_MODES) == ("strict", "auto", "degrade")


# ---------------------------------------------------------------------------
# integration: through the VisionEngine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def packed_vit(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)
    return cfg, masked, packed


def _reqs(cfg, n_list, **kw):
    rng = np.random.default_rng(5)
    pdim = cfg.patch_size ** 2 * 3
    return [VisionRequest(
        uid=i, patches=rng.standard_normal((n, pdim)).astype(np.float32),
        **kw) for i, n in enumerate(n_list)]


def _offline(cfg, masked, packed, req, schedule=None, soft=False):
    c = cfg if req.r_t is None else cfg.replace(
        pruning=dataclasses.replace(cfg.pruning, r_t=req.r_t))
    return np.asarray(PR.forward_vit_packed(
        c, masked, packed, req.patches[None], schedule=schedule,
        soft=soft).logits[0])


def _digest(out):
    import hashlib
    h = hashlib.sha256()
    for uid in sorted(out):
        h.update(np.asarray(out[uid], np.float32).tobytes())
    return h.hexdigest()


@pytest.mark.parametrize("pmode", ["off", "merge", "fuse", "full"])
def test_controller_off_bitexact_every_planner_and_depth(packed_vit, pmode):
    """The tentpole's hard constraint: an engine with the (default,
    strict) controller serves byte-identical logits to the offline
    fixed-rate path at pipeline depths 1 and 2 — quality plumbing must be
    invisible until enabled."""
    cfg, masked, packed = packed_vit
    digests = set()
    for depth in (1, 2):
        reqs = _reqs(cfg, [16, 9, 16])
        reqs[1].r_t = 0.5
        vc = VisionEngineConfig(max_batch=2, planner=pmode,
                                pipeline_depth=depth)
        eng = VisionEngine(cfg, masked, packed, vc)
        out = eng.serve(reqs)
        for r in reqs:
            assert np.array_equal(out[r.uid],
                                  _offline(cfg, masked, packed, r))
        digests.add(_digest(out))
        st = eng.stats()
        assert st["quality_mode"] == "strict"
        assert st["quality_tightened"] == 0
        assert st["quality_levels_used"] == ()
    assert len(digests) == 1  # depth cannot change the bits


def test_degrade_serves_exactly_the_floor_schedule(packed_vit):
    """Shed-load mode pins every consenting request to the lowest usable
    grid level — bit-exact against the offline path run at precisely that
    schedule (the controller changes WHICH schedule runs, never the
    math)."""
    cfg, masked, packed = packed_vit
    vc = VisionEngineConfig(max_batch=2, planner="full",
                            quality="degrade",
                            keep_levels=(1.0, 0.7, 0.5), keep_floor=0.5)
    eng = VisionEngine(cfg, masked, packed, vc)
    reqs = _reqs(cfg, [16, 9])
    reqs[0].r_t = 0.9
    reqs[1].quality = "strict"  # opts out: pinned to its base schedule
    out = eng.serve(reqs)
    assert np.array_equal(out[0], _offline(cfg, masked, packed, reqs[0],
                                           schedule=(0.5,)))
    assert np.array_equal(out[1], _offline(cfg, masked, packed, reqs[1]))
    st = eng.stats()
    assert st["quality_levels_used"] == (0.5,)
    assert st["jit_compile_count"] <= st["compile_budget"]


def test_auto_tightens_only_under_backlog(packed_vit):
    """Auto mode is a no-op on an unloaded engine and tightens (onto grid
    levels only) when the queue outgrows the slots."""
    cfg, masked, packed = packed_vit
    grid = (1.0, 0.85, 0.7, 0.55)
    # unloaded: 2 requests into 2 slots -> no pressure -> base schedules
    vc = VisionEngineConfig(max_batch=2, quality="auto", keep_levels=grid,
                            keep_floor=0.55)
    eng = VisionEngine(cfg, masked, packed, vc)
    reqs = _reqs(cfg, [16, 9])
    out = eng.serve(reqs)
    for r in reqs:
        assert np.array_equal(out[r.uid], _offline(cfg, masked, packed, r))
    assert eng.stats()["quality_tightened"] == 0
    # backlogged: one slot, simultaneous arrivals -> pressure tightens,
    # resolved rates come from the grid only
    eng2 = VisionEngine(cfg, masked, packed, VisionEngineConfig(
        max_batch=1, quality="auto", keep_levels=grid, keep_floor=0.55))
    out2 = eng2.serve(_reqs(cfg, [16, 16, 16, 16, 16, 16]))
    st = eng2.stats()
    assert len(out2) == 6
    assert st["quality_tightened"] > 0
    assert set(st["quality_levels_used"]) <= set(grid)
    assert st["jit_compile_count"] <= st["compile_budget"]


def test_explicit_keep_schedule_request(packed_vit):
    """A request carrying its own per-step schedule is served under it
    verbatim (controller off), bit-exact vs the offline schedule path."""
    cfg, masked, packed = packed_vit
    eng = VisionEngine(cfg, masked, packed, VisionEngineConfig(max_batch=2))
    reqs = _reqs(cfg, [16])
    reqs[0].keep_schedule = (0.6,)
    out = eng.serve(reqs)
    assert np.array_equal(out[0], _offline(cfg, masked, packed, reqs[0],
                                           schedule=(0.6,)))
