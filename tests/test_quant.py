"""Quantized serving path (repro.core.quant + kernels.sbmm.quant +
precision-threaded runner/planner/engine).

Layers of defense, mirroring the fp32 stack's test structure:
  * format roundtrip — symmetric quantize→dequantize error bounded by
    scale/2 per element (deterministic sweep here; the hypothesis
    properties live in TestQuantProperties below, skipped without the
    optional 'test' extra);
  * kernel vs oracle — the dequant-in-kernel Pallas SBMM bit-matches the
    accumulation-order-matched jnp reference in interpret mode, and the
    fp16 attention variant matches the jnp oracle on the same fp16-cast
    operands;
  * runner — forward_vit_packed(precision=...) chains the quantized
    kernels across TDM steps; the engine (tiles AND express lanes) is
    bit-exact against it per request;
  * planner — precision decisions deterministic, fp32 ties win, pricing
    strictly ordered int8 < fp16 < fp32 on encoder segments;
  * accounting — nbytes/packed_model_size_bytes derive from actual dtypes
    and include scales.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DEIT_SMALL
from repro.core import block_pruning as bp
from repro.core import packed_runner as PR
from repro.core import packing
from repro.core import quant as Q
from repro.core.perf_model import (PRECISION_SPEEDUP, precision_speedup,
                                   vit_segment_cycles)
from repro.kernels.flash_attention import flash_attention_fp16
from repro.kernels.sbmm import (sbmm, sbmm_quant_pallas, sbmm_quant_ref,
                                sbmm_quant_raw)
from repro.models import attention as A
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving.planner import TileCostModel, TilePlanner
from repro.serving.ragged_batcher import RaggedBatcher
from repro.serving.vision import (VisionEngine, VisionEngineConfig,
                                  VisionRequest)


def _packed(key, K=64, N=96, b=16, keep=12, dtype=np.float32):
    w = np.asarray(jax.random.normal(key, (K, N)), dtype)
    sc = np.asarray(jax.random.normal(key, bp.score_shape((K, N), b)))
    mask = np.asarray(bp._hard_topk(jnp.asarray(sc), keep))
    return packing.pack_weight(w, mask, b)


# ---------------------------------------------------------------------------
# Quantization format: roundtrip bounds, pytree, dtype handling
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,granularity", [
    (16, "block"), (16, "channel"), (32, "block"), (32, "channel"),
])
def test_int8_roundtrip_error_bound(b, granularity):
    """|w - dequant(quant(w))| <= scale/2 elementwise — the symmetric
    quantizer's defining bound, at both scale granularities."""
    key = jax.random.PRNGKey(b)
    pw = _packed(key, K=4 * b, N=6 * b, b=b, keep=9)
    qpw = Q.quantize_packed(pw, "int8", granularity)
    assert qpw.granularity == granularity
    assert qpw.blocks.dtype == jnp.int8
    want_ndim = 2 if granularity == "block" else 3
    assert qpw.scales.ndim == want_ndim
    w = np.asarray(pw.blocks, np.float32)
    wq = np.asarray(Q.dequantize_packed(qpw).blocks, np.float32)
    bound = np.asarray(Q._expand_scales(np.asarray(qpw.scales)),
                       np.float32) / 2.0
    assert np.all(np.abs(w - wq) <= np.broadcast_to(bound, w.shape) + 1e-7)
    assert Q.quantization_error(pw, qpw) <= float(bound.max()) + 1e-7


def test_channel_scales_never_looser_than_block():
    """Per-output-channel scales refine per-block scales, so the roundtrip
    error cannot get worse (it's the serving default for a reason)."""
    pw = _packed(jax.random.PRNGKey(3))
    e_block = Q.quantization_error(pw, Q.quantize_packed(pw, "int8", "block"))
    e_chan = Q.quantization_error(pw,
                                  Q.quantize_packed(pw, "int8", "channel"))
    assert e_chan <= e_block + 1e-7


def test_quantize_fp32_identity_fp16_halves():
    pw = _packed(jax.random.PRNGKey(1))
    assert Q.quantize_packed(pw, "fp32") is pw
    h = Q.quantize_packed(pw, "fp16")
    assert isinstance(h, packing.PackedWeight)
    assert h.blocks.dtype == jnp.float16
    # fp16 roundtrip: plain cast, error bounded by half-precision ulp
    w = np.asarray(pw.blocks, np.float32)
    wh = np.asarray(h.blocks, np.float32)
    assert np.abs(w - wh).max() <= np.abs(w).max() * 2 ** -10
    with pytest.raises(ValueError):
        Q.quantize_packed(pw, "int4")
    with pytest.raises(ValueError):
        Q.quantize_packed(pw, "int8", "tensor")


def test_all_zero_block_roundtrips_exactly():
    """The scale zero-guard: an all-zero kept block must dequantize to
    exactly zero (scale falls back to 1.0, not 0 or NaN)."""
    w = np.zeros((32, 32), np.float32)
    mask = np.ones(bp.score_shape(w.shape, 16), bool)
    pw = packing.pack_weight(w, mask, 16)
    for g in Q.GRANULARITIES:
        qpw = Q.quantize_packed(pw, "int8", g)
        assert np.all(np.isfinite(np.asarray(qpw.scales)))
        assert Q.quantization_error(pw, qpw) == 0.0


def test_quantized_packed_weight_is_pytree():
    pw = _packed(jax.random.PRNGKey(2))
    qpw = Q.quantize_packed(pw, "int8", "channel")
    leaves, treedef = jax.tree_util.tree_flatten(qpw)
    assert len(leaves) == 4  # blocks, scales, header, counts
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.granularity == "channel"
    assert rebuilt.shape == qpw.shape
    np.testing.assert_array_equal(np.asarray(rebuilt.blocks),
                                  np.asarray(qpw.blocks))
    # hashable aux data -> usable as a jit operand
    hash(treedef)


# ---------------------------------------------------------------------------
# Size accounting (satellite: dtype-derived, scales included)
# ---------------------------------------------------------------------------
def test_nbytes_derives_from_dtypes():
    pw = _packed(jax.random.PRNGKey(4), b=16, keep=12)
    kept = int(np.asarray(pw.counts).sum())
    assert pw.nbytes() == kept * 16 * 16 * 4 + kept * 4  # f32 blocks, i32 hdr
    h = Q.quantize_packed(pw, "fp16")
    assert h.nbytes() == kept * 16 * 16 * 2 + kept * 4
    q_b = Q.quantize_packed(pw, "int8", "block")
    assert q_b.nbytes() == kept * 16 * 16 * 1 + kept * 4 + kept * 1 * 4
    q_c = Q.quantize_packed(pw, "int8", "channel")
    assert q_c.nbytes() == kept * 16 * 16 * 1 + kept * 4 + kept * 16 * 4
    assert Q.packed_dict_nbytes({"a": pw, "b": q_c}) == \
        pw.nbytes() + q_c.nbytes()


def test_packed_model_size_bytes_scales_term():
    mw = [((64, 64), None), ((64, 64), np.ones((4, 4), bool))]
    base = packing.packed_model_size_bytes(mw, 16, dtype_bytes=1)
    with_scales = packing.packed_model_size_bytes(
        mw, 16, dtype_bytes=1, scale_bytes=4, scales_per_block=16)
    assert with_scales - base == 16 * 16 * 4  # 16 kept blocks × 16 ch × f32
    # backward-compatible default is the paper's int16 + 4-byte header
    legacy = packing.packed_model_size_bytes(mw, 16)
    assert legacy == 64 * 64 * 2 + 16 * (16 * 16 * 2 + 4)


# ---------------------------------------------------------------------------
# Kernels: Pallas dequant vs jnp oracle (bit-match), fp16 attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N,b,keep", [
    (32, 32, 32, 16, 2),
    (64, 64, 128, 16, 10),
    (100, 96, 80, 16, 14),   # non-multiples: the ops.py padding path
    (48, 64, 64, 32, 3),
])
@pytest.mark.parametrize("granularity", ["block", "channel"])
def test_sbmm_quant_kernel_bit_matches_ref(M, K, N, b, keep, granularity):
    key = jax.random.PRNGKey(hash((M, K, N, b)) % 2 ** 31)
    pw = _packed(key, K=K, N=N, b=b, keep=keep)
    qpw = Q.quantize_packed(pw, "int8", granularity)
    x = jax.random.normal(jax.random.fold_in(key, 2), (M, K), jnp.float32)
    y = sbmm_quant_raw(x, qpw.blocks, qpw.header, qpw.scales, tm=32)
    y_ref = sbmm_quant_ref(x, qpw.blocks, qpw.header, qpw.scales)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_sbmm_quant_pallas_direct_bit_match():
    """Unpadded direct kernel call (M multiple of tm) — the pure kernel
    grid, no ops.py involvement."""
    pw = _packed(jax.random.PRNGKey(11), K=64, N=64, b=16, keep=8)
    qpw = Q.quantize_packed(pw, "int8", "channel")
    x = jax.random.normal(jax.random.PRNGKey(12), (64, 64), jnp.float32)
    y = sbmm_quant_pallas(x, qpw.blocks, qpw.header, qpw.scales, tm=32)
    y_ref = sbmm_quant_ref(x, qpw.blocks, qpw.header, qpw.scales)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_sbmm_dispatches_quantized_and_matches_dense_dequant():
    """The public sbmm() entry point routes QuantizedPackedWeight to the
    dequant kernel and undoes the column permutation: result must match
    x @ dequant(W) computed dense."""
    pw = _packed(jax.random.PRNGKey(5), K=64, N=96, b=16, keep=12)
    qpw = Q.quantize_packed(pw, "int8", "channel")
    x = jax.random.normal(jax.random.PRNGKey(6), (40, 64), jnp.float32)
    y = sbmm(x, qpw, tm=32)
    y_dense = x @ qpw.to_dense()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)


def test_sbmm_quant_empty_column_zero():
    w = np.ones((32, 32), np.float32)
    mask = np.zeros(bp.score_shape(w.shape, 16), bool)
    mask[:, 0] = True  # second block-column fully pruned
    pw = packing.pack_weight(w, mask, 16)
    qpw = Q.quantize_packed(pw, "int8", "block")
    x = jnp.ones((32, 32), jnp.float32)
    y = np.asarray(sbmm(x, qpw, tm=32))
    assert np.all(y[:, 16:] == 0.0)
    assert np.all(y[:, :16] != 0.0)


def test_flash_attention_fp16_matches_jnp_oracle():
    """The cast IS the quantizer: the fp16 kernel variant must match the
    jnp online-softmax oracle evaluated on the SAME fp16-cast operands
    (fp32 softmax/accumulation both sides), output fp32."""
    key = jax.random.PRNGKey(9)
    B, N, H, Dh = 2, 33, 4, 16
    q = jax.random.normal(key, (B, N, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, N, H, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, N, H, Dh))
    out = flash_attention_fp16(q, k, v, causal=False)
    assert out.dtype == jnp.float32
    oracle = A.flash_attention_jnp(q.astype(jnp.float16),
                                   k.astype(jnp.float16),
                                   v.astype(jnp.float16),
                                   causal=False).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=2e-3, rtol=2e-3)
    # and it is a genuinely different rounding than fp32 attention
    full = A.flash_attention_jnp(q, k, v, causal=False)
    assert np.abs(np.asarray(out) - np.asarray(full)).max() > 0.0


# ---------------------------------------------------------------------------
# Runner: precision threads through segments, TDM chains, fused lanes
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_model():
    cfg = DEIT_SMALL.reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)
    return cfg, masked, packed


@pytest.mark.parametrize("precision", ["int8", "fp16"])
def test_forward_vit_packed_quantized_close_to_fp32(small_model, precision):
    """Full forward (TDM chained) at a quantized tier: close to fp32 in
    logits, identical in top-1 at this scale, and actually different
    (the quantized kernels really ran)."""
    cfg, masked, packed = small_model
    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(jax.random.PRNGKey(1),
                                (2, n, cfg.patch_size ** 2 * 3))
    l32 = np.asarray(PR.forward_vit_packed(cfg, masked, packed,
                                           patches).logits)
    lq = np.asarray(PR.forward_vit_packed(cfg, masked, packed, patches,
                                          precision=precision).logits)
    d = np.abs(l32 - lq).max()
    assert 0.0 < d < 0.1
    # top-1 may only flip where fp32 itself was within the quantization
    # perturbation of a tie (random-init logits are near-uniform; the
    # accuracy gate proper is vision_bench's precision_compare arm)
    for row32, rowq in zip(l32, lq):
        if row32.argmax() != rowq.argmax():
            top2 = np.sort(row32)[-2:]
            assert top2[1] - top2[0] <= 2.0 * d


def test_segments_runner_precision_ledger(small_model):
    """fp32 ledger keys unchanged; quantized runs append a marker; embed
    and head tiles are shared across precisions (no marker, no re-entry)."""
    cfg, masked, packed = small_model
    seg = PR.PackedVitSegments(cfg, masked, packed, use_tdm=False)
    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = jax.random.normal(jax.random.PRNGKey(2),
                                (1, n, cfg.patch_size ** 2 * 3))
    x = seg.run(("embed",), patches)
    seg.run(("layers", 0, cfg.num_layers), x)
    keys_fp32 = set(seg.compiled_tiles())
    assert all(k[-1] not in Q.PRECISIONS for k in keys_fp32)
    count_fp32 = seg.compile_count
    seg.run(("embed",), patches)  # embed ignores precision entirely
    seg.run(("layers", 0, cfg.num_layers), x, precision="int8")
    assert seg.compile_count == count_fp32 + 1
    new = set(seg.compiled_tiles()) - keys_fp32
    assert len(new) == 1 and next(iter(new))[-1] == "int8"
    with pytest.raises(ValueError):
        seg.run(("layers", 0, cfg.num_layers), x, precision="int4")


def test_run_fused_quantized_matches_segmented(small_model):
    """Express lane at int8: the fused trajectory program must be
    bit-exact against the per-segment quantized path (same pure bodies,
    one XLA program — the fp32 exactness argument carries over)."""
    cfg, masked, packed = small_model
    runner = PR.PackedVitSegments(cfg, masked, packed)
    n = (cfg.image_size // cfg.patch_size) ** 2
    patches = np.asarray(jax.random.normal(
        jax.random.PRNGKey(3), (1, n, cfg.patch_size ** 2 * 3)))
    ref = PR.forward_vit_packed(cfg, masked, packed,
                                jnp.asarray(patches), segments=runner,
                                precision="int8").logits
    steps = []
    ntok = n + 1
    sched = PR.keep_schedule(cfg)
    ti = 0
    for s in runner.plan:
        if s[0] == "tdm":
            k = PR.tdm_keep_count(ntok, sched[ti])
            steps.append((s, k))
            ntok = k + 2
            ti += 1
        else:
            steps.append((s, None))
    fused = runner.run_fused(tuple(steps), patches, precision="int8")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))
    # trajectory ledger keys carry the precision marker
    assert any(t[-1] == "int8" for t in runner._fused_trajectories)


# ---------------------------------------------------------------------------
# Perf model + planner: precision pricing and decisions
# ---------------------------------------------------------------------------
def test_vit_segment_cycles_precision_ordering():
    cfg = DEIT_SMALL.reduced()
    for seg in (("layers", 0, 2), ("tdm", 1)):
        c32 = vit_segment_cycles(cfg, seg, 64)
        c16 = vit_segment_cycles(cfg, seg, 64, precision="fp16")
        c8 = vit_segment_cycles(cfg, seg, 64, precision="int8")
        assert c8 < c16 < c32
        assert c32 / c8 == pytest.approx(PRECISION_SPEEDUP["int8"])
    for seg in (("embed",), ("head",)):  # always fp32: no discount
        assert vit_segment_cycles(cfg, seg, 64) == \
            vit_segment_cycles(cfg, seg, 64, precision="int8")
    with pytest.raises(ValueError):
        precision_speedup("int4")


def test_cost_model_reads_precision_marker():
    cfg = DEIT_SMALL.reduced()
    cm = TileCostModel(cfg)
    seg = ("layers", 0, 2)
    base = cm.stage_row_cycles((1, seg, None), 64)
    assert cm.stage_row_cycles((1, seg, None, "int8"), 64) == \
        pytest.approx(base / 4.0)
    assert cm.stage_row_cycles((1, seg, 7, "soft", "fp16"), 64) == \
        pytest.approx(cm.stage_row_cycles((1, seg, 7, "soft"), 64) / 2.0)
    # opaque proxy scales consistently too
    proxy = TileCostModel(None)
    assert proxy.stage_row_cycles(("op", "x", 0, "int8"), 10) == \
        pytest.approx(proxy.stage_row_cycles("opaque-10", 10) / 4.0)


def _mk_planner(mode="full"):
    return TilePlanner(RaggedBatcher(mode="balanced"), TileCostModel(None),
                       mode=mode)


def test_choose_precision_deterministic_and_strict():
    pl = _mk_planner()
    traj32 = ((("s", 0), 8),)
    traj8 = ((("s", 0, "int8"), 8),)
    # strictly cheaper int8 wins; repeated calls identical
    picks = [pl.choose_precision([("fp32", traj32), ("int8", traj8)],
                                 record=False) for _ in range(5)]
    assert picks == ["int8"] * 5
    # equal-cost tie keeps fp32 (first candidate, strict < required)
    assert pl.choose_precision([("fp32", traj32), ("int8", traj32)],
                               record=False) == "fp32"
    assert pl.precision_decisions == {p: 0 for p in Q.PRECISIONS}
    pl.choose_precision([("fp32", traj32), ("int8", traj8)])
    assert pl.precision_decisions["int8"] == 1
    assert pl.stats()["precision_int8"] == 1
    with pytest.raises(ValueError):
        pl.choose_precision([])


# ---------------------------------------------------------------------------
# Engine: bit-exactness per precision, strict pinning, counters, cache
# ---------------------------------------------------------------------------
def _requests(cfg, n_req=5, strict_uid=None):
    key = jax.random.PRNGKey(42)
    n_max = (cfg.image_size // cfg.patch_size) ** 2
    reqs = []
    for i in range(n_req):
        n = n_max - (i % 3)
        p = np.asarray(jax.random.normal(
            jax.random.fold_in(key, i), (n, cfg.patch_size ** 2 * 3)),
            np.float32)
        reqs.append(VisionRequest(
            uid=i, patches=p, arrival_step=i // 2,
            quality="strict" if i == strict_uid else None))
    return reqs


@pytest.mark.parametrize("precision", ["int8", "fp16"])
def test_engine_quantized_bit_exact_vs_offline_oracle(small_model,
                                                      precision):
    """Every request served by a quantized engine (tiles, merged tiles and
    express lanes mixed by planner=full) is bit-exact against the offline
    single-request forward at the same precision."""
    cfg, masked, packed = small_model
    vc = VisionEngineConfig(max_batch=4, planner="full",
                            precision=precision)
    eng = VisionEngine(cfg, masked, packed, vc=vc)
    reqs = _requests(cfg)
    out = eng.serve(reqs)
    # budget check BEFORE the oracle runs below add their own (unbatched,
    # unpadded) entries to the shared segment jit caches
    s = eng.stats()
    assert s["precision"] == precision
    assert s[f"dispatch_{precision}"] > 0
    assert s["jit_compile_count"] <= s["compile_budget"]
    for r in reqs:
        ref = PR.forward_vit_packed(
            cfg, masked, packed, jnp.asarray(r.patches)[None],
            segments=eng.segments, precision=precision).logits
        np.testing.assert_array_equal(out[r.uid], np.asarray(ref[0]))
    if precision == "int8":
        assert s["dequant_dispatches"] == s["dispatch_int8"]
    else:
        assert s["dequant_dispatches"] == 0


def test_engine_strict_quality_pins_fp32(small_model):
    """quality='strict' requests run fp32 on a quantized engine — their
    logits bit-match the fp32 engine's."""
    cfg, masked, packed = small_model
    reqs32 = _requests(cfg, strict_uid=2)
    out32 = VisionEngine(cfg, masked, packed,
                         vc=VisionEngineConfig(max_batch=4)).serve(reqs32)
    reqs8 = _requests(cfg, strict_uid=2)
    eng8 = VisionEngine(cfg, masked, packed,
                        vc=VisionEngineConfig(max_batch=4, precision="int8"))
    out8 = eng8.serve(reqs8)
    np.testing.assert_array_equal(out32[2], out8[2])
    # the non-strict ones really quantized
    assert any(not np.array_equal(out32[u], out8[u]) for u in out32
               if u != 2)
    assert eng8.planner.precision_decisions["int8"] > 0


def test_engine_fp32_path_untouched_by_quant_plumbing(small_model):
    """An fp32 engine never builds quantized dicts, never marks a stage
    key, and records zero precision decisions — the pre-PR fp32 surface."""
    cfg, masked, packed = small_model
    eng = VisionEngine(cfg, masked, packed,
                       vc=VisionEngineConfig(max_batch=4, planner="full"))
    eng.serve(_requests(cfg))
    assert set(eng.segments._packed_by) == {"fp32"}
    assert all(k[-1] not in Q.PRECISIONS
               for k in eng.segments.compiled_tiles())
    assert eng.planner.precision_decisions == {p: 0 for p in Q.PRECISIONS}
    s = eng.stats()
    assert s["dispatch_fp32"] > 0 and s["dequant_dispatches"] == 0
    rep = eng.quantization_report()
    assert rep["quant_max_abs_error"] == 0.0
    assert rep["packed_bytes"] == rep["packed_bytes_fp32"]


def test_items_fingerprint_precision_aware():
    """Plan-cache stability: stage-key precision markers flow into the
    population fingerprint, so an int8 population never reuses an fp32
    speculative plan (and vice versa)."""
    from repro.serving.planner import PlanItem
    a = PlanItem(stage=(0, ("layers", 0, 2), None), n_tokens=8,
                 trajectory=(((0, ("layers", 0, 2), None), 8),))
    b = PlanItem(stage=(0, ("layers", 0, 2), None, "int8"), n_tokens=8,
                 trajectory=(((0, ("layers", 0, 2), None, "int8"), 8),))
    fa = VisionEngine._items_fingerprint([a])
    fb = VisionEngine._items_fingerprint([b])
    assert fa is not None and fb is not None and fa != fb


def test_engine_quantization_report(small_model):
    cfg, masked, packed = small_model
    eng = VisionEngine(cfg, masked, packed,
                       vc=VisionEngineConfig(max_batch=2, precision="int8"))
    rep = eng.quantization_report()
    assert rep["precision"] == "int8"
    assert rep["granularity"] == "channel"
    assert 0.0 < rep["quant_max_abs_error"] < 0.1
    assert rep["packed_bytes"] < rep["packed_bytes_fp32"]
    # metrics export carries the counters as gauges
    from repro.obs.metrics import MetricsRegistry
    eng.serve(_requests(cfg, n_req=2))
    snap = eng.export_metrics(MetricsRegistry()).snapshot()
    for name in ("vision.dequant_dispatches", "vision.dispatch_int8",
                 "vision.plan_precision_int8"):
        assert snap[name]["type"] == "gauge"
    assert snap["vision.dispatch_int8"]["value"] > 0


def test_engine_config_validation():
    with pytest.raises(ValueError):
        VisionEngineConfig(precision="int4")
    with pytest.raises(ValueError):
        VisionEngineConfig(quant_granularity="tensor")
