"""Property tests for the quantized packing format and dequant kernels.

Requires ``hypothesis`` (the optional 'test' extra); the deterministic
fallbacks for the same invariants live in tests/test_quant.py.
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'test' extra")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing
from repro.core import quant as Q
from repro.kernels.sbmm import sbmm_quant_raw, sbmm_quant_ref

_fast = settings(max_examples=20, deadline=None)


def _pack(rng, b, keep, n_rows=3, n_cols=4, amp=1.0):
    w = (rng.standard_normal((n_rows * b, n_cols * b)) * amp
         ).astype(np.float32)
    mask = np.zeros((n_rows, n_cols), bool)
    total = n_rows * n_cols
    flat = rng.choice(total, size=min(keep, total), replace=False)
    mask[flat // n_cols, flat % n_cols] = True
    return packing.pack_weight(w, mask, b)


@_fast
@given(b=st.sampled_from([8, 16, 32]),
       granularity=st.sampled_from(Q.GRANULARITIES),
       keep=st.integers(1, 12), seed=st.integers(0, 2 ** 16),
       scale_pow=st.integers(-6, 6))
def test_roundtrip_error_within_half_scale(b, granularity, keep, seed,
                                           scale_pow):
    """|w - dequant(quant(w))| <= scale/2 per element, across block sizes,
    scale granularities, keep counts and weight magnitudes 2^-6..2^6 (the
    scale must adapt, not clip), and the int8 payload stays in [-127, 127]
    (symmetric: -128 never emitted)."""
    rng = np.random.default_rng(seed)
    pw = _pack(rng, b, keep, amp=2.0 ** scale_pow)
    qpw = Q.quantize_packed(pw, "int8", granularity)
    err = np.abs(np.asarray(pw.blocks, np.float32)
                 - np.asarray(Q.dequantize_packed(qpw).blocks, np.float32))
    bound = np.asarray(Q._expand_scales(np.asarray(qpw.scales)),
                       np.float32) / 2.0
    assert np.all(err <= np.broadcast_to(bound, err.shape)
                  * (1 + 1e-6) + 1e-12)
    assert np.all(np.abs(np.asarray(qpw.blocks, np.int64)) <= 127)


@_fast
@given(granularity=st.sampled_from(Q.GRANULARITIES),
       keep=st.integers(1, 12), seed=st.integers(0, 2 ** 16))
def test_channel_granularity_refines_block(granularity, keep, seed):
    """Channel scales partition each block's columns, so the max-abs
    roundtrip error can only shrink relative to one scale per block."""
    rng = np.random.default_rng(seed)
    pw = _pack(rng, 16, keep)
    e_block = Q.quantization_error(pw, Q.quantize_packed(pw, "int8",
                                                         "block"))
    e_chan = Q.quantization_error(pw, Q.quantize_packed(pw, "int8",
                                                        "channel"))
    assert e_chan <= e_block + 1e-7


@_fast
@given(m=st.integers(1, 40), keep=st.integers(1, 6),
       seed=st.integers(0, 2 ** 16),
       granularity=st.sampled_from(Q.GRANULARITIES))
def test_quant_kernel_bit_matches_ref(m, keep, seed, granularity):
    """Interpret-mode dequant SBMM kernel == accumulation-order-matched
    jnp reference, bitwise, at arbitrary row counts (exercises the ops.py
    pad-to-tile path whenever m % tm != 0)."""
    rng = np.random.default_rng(seed)
    pw = _pack(rng, 16, keep, n_rows=2, n_cols=3)
    qpw = Q.quantize_packed(pw, "int8", granularity)
    x = jnp.asarray(rng.standard_normal((m, 32)), jnp.float32)
    y = sbmm_quant_raw(x, qpw.blocks, qpw.header, qpw.scales, tm=16)
    y_ref = sbmm_quant_ref(x, qpw.blocks, qpw.header, qpw.scales)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
