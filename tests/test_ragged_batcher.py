"""RaggedBatcher invariants: every plan is a partition (zero dropped
requests) with bounded padding waste, under arbitrary per-stage keep-count
populations — the property the vision engine's correctness rests on."""
import pytest

from repro.serving.cache_manager import bucket_length
from repro.serving.ragged_batcher import RaggedBatcher


def _check_partition(items, tiles):
    """Every item index appears in exactly one tile."""
    seen = [i for t in tiles for i in t.members]
    assert sorted(seen) == list(range(len(items)))
    for t in tiles:
        assert t.n_tokens == tuple(items[i][1] for i in t.members)


def _check_balanced_bounds(batcher, tiles):
    for t in tiles:
        for n in t.n_tokens:
            assert 0 <= t.n_tile - n < batcher.token_tile  # bounded pad
        assert len(t.members) <= t.b_tile
        assert t.b_tile == bucket_length(
            len(t.members), cap=batcher.max_batch or len(t.members), lo=1)
        if batcher.max_batch is not None:
            assert t.b_tile <= batcher.max_batch


def test_exact_buckets_have_zero_padding():
    b = RaggedBatcher(token_tile=1, max_batch=4)
    items = [("s0", 17), ("s0", 17), ("s0", 10), ("s1", 17), ("s0", 5)]
    tiles = b.plan(items)
    _check_partition(items, tiles)
    for t in tiles:
        assert not t.needs_mask
        assert t.n_tile == t.n_tokens[0]
    # (s0,17) pair -> one 2-row tile; singles -> b_tile 1
    by_key = {(t.stage, t.n_tile): t for t in tiles}
    assert by_key[("s0", 17)].b_tile == 2
    assert b.padding_waste() == 0.0


def test_token_tile_quantizes_and_masks():
    b = RaggedBatcher(token_tile=8, max_batch=4)
    (t,) = b.plan([("s", 10), ("s", 14)])  # both round up to 16
    assert t.n_tile == 16 and t.needs_mask
    assert t.real_cells == 24 and t.padded_cells == 32


def test_naive_pads_to_group_max_and_full_batch():
    b = RaggedBatcher(mode="naive", max_batch=4)
    tiles = b.plan([("s", 5), ("s", 17), ("s", 9), ("t", 3)])
    _check_partition([("s", 5), ("s", 17), ("s", 9), ("t", 3)], tiles)
    s_tiles = [t for t in tiles if t.stage == "s"]
    assert len(s_tiles) == 1
    assert s_tiles[0].n_tile == 17 and s_tiles[0].b_tile == 4
    assert s_tiles[0].needs_mask


def test_naive_overflow_spills_into_more_tiles():
    b = RaggedBatcher(mode="naive", max_batch=2)
    tiles = b.plan([("s", 4)] * 5)
    assert [len(t.members) for t in tiles] == [2, 2, 1]
    assert all(t.b_tile == 2 for t in tiles)


def test_bucket_key_distinguishes_masked_tiles():
    b = RaggedBatcher(token_tile=8, max_batch=4)
    (full,) = b.plan([("s", 8)])      # exact: no mask
    (padded,) = b.plan([("s", 5)])    # padded to 8: masked
    assert full.n_tile == padded.n_tile == 8
    assert full.bucket_key != padded.bucket_key
    assert b.bucket_count == 2


def test_token_cap_bounds_quantization():
    """A per-item cap (e.g. the position-table capacity at the embed
    stage) stops token_tile rounding from padding past a hard shape
    bound."""
    b = RaggedBatcher(token_tile=15, max_batch=4)
    (t,) = b.plan([("embed", 16, 16)])
    assert t.n_tile == 16  # would be 30 uncapped
    (t2,) = b.plan([("embed", 7, 16)])
    assert t2.n_tile == 15  # cap only clamps, smaller tiles still quantize
    with pytest.raises(ValueError, match="cap"):
        b.plan([("embed", 16, 9)])


def test_validation():
    with pytest.raises(ValueError):
        RaggedBatcher(token_tile=0)
    with pytest.raises(ValueError):
        RaggedBatcher(mode="magic")
    with pytest.raises(ValueError):
        RaggedBatcher(mode="naive")  # needs max_batch
    with pytest.raises(ValueError):
        RaggedBatcher().plan([("s", 0)])
