"""Property-based RaggedBatcher invariants (hypothesis): any sequence of
per-stage keep-counts bin-packs into buckets with zero dropped requests and
bounded padding waste — the vision engine's zero-drop guarantee."""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.token_pruning import num_kept_tokens  # noqa: E402
from repro.serving.ragged_batcher import RaggedBatcher  # noqa: E402

from test_ragged_batcher import (_check_balanced_bounds,  # noqa: E402
                                 _check_partition)

_fast = settings(max_examples=50, deadline=None)


@_fast
@given(ns=st.lists(st.tuples(st.sampled_from(["a", "b", "c"]),
                             st.integers(1, 64)), min_size=1, max_size=40),
       tile=st.sampled_from([1, 2, 8, 16]),
       mode=st.sampled_from(["balanced", "naive"]),
       max_batch=st.integers(1, 8))
def test_plan_partitions_any_population(ns, tile, mode, max_batch):
    """Zero dropped requests + bounded padding for arbitrary stage/count
    populations in both modes."""
    b = RaggedBatcher(token_tile=tile, mode=mode, max_batch=max_batch)
    tiles = b.plan(ns)
    _check_partition(ns, tiles)
    if mode == "balanced":
        _check_balanced_bounds(b, tiles)
        # waste bound: < token_tile per row plus pow2 batch rounding -> a
        # tile's padded area is < 2x its (real + token_tile) area
        for t in tiles:
            assert t.padded_cells < 2 * sum(n + tile for n in t.n_tokens)
    else:
        for t in tiles:
            assert t.n_tile == max(t.n_tokens)
            assert t.b_tile == max_batch


@_fast
@given(pop=st.lists(st.tuples(st.integers(2, 64),
                              st.floats(0.05, 1.0)), min_size=1,
                    max_size=16),
       n_stages=st.integers(1, 4), tile=st.sampled_from([1, 4]))
def test_keep_count_trajectories_bin_pack(pop, n_stages, tile):
    """Any sequence of per-stage keep-counts (the TDM trajectory of a
    (patches, r_t) population) bin-packs with zero drops at every stage."""
    b = RaggedBatcher(token_tile=tile, max_batch=8)
    counts = [n for n, _ in pop]
    rates = [r for _, r in pop]
    for stage in range(n_stages):
        items = [(stage, n) for n in counts]
        tiles = b.plan(items)
        _check_partition(items, tiles)
        _check_balanced_bounds(b, tiles)
        counts = [num_kept_tokens(n, r) for n, r in zip(counts, rates)]
    assert b.tiles_planned >= n_stages
    assert b.bucket_count <= b.tiles_planned