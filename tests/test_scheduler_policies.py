"""Latency-aware Scheduler policies (duck-typed over LM + vision requests)
and the shared bench-artifact envelope."""
import dataclasses
import json

import numpy as np
import pytest

from repro.bench import (SCHEMA_VERSION, load_bench_artifact,
                         write_bench_artifact)
from repro.serving.scheduler import (Scheduler, predicted_prune_load,
                                     prune_pressure_aware, request_tokens,
                                     shortest_prompt_first)


@dataclasses.dataclass
class _LM:
    uid: int
    prompt: np.ndarray
    prune_load: float = None


@dataclasses.dataclass
class _Vision:
    uid: int
    patches: np.ndarray
    prune_load: float = None


def _lm(uid, n, load=None):
    return _LM(uid, np.zeros(n, np.int32), load)


def _vis(uid, n, load=None):
    return _Vision(uid, np.zeros((n, 192), np.float32), load)


def test_request_tokens_duck_types_both_paths():
    assert request_tokens(_lm(0, 12)) == 12
    assert request_tokens(_vis(0, 9)) == 10  # patches + CLS


def test_predicted_prune_load_falls_back_to_size():
    assert predicted_prune_load(_lm(0, 12)) == 12
    assert predicted_prune_load(_lm(0, 12, load=3.5)) == 3.5


def test_shortest_prompt_first_mixed_population():
    waiting = [_lm(0, 30), _vis(1, 4), _lm(2, 8), _vis(3, 16)]
    assert shortest_prompt_first(waiting) == 1
    # ties stay FIFO: two equal-size requests -> earlier one
    assert shortest_prompt_first([_lm(0, 8), _lm(1, 8)]) == 0


def test_prune_pressure_prefers_low_post_prune_load():
    # big-but-heavily-pruned beats small-but-unpruned
    waiting = [_lm(0, 8), _lm(1, 40, load=4.0)]
    assert prune_pressure_aware(waiting) == 1


def test_scheduler_admits_in_policy_order():
    sched = Scheduler(1, policy="shortest_prompt_first")
    sched.submit([_lm(0, 30), _lm(1, 5), _lm(2, 12)])
    order = []
    while sched.waiting:
        (slot, req), = sched.schedule()
        order.append(req.uid)
        sched.retire(slot)
    assert order == [1, 2, 0]
    assert [e[0] for e in sched.events] == ["admit", "retire"] * 3


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown policy"):
        Scheduler(2, policy="round_robin")


# ---------------------------------------------------------------------------
# bench artifact envelope
# ---------------------------------------------------------------------------
def test_artifact_roundtrip(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    written = write_bench_artifact(
        path, kind="vision", config={"slots": 4},
        results={"balanced": {"images_s": 10.0}},
        extra={"balanced_vs_naive": 1.5},
        seed=7, trace_fingerprint="abc123")
    loaded = load_bench_artifact(path, expect_kind="vision")
    assert loaded == json.loads(json.dumps(written))
    assert loaded["schema_version"] == SCHEMA_VERSION
    assert loaded["balanced_vs_naive"] == 1.5
    # v3 provenance block: seed + fingerprint as passed, git_sha captured
    # from the checkout (string or null, never absent)
    prov = loaded["provenance"]
    assert prov["seed"] == 7
    assert prov["trace_fingerprint"] == "abc123"
    assert "git_sha" in prov


def test_artifact_rejects_reserved_extra(tmp_path):
    with pytest.raises(ValueError, match="collides"):
        write_bench_artifact(str(tmp_path / "b.json"), "serving", {}, {},
                             extra={"results": {}})


def test_artifact_load_validates(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"kind": "serving"}))
    with pytest.raises(ValueError, match="missing"):
        load_bench_artifact(str(bad))
    path = str(tmp_path / "v.json")
    write_bench_artifact(path, "serving", {}, {})
    with pytest.raises(ValueError, match="kind"):
        load_bench_artifact(path, expect_kind="vision")
    wrong = json.load(open(path))
    wrong["schema_version"] = 999
    (tmp_path / "w.json").write_text(json.dumps(wrong))
    with pytest.raises(ValueError, match="schema_version"):
        load_bench_artifact(str(tmp_path / "w.json"))
    gutted = json.load(open(path))
    gutted["provenance"] = {"seed": 0}   # missing git_sha / fingerprint
    (tmp_path / "p.json").write_text(json.dumps(gutted))
    with pytest.raises(ValueError, match="provenance"):
        load_bench_artifact(str(tmp_path / "p.json"))
