"""Serving engine + dynamic KV pruning tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models import model as M
from repro.serving import EngineConfig, Request, ServeEngine, prune_kv_caches


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_len=64))
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                    max_new_tokens=5) for i in range(5)]
    out = eng.run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    assert all(len(v) == 5 for v in out.values())
    assert all(0 <= t < cfg.vocab_size for v in out.values() for t in v)


def test_engine_deterministic(engine_setup):
    cfg, params = engine_setup
    ec = EngineConfig(max_batch=2, max_len=64)
    reqs = lambda: [Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=4) for i in range(2)]
    o1 = ServeEngine(cfg, params, ec).run(reqs())
    o2 = ServeEngine(cfg, params, ec).run(reqs())
    assert o1 == o2


def test_kv_pruning_preserves_shapes_and_shrinks_length(engine_setup):
    cfg, params = engine_setup
    from repro.models import steps as ST
    caches = ST.init_caches(cfg, 2, 32)
    caches = ST.set_cache_length(cfg, caches, 16)
    # fake accumulated attention mass
    def with_mass(c):
        if isinstance(c, A.KVCache):
            mass = jnp.asarray(
                np.random.default_rng(0).random(c.attn_mass.shape),
                jnp.float32)
            return c._replace(attn_mass=mass)
        return c
    caches = jax.tree.map(with_mass, caches,
                          is_leaf=lambda x: isinstance(x, A.KVCache))
    pruned = prune_kv_caches(caches, keep_frac=0.5)
    flat_old = [c for c in jax.tree_util.tree_leaves(caches)]
    flat_new = [c for c in jax.tree_util.tree_leaves(pruned)]
    for o, n in zip(flat_old, flat_new):
        assert o.shape == n.shape
    # lengths shrank to <= keep
    def check(c):
        if isinstance(c, A.KVCache):
            assert int(np.max(np.asarray(c.length))) <= 16
    jax.tree.map(check, pruned, is_leaf=lambda x: isinstance(x, A.KVCache))


def test_kv_pruned_decode_still_runs(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, kv_prune_interval=2, kv_prune_keep=0.5))
    reqs = [Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                    max_new_tokens=8)]
    out = eng.run(reqs)
    assert len(out[0]) == 8
