"""Serving engine + dynamic KV pruning tests: static waves, the
continuous-batching slot path, pad masking, and elastic degradation."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models import model as M
from repro.serving import EngineConfig, Request, ServeEngine, prune_kv_caches


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_len=64))
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                    max_new_tokens=5) for i in range(5)]
    out = eng.run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    assert all(len(v) == 5 for v in out.values())
    assert all(0 <= t < cfg.vocab_size for v in out.values() for t in v)


def test_engine_deterministic(engine_setup):
    cfg, params = engine_setup
    ec = EngineConfig(max_batch=2, max_len=64)
    reqs = lambda: [Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=4) for i in range(2)]
    o1 = ServeEngine(cfg, params, ec).run(reqs())
    o2 = ServeEngine(cfg, params, ec).run(reqs())
    assert o1 == o2


def _with_mass(caches, seed=0):
    def one(c):
        if isinstance(c, A.KVCache):
            mass = jnp.asarray(
                np.random.default_rng(seed).random(c.attn_mass.shape),
                jnp.float32)
            return c._replace(attn_mass=mass)
        return c
    return jax.tree.map(one, caches,
                        is_leaf=lambda x: isinstance(x, A.KVCache))


def test_kv_pruning_preserves_shapes_and_shrinks_length(engine_setup):
    cfg, params = engine_setup
    from repro.models import steps as ST
    caches = ST.init_caches(cfg, 2, 32)
    caches = ST.set_cache_length(cfg, caches, 16)
    pruned, new_starts = prune_kv_caches(_with_mass(caches), keep_frac=0.5)
    flat_old = jax.tree_util.tree_leaves(caches)
    flat_new = jax.tree_util.tree_leaves(pruned)
    for o, n in zip(flat_old, flat_new):
        assert o.shape == n.shape
    # lengths shrank to <= keep, and unpadded slots have no garbage prefix
    np.testing.assert_array_equal(np.asarray(new_starts), 0)
    def check(c):
        if isinstance(c, A.KVCache):
            assert int(np.max(np.asarray(c.length))) <= 16
    jax.tree.map(check, pruned, is_leaf=lambda x: isinstance(x, A.KVCache))


def test_kv_pruning_pad_slots_never_kept(engine_setup):
    """Left-pad positions must lose to real tokens in the KV compaction
    even when their accumulated mass is (artificially) enormous."""
    cfg, params = engine_setup
    from repro.models import steps as ST
    caches = ST.init_caches(cfg, 2, 32)
    caches = ST.set_cache_length(cfg, caches, 16)
    starts = jnp.asarray([0, 6], jnp.int32)  # slot 1 left-padded 6 deep

    def poison(c):
        if isinstance(c, A.KVCache):
            mass = jnp.asarray(
                np.random.default_rng(1).random(c.attn_mass.shape),
                jnp.float32)
            mass = mass.at[..., 1, :6].set(1e6)  # pad slots look "important"
            # make every key recognizably nonzero so garbage zeroing shows
            k = jnp.ones_like(c.k)
            return c._replace(attn_mass=mass, k=k, v=k)
        return c

    caches = jax.tree.map(poison, caches,
                          is_leaf=lambda x: isinstance(x, A.KVCache))
    pruned, new_starts = prune_kv_caches(caches, keep_frac=0.5, starts=starts)
    keep = 16  # 0.5 * 32
    # slot 0: 16 valid entries -> full window; slot 1: 10 valid -> 6 garbage
    np.testing.assert_array_equal(np.asarray(new_starts), [0, 6])

    def check(c):
        if isinstance(c, A.KVCache):
            k = np.asarray(c.k, np.float32)
            # slot 1's garbage prefix is zeroed; its valid window is intact
            assert (k[..., 1, :6, :, :] == 0).all()
            assert (k[..., 1, 6:keep, :, :] != 0).all()
            assert (k[..., 0, :keep, :, :] != 0).all()
    jax.tree.map(check, pruned, is_leaf=lambda x: isinstance(x, A.KVCache))


def test_kv_pruned_decode_still_runs(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(
        max_batch=2, max_len=24, kv_prune_interval=2, kv_prune_keep=0.5))
    reqs = [Request(uid=0, prompt=np.arange(14, dtype=np.int32),
                    max_new_tokens=8)]
    out = eng.run(reqs)
    assert len(out[0]) == 8
    assert eng.prune_events > 0  # cache outgrew keep=12, pruning fired


def test_prune_cadence_resets_per_wave(engine_setup):
    """steps_since_prune must not leak across waves: two 3-step waves under
    interval=5 never prune, and identical requests in wave 1 and wave 2
    produce identical outputs."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(
        max_batch=1, max_len=16, kv_prune_interval=5, kv_prune_keep=0.5))
    mk = lambda uid: Request(uid=uid, prompt=np.arange(8, dtype=np.int32),
                             max_new_tokens=4)
    out = eng.run([mk(0), mk(1)])  # max_batch=1 -> two consecutive waves
    assert eng.prune_events == 0   # 3+3 decode steps, cadence reset between
    assert out[0] == out[1]        # wave 2 not perturbed by wave 1's count


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
def _mixed_requests():
    return [Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=6),
            Request(uid=1, prompt=np.arange(7, dtype=np.int32) + 3,
                    max_new_tokens=3),
            Request(uid=2, prompt=np.arange(5, dtype=np.int32) + 9,
                    max_new_tokens=5)]


def test_continuous_matches_static_single_wave(engine_setup):
    """With every request admitted at t=0 the slot engine runs the same
    prefill + decode sequence as a static wave — outputs must be equal."""
    cfg, params = engine_setup
    ec = EngineConfig(max_batch=3, max_len=64)
    static = ServeEngine(cfg, params, ec).run(_mixed_requests())
    cont = ServeEngine(cfg, params, ec).run_continuous(_mixed_requests())
    assert static == cont


def test_continuous_slot_reuse_after_done(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_len=64))
    reqs = [Request(uid=i, prompt=np.arange(3 + i % 4, dtype=np.int32) + i,
                    max_new_tokens=2 + (i % 3)) for i in range(6)]
    out = eng.run_continuous(reqs)
    assert sorted(out) == list(range(6))
    assert all(len(out[r.uid]) == r.max_new_tokens for r in reqs)
    assert all(r.done for r in reqs)
    # slots were actually reused: more admissions than slots
    admits = [e for e in eng.events if e[0] == "admit"]
    assert len(admits) == 6


def test_continuous_with_kv_pruning(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(
        max_batch=2, max_len=24, kv_prune_interval=2, kv_prune_keep=0.5))
    reqs = [Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                    max_new_tokens=8),
            Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=10),
            Request(uid=2, prompt=np.arange(6, dtype=np.int32) + 2,
                    max_new_tokens=4)]
    out = eng.run_continuous(reqs)
    assert {k: len(v) for k, v in out.items()} == {0: 8, 1: 10, 2: 4}
    assert eng.prune_events > 0
    assert all(0 <= t < cfg.vocab_size for v in out.values() for t in v)


def test_continuous_overflow_raises(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=1, max_len=8))
    with pytest.raises(RuntimeError, match="max_len"):
        eng.run_continuous([Request(uid=0,
                                    prompt=np.arange(6, dtype=np.int32),
                                    max_new_tokens=16)])


def test_capacity_accounts_for_left_padding(engine_setup):
    """Whole-batch prefill left-pads a short prompt to the longest prompt
    in the batch, so its writes reach pad + prompt + new — the capacity
    check must use the padded length, not each request's own prompt length
    (regression: used to pass the check then crash mid-stream after the
    outputs were already half-generated). Per-slot admission pads each
    prompt only to its own bucket, so the same workload fits: the check
    must account for exactly the padding each path actually writes."""
    cfg, params = engine_setup
    reqs = lambda: [Request(uid=0, prompt=np.arange(30, dtype=np.int32),
                            max_new_tokens=4),
                    Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=30)]
    # whole-batch paths: padded high-water 30 + 30 - 1 > 40 -> refuse
    legacy = EngineConfig(max_batch=2, max_len=40, per_slot_prefill=False)
    with pytest.raises(RuntimeError, match="max_len"):
        ServeEngine(cfg, params, legacy).run_continuous(reqs())
    # static waves decode until the slowest request finishes, so even the
    # per-slot path's high-water is bucket(30) + 30 - 1 = 61 > 40 -> refuse
    with pytest.raises(RuntimeError, match="max_len"):
        ServeEngine(cfg, params,
                    EngineConfig(max_batch=2, max_len=40)).run(reqs())
    # per-slot admission: worst slot is bucket(4)=8 + 30 - 1 = 37 <= 40,
    # the workload fits without raising max_len (the padding win)
    ok = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_len=40))
    out = ok.run_continuous(reqs())
    assert {k: len(v) for k, v in out.items()} == {0: 4, 1: 30}


def test_static_wave_overflow_raises_not_corrupts(engine_setup):
    """The static path must refuse prompt+max_new > max_len instead of
    silently clamping cache writes onto the last slot."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=1, max_len=16))
    with pytest.raises(RuntimeError, match="max_len"):
        eng.run([Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                         max_new_tokens=10)])


def test_prune_kv_caches_recurrent_state_passthrough():
    """ssm/hybrid serve states contain non-KVCache leaves — pruning must
    pass them through untouched instead of crashing."""
    from repro.models import steps as ST
    cfg = get_config("rwkv6-1.6b").reduced()
    states = ST.init_caches(cfg, 2, 16)
    pruned, new_starts = prune_kv_caches(states, keep_frac=0.5)
    for a, b in zip(jax.tree_util.tree_leaves(states),
                    jax.tree_util.tree_leaves(pruned)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert new_starts is None  # nothing compacted, starts unchanged

    cfg_h = get_config("zamba2-1.2b").reduced()
    hybrid = ST.set_cache_length(cfg_h, ST.init_caches(cfg_h, 2, 16), 8)
    pruned_h, starts_h = prune_kv_caches(_with_mass(hybrid), keep_frac=0.5)
    def check(c):
        if isinstance(c, A.KVCache):
            assert int(np.max(np.asarray(c.length))) <= 8
    jax.tree.map(check, pruned_h, is_leaf=lambda x: isinstance(x, A.KVCache))
    assert starts_h is not None


def test_decode_pad_slots_accumulate_no_mass(engine_setup):
    """attn_mass at left-pad positions must stay exactly zero through
    prefill + decode so pad slots never compete in KV pruning."""
    cfg, params = engine_setup
    from repro.models import steps as ST
    prefill = jax.jit(ST.make_prefill(cfg))
    decode = jax.jit(ST.make_decode_step(cfg))
    toks = np.zeros((2, 8), np.int32)
    toks[0, :] = np.arange(8)
    toks[1, 5:] = np.arange(3)           # 5 pad positions
    starts = jnp.asarray([0, 5], jnp.int32)
    caches = ST.init_caches(cfg, 2, 16)
    batch = {"tokens": jnp.asarray(toks), "valid_start": starts}
    tok, caches = prefill(params, batch, caches)
    for _ in range(3):
        tok, caches = decode(params, tok[:, None], caches,
                             valid_start=starts)

    def check(c):
        if isinstance(c, A.KVCache):
            mass = np.asarray(c.attn_mass)
            assert (mass[..., 1, :5] == 0).all()      # pads: zero mass
            assert (mass[..., 1, 5:11] > 0).all()     # real tokens: mass
    jax.tree.map(check, caches, is_leaf=lambda x: isinstance(x, A.KVCache))


# ---------------------------------------------------------------------------
# Elastic degradation (subprocess: needs a forced multi-device host)
# ---------------------------------------------------------------------------
def _serve_cli(extra, env_extra):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"),
               **env_extra)
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "stablelm-1.6b", "--continuous", "--json",
           "--requests", "4", "--prompt-len", "6", "--max-new", "6",
           "--max-batch", "2"] + extra
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=520,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_continuous_degradation_replans_and_finishes():
    """Force a device loss mid-stream: the engine must walk the degradation
    ladder, re-shard from the checkpoint, finish every request, and produce
    the same tokens as an undisturbed run."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    healthy = _serve_cli([], env)
    degraded = _serve_cli(["--elastic-drop", "3"], env)
    assert [e for e in degraded["events"] if e[0] == "degrade"], \
        degraded["events"]
    assert sorted(degraded["outputs"]) == ["0", "1", "2", "3"]
    assert all(len(v) == 6 for v in degraded["outputs"].values())
    assert degraded["outputs"] == healthy["outputs"]
