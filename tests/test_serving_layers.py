"""PR-3 layered serving API tests: Scheduler / KVCacheManager / ModelRunner
composition, per-slot prefill equivalence, bounded jit recompiles under
churn, EngineConfig validation, and unified event telemetry."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving import (EngineConfig, KVCacheManager, Request, Scheduler,
                           ServeEngine, bucket_length)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mixed_requests(num=6, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, 128, int(rng.integers(3, 12)),
                                        dtype=np.int32),
                    max_new_tokens=int(rng.integers(2, 7)))
            for i in range(num)]


# ---------------------------------------------------------------------------
# Per-slot prefill equivalence
# ---------------------------------------------------------------------------
def test_per_slot_equivalence_staggered(engine_setup):
    """Greedy tokens must be bit-exact between per-slot prefill admission
    and the PR-2 whole-batch re-prefill, under a churny mix where slots
    free and re-admit mid-stream at unequal per-row cache lengths."""
    cfg, params = engine_setup
    mk = lambda per_slot: EngineConfig(max_batch=2, max_len=64,
                                       per_slot_prefill=per_slot)
    new = ServeEngine(cfg, params, mk(True)).serve(
        _mixed_requests(), continuous=True)
    legacy = ServeEngine(cfg, params, mk(False)).serve(
        _mixed_requests(), continuous=True)
    assert new == legacy
    assert sorted(new) == list(range(6))


def test_per_slot_equivalence_with_kv_pruning(engine_setup):
    """With every request admitted at t=0 (slots >= requests) the prune
    cadence fires identically on both admission paths — outputs must stay
    bit-exact with KV pruning enabled, and pruning must actually fire."""
    cfg, params = engine_setup
    reqs = lambda: [Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                            max_new_tokens=10),
                    Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 7,
                            max_new_tokens=12)]
    mk = lambda per_slot: EngineConfig(
        max_batch=2, max_len=24, kv_prune_interval=2, kv_prune_keep=0.5,
        per_slot_prefill=per_slot)
    eng_new = ServeEngine(cfg, params, mk(True))
    eng_old = ServeEngine(cfg, params, mk(False))
    out_new = eng_new.serve(reqs(), continuous=True)
    out_old = eng_old.serve(reqs(), continuous=True)
    assert out_new == out_old
    assert eng_new.prune_events > 0
    assert eng_new.prune_events == eng_old.prune_events


def test_continuous_matches_isolated_request(engine_setup):
    """A request served alongside churny slot-mates must generate exactly
    the tokens it generates alone — per-slot cache writes and per-row
    masks may never leak across rows."""
    cfg, params = engine_setup
    probe = lambda: Request(uid=99, prompt=np.arange(6, dtype=np.int32) + 2,
                            max_new_tokens=8)
    ec = EngineConfig(max_batch=2, max_len=64)
    alone = ServeEngine(cfg, params, ec).serve([probe()], continuous=True)
    crowd = _mixed_requests(5) + [probe()]
    together = ServeEngine(cfg, params, ec).serve(crowd, continuous=True)
    assert together[99] == alone[99]


# ---------------------------------------------------------------------------
# Bounded recompiles + admission cost
# ---------------------------------------------------------------------------
def test_bounded_recompiles_under_churn(engine_setup):
    """Under a churny request mix with bucketing on, distinct jit
    compilations of the per-slot prefill stay <= the number of distinct
    prefix-length buckets."""
    cfg, params = engine_setup
    reqs = _mixed_requests(10, seed=11)
    ec = EngineConfig(max_batch=2, max_len=64)
    eng = ServeEngine(cfg, params, ec)
    eng.serve(reqs, continuous=True)
    buckets = {bucket_length(len(r.prompt), ec.max_len,
                             ec.prefill_bucket_min) for r in reqs}
    slot_fn = eng.runner._prefill_slot
    try:
        compiles = slot_fn._cache_size()
    except AttributeError:
        compiles = sum(1 for k in eng.runner.compiled_shapes()
                       if k[0] == "prefill_slot")
    assert compiles <= len(buckets), (compiles, buckets)
    # the shape ledger agrees: one prefill_slot entry per bucket
    slot_shapes = {k for k in eng.runner.compiled_shapes()
                   if k[0] == "prefill_slot"}
    assert len(slot_shapes) <= len(buckets)


def test_admission_cost_independent_of_active_slots(engine_setup):
    """Per-slot admission prefills only the admitted prompt's bucket: the
    per-admission token cost must not change with slot count, while the
    PR-2 re-prefill path's cost grows with occupancy."""
    cfg, params = engine_setup
    def cost(slots, per_slot):
        ec = EngineConfig(max_batch=slots, max_len=64,
                          per_slot_prefill=per_slot)
        eng = ServeEngine(cfg, params, ec)
        eng.serve(_mixed_requests(8, seed=5), continuous=True)
        return eng.stats()["prefill_tokens_per_admission"]

    assert cost(2, True) == cost(4, True)  # bucket sizes only
    # whole-batch re-prefill pays for every active prefix per admission
    assert cost(4, False) > cost(4, True)


# ---------------------------------------------------------------------------
# EngineConfig validation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kwargs, match", [
    (dict(max_batch=0), "max_batch"),
    (dict(max_batch=-2), "max_batch"),
    (dict(max_len=0), "max_len"),
    (dict(kv_prune_keep=0.0), "kv_prune_keep"),
    (dict(kv_prune_keep=1.5), "kv_prune_keep"),
    (dict(kv_prune_interval=-1), "kv_prune_interval"),
    (dict(prefill_bucket_min=0), "prefill_bucket_min"),
])
def test_engine_config_validation(kwargs, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kwargs)


def test_engine_config_valid_defaults():
    ec = EngineConfig()
    assert ec.max_batch > 0 and ec.per_slot_prefill


# ---------------------------------------------------------------------------
# Unified event telemetry
# ---------------------------------------------------------------------------
def test_static_path_emits_same_event_stream(engine_setup):
    """The static-wave path must emit the same admit/retire stream through
    the Scheduler as the continuous path (PR-2 recorded events only for
    run_continuous)."""
    cfg, params = engine_setup
    ec = EngineConfig(max_batch=2, max_len=64)
    eng_s = ServeEngine(cfg, params, ec)
    eng_c = ServeEngine(cfg, params, ec)
    eng_s.serve(_mixed_requests(5, seed=7))
    eng_c.serve(_mixed_requests(5, seed=7), continuous=True)
    for eng in (eng_s, eng_c):
        admits = sorted(u for k, u in eng.events if k == "admit")
        retires = sorted(u for k, u in eng.events if k == "retire")
        assert admits == list(range(5))
        assert retires == list(range(5))
    # every event is (kind, payload) drawn from one shared vocabulary
    kinds = {k for k, _ in eng_s.events} | {k for k, _ in eng_c.events}
    assert kinds <= {"admit", "retire", "degrade"}


# ---------------------------------------------------------------------------
# Layer units: Scheduler + KVCacheManager
# ---------------------------------------------------------------------------
def test_scheduler_fifo_and_pluggable_policy():
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=2) for i in range(4)]
    s = Scheduler(2)
    s.submit(reqs)
    assert [r.uid for _, r in s.schedule()] == [0, 1]  # FIFO into slots
    assert s.free_slots() == []
    s.retire(0)
    assert [r.uid for _, r in s.schedule()] == [2]
    assert s.num_admissions == 3
    assert [e for e in s.events if e[0] == "retire"] == [("retire", 0)]

    lifo = Scheduler(1, policy=lambda waiting: len(waiting) - 1)
    lifo.submit(list(reqs))
    assert lifo.schedule()[0][1].uid == 3  # policy picks the newest


def test_cache_manager_admit_free_and_capacity(engine_setup):
    cfg, _ = engine_setup
    ec = EngineConfig(max_batch=2, max_len=32)
    kvm = KVCacheManager(cfg, ec)
    kvm.reset()
    lb, start = kvm.admit(0, prompt_len=5, max_new_tokens=4)
    assert lb == bucket_length(5, 32, ec.prefill_bucket_min) == 8
    assert start == 3 and kvm.active[0]
    with pytest.raises(RuntimeError, match="max_len"):
        kvm.admit(1, prompt_len=5, max_new_tokens=30)  # 8 + 29 > 32
    with pytest.raises(RuntimeError, match="exceeds max_len"):
        kvm.admit(1, prompt_len=40)
    kvm.free(0)
    assert not kvm.active[0]


def test_cache_manager_prune_cadence(engine_setup):
    cfg, _ = engine_setup
    ec = EngineConfig(max_batch=2, max_len=16, kv_prune_interval=2,
                      kv_prune_keep=0.5)
    kvm = KVCacheManager(cfg, ec)
    kvm.reset()
    kvm.admit(0, prompt_len=10)
    assert not kvm.maybe_prune()      # cadence: 1 of 2 steps
    assert kvm.maybe_prune()          # fires: length 10 >= keep 8
    assert kvm.prune_events == 1
    assert int(kvm.lengths.max()) == 8
    # short caches skip: nothing to prune below the keep target
    kvm.reset()
    kvm.admit(0, prompt_len=4)
    assert not kvm.maybe_prune() and not kvm.maybe_prune()
    assert kvm.prune_events == 1      # unchanged


def test_prune_cadence_ignores_freed_slots(engine_setup):
    """A retired slot's buffer position keeps advancing with every batched
    decode; the prune cadence must gauge growth from ACTIVE slots only
    (regression: a freed long-prompt slot used to drive compactions of a
    live short request that never reached the keep target)."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(
        max_batch=2, max_len=256, kv_prune_interval=4, kv_prune_keep=0.25))
    reqs = [Request(uid=0, prompt=np.arange(60, dtype=np.int32),
                    max_new_tokens=2),     # retires early at ~62 real tokens
            Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=30)]    # never exceeds 34 < keep=64
    out = eng.serve(reqs, continuous=True)
    assert {k: len(v) for k, v in out.items()} == {0: 2, 1: 30}
    assert eng.prune_events == 0


def test_bucket_padding_never_rejects_feasible_prompt(engine_setup):
    """A prompt whose raw length + decode budget fits max_len must be
    admitted even when its power-of-two bucket (capped at max_len) would
    not (regression: prompt 40 / max_new 4 / max_len 56 bucketed to 56 and
    raised, though 40 + 3 = 43 <= 56 and the PR-2 path served it)."""
    cfg, params = engine_setup
    ec = EngineConfig(max_batch=2, max_len=56)
    reqs = lambda: [Request(uid=0, prompt=np.arange(40, dtype=np.int32),
                            max_new_tokens=4)]
    for continuous in (True, False):
        eng = ServeEngine(cfg, params, ec)
        out = eng.serve(reqs(), continuous=continuous)
        assert len(out[0]) == 4
    # infeasible stays infeasible: the raw prompt itself cannot fit
    with pytest.raises(RuntimeError, match="max_len"):
        ServeEngine(cfg, params, ec).serve(
            [Request(uid=0, prompt=np.arange(40, dtype=np.int32),
                     max_new_tokens=30)], continuous=True)


def test_bucket_padding_leaves_prune_headroom(engine_setup):
    """With KV pruning on, bucket padding must leave enough decode headroom
    for the first compaction to fire (regression: prompt 20 bucketed to
    max_len=24 put the write head at capacity and overflowed on the first
    decode, while the PR-2 path served the same config)."""
    cfg, params = engine_setup
    ec = EngineConfig(max_batch=2, max_len=24, kv_prune_interval=2,
                      kv_prune_keep=0.5)
    reqs = lambda: [Request(uid=0, prompt=np.arange(20, dtype=np.int32),
                            max_new_tokens=8)]
    eng = ServeEngine(cfg, params, ec)
    out = eng.serve(reqs(), continuous=True)
    assert len(out[0]) == 8
    assert eng.prune_events > 0
    # matches the whole-batch path on the same workload
    legacy = ServeEngine(cfg, params, EngineConfig(
        max_batch=2, max_len=24, kv_prune_interval=2, kv_prune_keep=0.5,
        per_slot_prefill=False)).serve(reqs(), continuous=True)
    assert out == legacy


def test_elastic_rebuild_keeps_per_slot_capacity(engine_setup, tmp_path):
    """A mid-stream degrade must rebuild via per-slot prefill: the
    whole-batch fallback's left-padding would reject this workload
    (bucket(4) + 30 - 1 = 37 <= 40 per slot, but common-L padding needs
    30 + 30 - 1 = 59 > 40) and crash in-flight requests. Outputs must be
    bit-exact against an undisturbed run, with a degrade event emitted."""
    cfg, params = engine_setup
    from repro.checkpoint import CheckpointManager
    from repro.dist.elastic import MeshPlan
    from repro.serving import ElasticContext

    manager = CheckpointManager(str(tmp_path), keep=1)
    manager.save(0, params)
    probes = {"n": 0}

    def device_count():
        probes["n"] += 1
        return 2 if probes["n"] <= 3 else 1  # lose a device after 3 probes

    elastic = ElasticContext(manager=manager,
                             plan=MeshPlan((2, 1), ("data", "model")),
                             budgets=[1], device_count=device_count)
    ec = EngineConfig(max_batch=2, max_len=40)
    reqs = lambda: [Request(uid=0, prompt=np.arange(30, dtype=np.int32),
                            max_new_tokens=4),
                    Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=30)]
    healthy = ServeEngine(cfg, params, ec).serve(reqs(), continuous=True)
    eng = ServeEngine(cfg, params, ec, elastic=elastic)
    degraded = eng.serve(reqs(), continuous=True)
    assert [e for e in eng.events if e[0] == "degrade"]
    assert degraded == healthy


def test_bucket_length():
    assert bucket_length(1, 64) == 8
    assert bucket_length(8, 64) == 8
    assert bucket_length(9, 64) == 16
    assert bucket_length(33, 64) == 64
    assert bucket_length(60, 64) == 64   # capped at max_len
    assert bucket_length(5, 64, lo=4) == 8
