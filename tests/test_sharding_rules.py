"""Pure-logic tests for the sharding rules (no multi-device runtime —
PartitionSpecs are inspected structurally against a mesh built from a
single device via mock axis sizes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist import sharding as SH
from repro.models import model as M


class FakeMesh:
    """Duck-typed mesh: only .axis_names and .shape are consulted by the
    rule functions (NamedSharding construction is bypassed)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_param_spec_attention():
    cfg = get_config("qwen3-14b")
    assert SH.param_spec(cfg, "layers/attn/wq", 3, (40, 5120, 5120)) == \
        P(None, None, "model")
    assert SH.param_spec(cfg, "layers/attn/wo", 3, (40, 5120, 5120)) == \
        P(None, "model", None)
    assert SH.param_spec(cfg, "layers/attn/wqkv", 3, (40, 5120, 7168)) == \
        P(None, None, "model")


def test_param_spec_embeddings_and_ffn():
    cfg = get_config("qwen3-14b")
    assert SH.param_spec(cfg, "embed", 2, (151936, 5120)) == P("model", None)
    assert SH.param_spec(cfg, "unembed", 2, (5120, 151936)) == P(None, "model")
    assert SH.param_spec(cfg, "layers/mlp/wi", 3, (40, 5120, 17408)) == \
        P(None, None, "model")
    assert SH.param_spec(cfg, "layers/mlp/wo", 3, (40, 17408, 5120)) == \
        P(None, "model", None)


def test_param_spec_moe_expert_parallel():
    cfg = get_config("qwen2-moe-a2.7b")
    spec = SH.param_spec(cfg, "layers/moe/wi", 4, (24, 60, 2048, 1408))
    assert spec == P(None, "model", None, None)  # experts over model axis
    assert SH.param_spec(cfg, "layers/moe/router", 3, (24, 2048, 60)) == \
        P(None, None, None)


def test_param_spec_norms_replicated():
    cfg = get_config("qwen3-14b")
    assert SH.param_spec(cfg, "layers/ln1", 2, (40, 5120)) == P(None, None)
    assert SH.param_spec(cfg, "ln_f", 1, (5120,)) == P(None)


def test_validate_drops_nondivisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    # kv=8 heads can't shard 16 ways on the last dim of a (d, 8*128) weight
    spec = SH._validate(P(None, "model"), (5120, 1024), mesh, "x")
    assert spec == P(None, "model")  # 1024 % 16 == 0 -> kept
    spec = SH._validate(P("model", None), (100, 64), mesh, "x")
    assert spec == P(None, None)  # 100 % 16 != 0 -> dropped


def test_full_param_tree_shardings_cover_all_leaves():
    """Every leaf of every arch's param tree gets a sharding whose specs
    divide the leaf shape on a 16x16 mesh (structural check via fake mesh
    sizes; NamedSharding construction is exercised in the dry-run tests)."""
    mesh = FakeMesh({"data": 16, "model": 16})
    for arch in ["qwen3-14b", "qwen2-moe-a2.7b", "zamba2-1.2b",
                 "rwkv6-1.6b", "whisper-base", "llama-3.2-vision-90b"]:
        cfg = get_config(arch)
        spec_tree = jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        flat = jax.tree_util.tree_flatten_with_path(spec_tree)[0]
        for path, leaf in flat:
            ps = SH._path_str(path)
            spec = SH.param_spec(cfg, ps, leaf.ndim, leaf.shape)
            spec = SH._validate(spec, leaf.shape, mesh, ps)
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is not None:
                    size = mesh.shape[ax]
                    assert dim % size == 0, (arch, ps, leaf.shape, spec)


def test_chunked_loss_equals_dense_loss():
    """chunked_lm_xent must equal the direct full-logits CE."""
    cfg = get_config("stablelm-1.6b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    out = M.forward_lm(cfg, params, toks, mode="train", remat=False,
                       logits_for="all")
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full_like(toks[:, :1], -1)], axis=1)
    dense_ce = M.softmax_xent(out.logits, labels)
    for chunk in (4, 8, 16):
        ck = M.chunked_lm_xent(cfg, params, out.hidden, labels, chunk=chunk)
        np.testing.assert_allclose(float(ck), float(dense_ce), rtol=2e-3)
