"""Algorithm 1 (simultaneous fine-pruning) behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DEIT_SMALL
from repro.core import simultaneous as SIM
from repro.core.schedule import cubic_keep_rate
from repro.data import DataConfig, synthetic_vit_batch
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.optim import AdamW


def test_distillation_loss_zero_when_identical():
    logits = jnp.asarray([[1.0, 2.0, 3.0]])
    assert float(SIM.distillation_loss(logits, logits, 4.0)) < 1e-6


def test_distillation_loss_positive_when_different():
    a = jnp.asarray([[1.0, 2.0, 3.0]])
    b = jnp.asarray([[3.0, 2.0, 1.0]])
    assert float(SIM.distillation_loss(a, b, 4.0)) > 0


def test_simultaneous_step_trains_and_schedules(rng_key):
    cfg = DEIT_SMALL.reduced()
    state, opt = SIM.init_state(cfg, rng_key, AdamW(lr=2e-3))
    teacher = M.init_params(cfg, jax.random.fold_in(rng_key, 9))
    step = jax.jit(SIM.make_simultaneous_step(cfg, cfg, opt, total_steps=20))
    batch = synthetic_vit_batch(cfg, 8, DataConfig(seed=0), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    losses, rbs = [], []
    for _ in range(6):
        state, m = step(state, teacher, batch)
        losses.append(float(m["loss"]))
        rbs.append(float(m["r_b"]))
    assert losses[-1] < losses[0]
    # cubic schedule: r_b decreasing from ~1 toward cfg r_b
    assert rbs[0] > rbs[-1] >= cfg.pruning.r_b - 1e-6
    # score params actually moved
    s0 = PG.init_scores(cfg, M.init_params(cfg, rng_key),
                        jax.random.fold_in(rng_key, 7))
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.scores), jax.tree.leaves(s0)))
    assert moved


def test_cubic_schedule_endpoints():
    assert float(cubic_keep_rate(0, 100, 0.5, 10, 10)) == 1.0
    assert float(cubic_keep_rate(95, 100, 0.5, 10, 10)) == 0.5
    mid = float(cubic_keep_rate(50, 100, 0.5, 10, 10))
    assert 0.5 < mid < 1.0


def test_pruning_glue_masks_apply(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, rng_key)
    assert len(scores) > 0
    masked = PG.apply_pruning(cfg, params, scores, r_b=0.5)
    w0 = np.asarray(params["layers"][0]["attn"]["wq"])
    wm = np.asarray(masked["layers"][0]["attn"]["wq"])
    assert (wm == 0).sum() > (w0 == 0).sum()  # actually pruned
    # non-prunable leaves untouched
    np.testing.assert_array_equal(np.asarray(params["cls"]),
                                  np.asarray(masked["cls"]))


def test_lm_pruned_train_step_runs(rng_key):
    """Simultaneous (weight) pruning applies to LM archs too."""
    from repro.configs import get_config
    from repro.models import steps as ST
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = cfg.replace(pruning=cfg.pruning.__class__(block_size=16, r_b=0.5))
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, rng_key)
    opt = AdamW(lr=1e-3)
    step = jax.jit(ST.make_train_step(cfg, opt, with_pruning=True))
    opt_state = opt.init({"params": params, "scores": scores})
    batch = {"tokens": jax.random.randint(rng_key, (2, 16), 0,
                                          cfg.vocab_size)}
    p2, s2, o2, metrics = step(params, opt_state, batch, scores)
    assert np.isfinite(float(metrics["loss"]))
    assert s2 is not None
