"""Substrate tests: optimizer, checkpointing, fault tolerance, elastic
replanning, gradient compression, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, synthetic_lm_batch, synthetic_vit_batch
from repro.dist.elastic import MeshPlan, degradation_path
from repro.dist.fault import FaultConfig, RestartableLoop, StepWatchdog
from repro.optim import AdamW, global_norm
from repro.optim.compression import (compress_grads, decompress_grads,
                                     init_ef_state)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray(5.0).reshape(1, 1)}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: (p["x"] ** 2).sum())(params)
        params, state = opt.update(grads, state, params)
    assert abs(float(params["x"][0, 0])) < 0.05


def test_adamw_grad_clip():
    opt = AdamW(lr=1e-3, grad_clip=1.0)
    params = {"x": jnp.zeros((4, 4))}
    state = opt.init(params)
    huge = {"x": jnp.full((4, 4), 1e6)}
    new_params, _ = opt.update(huge, state, params)
    # after clipping, first-step update magnitude is bounded by lr
    assert float(jnp.abs(new_params["x"]).max()) < 1.1e-3 * 10


def test_adamw_weight_decay_only_matrices():
    opt = AdamW(lr=1e-2, weight_decay=0.5)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    zero_grads = jax.tree.map(jnp.zeros_like, params)
    new_params, _ = opt.update(zero_grads, state, params)
    assert float(new_params["w"][0, 0]) < 1.0   # decayed
    assert float(new_params["b"][0]) == 1.0     # biases not decayed


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "nested": {"b": jnp.ones((4,), jnp.int32)}}
        for s in (5, 10, 15):
            cm.save(s, tree, extra={"note": s})
        assert cm.all_steps() == [10, 15]
        r = cm.restore(tree)
        np.testing.assert_allclose(np.asarray(r["a"]), np.asarray(tree["a"]))
        assert r["nested"]["b"].dtype == jnp.int32
        assert cm.extra()["note"] == 15


def test_checkpoint_atomicity_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=3)
        cm.save(1, {"x": jnp.ones(3)})
        # a stale tmp dir from a crashed save must not count as a checkpoint
        os.makedirs(os.path.join(d, "step_0000000002.tmp"))
        assert cm.latest_step() == 1


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------
def test_restartable_loop_exact_resume():
    with tempfile.TemporaryDirectory() as d:
        fails = {3, 9}

        def injector(step):
            if step in fails:
                fails.discard(step)
                raise RuntimeError("injected")

        loop = RestartableLoop(
            CheckpointManager(d, keep=3), FaultConfig(checkpoint_every=2),
            make_state=lambda: {"acc": jnp.zeros(())},
            step_fn=lambda s, b: ({"acc": s["acc"] + b}, {}),
            data_fn=lambda step: jnp.float32(step))
        out = loop.run(12, fail_injector=injector)
        assert out["restarts"] == 2
        assert float(out["state"]["acc"]) == sum(range(12))


def test_watchdog_flags_stragglers():
    w = StepWatchdog(FaultConfig(slow_step_factor=3.0))
    for _ in range(20):
        assert w.observe(1.0) is None
    assert w.observe(10.0) == "straggler"


# ---------------------------------------------------------------------------
# Elastic
# ---------------------------------------------------------------------------
def test_degradation_path_preserves_tp():
    plans = degradation_path(
        MeshPlan((2, 16, 16), ("pod", "data", "model")), [256, 128])
    assert plans[1].shape == (16, 16)
    assert plans[2].shape == (8, 16)  # data absorbs the loss, TP preserved


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
def test_compression_error_feedback_reduces_bias():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((32, 32))
                          .astype(np.float32) * 1e-3)}
    state = init_ef_state(g)
    # accumulate the same gradient 50 times with EF: mean dequantized grad
    # must converge to the true gradient (error feedback kills the bias)
    acc = jnp.zeros_like(g["w"])
    for _ in range(50):
        q, s, state = compress_grads(g, state)
        acc = acc + decompress_grads(q, s)["w"]
    mean = acc / 50
    bias = float(jnp.abs(mean - g["w"]).max())
    one_shot_err = float(jnp.abs(
        decompress_grads(*compress_grads(g, init_ef_state(g))[:2])["w"]
        - g["w"]).max())
    assert bias < one_shot_err  # EF strictly better than naive quantization


def test_compression_int8_payload():
    g = {"w": jnp.ones((8, 8))}
    q, s, _ = compress_grads(g, init_ef_state(g))
    assert q["w"].dtype == jnp.int8


# ---------------------------------------------------------------------------
# Data determinism (the straggler-mitigation foundation)
# ---------------------------------------------------------------------------
def test_data_deterministic_per_step_and_shard():
    cfg = get_config("minitron-4b").reduced()
    shape = ShapeConfig("t", seq_len=16, global_batch=8, kind="train")
    dc = DataConfig(seed=1, num_shards=2, shard_index=1)
    b1 = synthetic_lm_batch(cfg, shape, dc, step=7)
    b2 = synthetic_lm_batch(cfg, shape, dc, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic_lm_batch(cfg, shape, dc, step=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    other = synthetic_lm_batch(
        cfg, shape, DataConfig(seed=1, num_shards=2, shard_index=0), step=7)
    assert not np.array_equal(b1["tokens"], other["tokens"])


def test_vit_data_learnable_structure():
    from repro.configs import DEIT_SMALL
    cfg = DEIT_SMALL.reduced()
    b = synthetic_vit_batch(cfg, 16, DataConfig(seed=0), step=0)
    assert b["patches"].shape[0] == 16
    assert (b["labels"] < cfg.num_classes).all()
