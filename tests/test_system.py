"""End-to-end behaviour tests for the full system: train -> checkpoint ->
restore -> serve, on reduced configs."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.launch.serve import serve


@pytest.mark.slow
def test_train_loss_decreases_lm():
    out = train("minitron-4b", steps=14, batch=4, seq=32, lr=3e-3)
    losses = out["losses"]
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


@pytest.mark.slow
def test_train_with_pruning_runs():
    out = train("minitron-4b", steps=4, batch=2, seq=32, lr=1e-3, prune=True)
    assert np.isfinite(out["losses"][-1])


@pytest.mark.slow
def test_train_checkpoint_restart_cycle():
    with tempfile.TemporaryDirectory() as d:
        out = train("stablelm-1.6b", steps=6, batch=2, seq=16,
                    ckpt_dir=d, checkpoint_every=3)
        assert out["restarts"] == 0
        kinds = [k for _, k in out["events"]]
        assert "checkpoint" in kinds
        # resume from the checkpoint: runs remaining steps without error
        out2 = train("stablelm-1.6b", steps=8, batch=2, seq=16,
                     ckpt_dir=d, checkpoint_every=3)
        assert any(k == "restored" for _, k in out2["events"])


@pytest.mark.slow
def test_serve_end_to_end():
    out = serve("rwkv6-1.6b", num_requests=3, prompt_len=8, max_new=4,
                max_batch=2)
    assert len(out["outputs"]) == 3
    assert out["tokens_per_s"] > 0


@pytest.mark.slow
def test_serve_with_kv_pruning():
    out = serve("qwen3-14b", num_requests=2, prompt_len=8, max_new=6,
                kv_prune=0.5)
    assert all(len(v) == 6 for v in out["outputs"].values())
