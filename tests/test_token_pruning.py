"""Unit tests for dynamic token pruning (paper §IV-B) + KV pruning."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import token_pruning as tp


def _mk(B=2, N=17, D=8, seed=0):
    key = jax.random.PRNGKey(seed)
    z = jax.random.normal(key, (B, N, D))
    s = jax.random.uniform(jax.random.fold_in(key, 1), (B, N))
    return z, s


def test_tdm_output_count():
    z, s = _mk()
    for rt in (0.25, 0.5, 0.9):
        out, idx = tp.tdm(z, s, rt)
        assert out.shape[1] == tp.num_kept_tokens(17, rt)


def test_tdm_keeps_cls():
    z, s = _mk()
    out, _ = tp.tdm(z, s, 0.5)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(z[:, 0]))


def test_tdm_keeps_top_scoring_tokens():
    z, s = _mk(B=1)
    out, idx = tp.tdm(z, s, 0.5)
    body_scores = np.asarray(s[0, 1:])
    top = set(np.argsort(-body_scores)[:8].tolist())
    assert set(np.asarray(idx[0]).tolist()) == top


def test_tdm_fused_token_is_weighted_average():
    z, s = _mk(B=1, N=5, D=4)
    out, idx = tp.tdm(z, s, 0.5)  # keeps 2 of 4 body tokens + fused
    kept = set(np.asarray(idx[0]).tolist())
    dropped = [i for i in range(4) if i not in kept]
    sc = np.asarray(s[0, 1:])
    w = sc[dropped] / sc[dropped].sum()
    expected = (w[:, None] * np.asarray(z[0, 1:])[dropped]).sum(0)
    np.testing.assert_allclose(np.asarray(out[0, -1]), expected, rtol=1e-5)


def test_tdm_rt_one_keeps_everything_plus_fused_slot():
    """r_t=1.0: no token is dropped, but the fused slot is still appended
    (static shape contract) and aggregates nothing (zero vector)."""
    z, s = _mk(B=2, N=9, D=4)
    out, idx = tp.tdm(z, s, 1.0)
    assert out.shape[1] == tp.num_kept_tokens(9, 1.0) == 9 + 1
    for b in range(2):
        assert sorted(np.asarray(idx[b]).tolist()) == list(range(8))
    np.testing.assert_allclose(np.asarray(out[:, -1]), 0.0, atol=1e-6)


def test_tdm_without_cls():
    """has_cls=False: no protected slot; output is top-k body + fused."""
    z, s = _mk(B=1, N=8)
    out, idx = tp.tdm(z, s, 0.5, has_cls=False)
    assert out.shape[1] == tp.num_kept_tokens(8, 0.5, has_cls=False) == 5
    top = set(np.argsort(-np.asarray(s[0]))[:4].tolist())
    assert set(np.asarray(idx[0]).tolist()) == top
    # first output slot is the best-scoring token, not a CLS passthrough
    best = int(np.argmax(np.asarray(s[0])))
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(z[0, best]))


def test_compact_kv_cache_preserves_temporal_order():
    """select_kv_keep sorts indices, so the compacted cache must read out
    in the original temporal order (RoPE sanity)."""
    B, N, H, Dh = 3, 16, 2, 4
    # encode each slot's position into its values
    pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.float32)[None, :, None, None],
                           (B, N, H, Dh))
    mass = jnp.asarray(np.random.default_rng(3).random((B, N)))
    idx = tp.select_kv_keep(mass, 6)
    k2, v2 = tp.compact_kv_cache(pos, pos, idx)
    kept_pos = np.asarray(k2[:, :, 0, 0])
    assert (np.diff(kept_pos, axis=1) > 0).all()
    np.testing.assert_array_equal(kept_pos, np.asarray(idx, np.float32))


def test_token_importance_from_attention():
    # attn [B, H, Nq, Nk]: scoring row aggregated over heads
    attn = jnp.zeros((1, 2, 3, 3)).at[0, 0, 0].set(jnp.asarray([0.1, 0.7, 0.2]))
    attn = attn.at[0, 1, 0].set(jnp.asarray([0.3, 0.3, 0.4]))
    s = tp.token_importance(attn, score_row=0)
    np.testing.assert_allclose(np.asarray(s[0]), [0.2, 0.5, 0.3], rtol=1e-6)


def test_kv_select_and_compact():
    B, N, H, Dh = 2, 8, 2, 4
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (B, N, H, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, N, H, Dh))
    mass = jnp.asarray(np.random.default_rng(0).random((B, N)))
    idx = tp.select_kv_keep(mass, 4)
    assert idx.shape == (B, 4)
    # temporal order preserved
    assert bool((jnp.diff(idx, axis=1) > 0).all())
    k2, v2 = tp.compact_kv_cache(k, v, idx)
    assert k2.shape == (B, 4, H, Dh)
    np.testing.assert_allclose(
        np.asarray(k2[0, 0]), np.asarray(k[0, int(idx[0, 0])]))


def test_kv_prune_scores_masks_invalid():
    mass = jnp.ones((1, 8))
    s = tp.kv_prune_scores(mass, cache_len=5)
    assert bool(jnp.isneginf(s[0, 5:]).all())
    assert bool((s[0, :5] == 1.0).all())


def test_kv_prune_scores_masks_left_padding():
    """Per-slot ``start`` masks the left-pad prefix alongside the tail."""
    mass = jnp.ones((2, 8))
    s = tp.kv_prune_scores(mass, cache_len=6, start=jnp.asarray([0, 3]))
    assert bool((s[0, :6] == 1.0).all())
    assert bool(jnp.isneginf(s[1, :3]).all())   # pads masked
    assert bool((s[1, 3:6] == 1.0).all())
    assert bool(jnp.isneginf(s[:, 6:]).all())


def test_select_kv_keep_never_picks_masked_pads():
    """Regression (serving left-pad bug): pad slots must never be selected
    while enough real tokens exist, even with huge accumulated mass."""
    mass = jnp.asarray(np.random.default_rng(0).random((2, 16)), jnp.float32)
    mass = mass.at[1, :4].set(1e9)  # poisoned pad mass
    starts = jnp.asarray([0, 4])
    scores = tp.kv_prune_scores(mass, cache_len=16, start=starts)
    idx = np.asarray(tp.select_kv_keep(scores, 8))
    assert (idx[1] >= 4).all()      # no pad index survives
    assert len(set(idx[1].tolist())) == 8


def test_select_kv_keep_clamps_keep_beyond_width():
    mass = jnp.ones((1, 8))
    idx = tp.select_kv_keep(mass, keep=20)  # clamped to 8
    assert idx.shape == (1, 8)
    assert sorted(np.asarray(idx[0]).tolist()) == list(range(8))


def test_select_kv_keep_groups_invalid_picks():
    """keep > valid count: -inf picks must not interleave with real ones —
    valid indices stay in temporal order at the front (default) or back
    (invalid_first=True, the compaction layout)."""
    scores = tp.kv_prune_scores(jnp.ones((1, 8)), cache_len=3)
    idx = np.asarray(tp.select_kv_keep(scores, 5))[0]
    assert idx[:3].tolist() == [0, 1, 2]        # valid, temporal order
    assert (idx[3:] >= 3).all()                  # invalid packed at back
    idx_f = np.asarray(tp.select_kv_keep(scores, 5, invalid_first=True))[0]
    assert idx_f[-3:].tolist() == [0, 1, 2]     # valid at the back
    assert (idx_f[:2] >= 3).all()                # garbage prefix


def test_lm_prefill_token_pruning():
    """TDM applied to a causal LM prompt: fewer tokens after TDM layers,
    finite last-token logits, and with r_t=1-ish behaviour approaching the
    dense forward."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.models.prefill_prune import pruned_prefill_logits

    key = jax.random.PRNGKey(0)
    cfg = get_config("minitron-4b").reduced()
    cfg = cfg.replace(pruning=cfg.pruning.__class__(
        block_size=16, r_t=0.5, tdm_layers=(1,)))
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    logits, n_final = pruned_prefill_logits(cfg, params, toks)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert n_final < 16  # tokens actually dropped

    # sanity: at high keep rate the pruned prediction tracks the dense one
    cfg_hi = cfg.replace(pruning=cfg.pruning.__class__(
        block_size=16, r_t=0.99, tdm_layers=(1,)))
    hi_logits, _ = pruned_prefill_logits(cfg_hi, params, toks)
    dense = M.forward_lm(cfg, params, toks, mode="train", remat=False)
    a = np.asarray(hi_logits)
    d = np.asarray(dense.logits[:, -1])
    corr = np.corrcoef(a.ravel(), d.ravel())[0, 1]
    assert corr > 0.98


# ---------------------------------------------------------------------------
# soft-pruning TDM (package token)
# ---------------------------------------------------------------------------
def test_tdm_soft_first_package_is_weighted_average():
    """First soft TDM (no package yet): dropped body tokens fold into one
    package row = score-weighted average, and the returned mass is the
    dropped score sum."""
    z, s = _mk(B=1, N=6, D=4)
    k = 2
    out, mass = tp.tdm_soft(z, s, k=k)
    assert out.shape == (1, k + 2, 4)
    body_s = np.asarray(s[0, 1:], np.float64)
    body_z = np.asarray(z[0, 1:], np.float64)
    order = np.argsort(-body_s)
    kept, dropped = order[:k], order[k:]
    # CLS passes through, kept rows in score order
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(z[0, 0]),
                               atol=1e-6)
    w = body_s[dropped]
    ref_pkg = (w[:, None] * body_z[dropped]).sum(0) / (w.sum() + 1e-9)
    np.testing.assert_allclose(np.asarray(out[0, -1]), ref_pkg, atol=1e-5)
    np.testing.assert_allclose(float(mass[0]), w.sum(), rtol=1e-6)


def test_tdm_soft_mass_accumulates_across_steps():
    """A second soft TDM folds its drops into the EXISTING package: the
    old package participates at its accumulated mass (raw-score scale)
    and the new mass is old mass + newly dropped score sum."""
    z, s = _mk(B=2, N=17, D=8)
    out1, mass1 = tp.tdm_soft(z, s, r_t=0.5)
    import jax
    s2 = jax.random.uniform(jax.random.PRNGKey(9), out1.shape[:2])
    out2, mass2 = tp.tdm_soft(out1, s2, k=3, pkg_mass=mass1)
    assert out2.shape[1] == 3 + 2
    body2 = np.asarray(s2[:, 1:], np.float64)  # includes the package col
    for b in range(2):
        scores_b = body2[b].copy()
        order = np.argsort(-np.where(
            np.arange(len(scores_b)) == len(scores_b) - 1, -np.inf,
            scores_b))
        dropped = order[3:]
        dropped = dropped[dropped != len(scores_b) - 1]
        expect = scores_b[dropped].sum() + float(mass1[b])
        np.testing.assert_allclose(float(mass2[b]), expect, rtol=1e-5)
    assert bool((np.asarray(mass2) > np.asarray(mass1)).all())


def test_tdm_soft_package_row_pinned_out_of_topk():
    """With a package present, top-k never selects the package row even
    when its score is the highest — it is pinned at the package slot."""
    z, s = _mk(B=1, N=8, D=4)
    s = s.at[0, -1].set(100.0)  # package row (last body row) scores huge
    out, mass = tp.tdm_soft(z, s, k=2, pkg_mass=jnp.ones((1,)))
    # kept rows are drawn from the non-package body rows only
    kept = np.asarray(out[0, 1:3])
    body = np.asarray(z[0, 1:-1])
    for row in kept:
        assert any(np.allclose(row, b) for b in body)


def test_tdm_soft_explicit_k_beyond_body_raises():
    z, s = _mk(B=1, N=6, D=4)
    import pytest
    with pytest.raises(ValueError):
        tp.tdm_soft(z, s, k=5, pkg_mass=jnp.ones((1,)))
