"""repro.traffic: traces, virtual-clock SLO accounting, admission control.

The acceptance properties of the traffic subsystem:

* traces are pure functions of (spec, seed) — same fingerprint on every
  synthesis, fingerprint-preserving JSONL roundtrip, versioned schema;
* harness timestamps (submit / first-dispatch / retire, hence TTFD,
  latency and every deadline verdict) are identical at pipeline depths 1
  and 2 for the same trace, on both engines — the virtual clock prices
  plans, and PR 6 guarantees identical plans at any depth;
* with admission off, the harness serves byte-identical outputs to a
  direct ``engine.serve()`` call on the same requests (digest equality —
  the replay path adds accounting, never math);
* admission decisions are deterministic given (seed, trace, limit), the
  controller degrades before it rejects, and a rejected request never
  enters the queue (no submitted_total advance, no slot, a ``reject``
  event).
"""
import jax
import numpy as np
import pytest

from repro.configs import DEIT_SMALL
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import (EngineConfig, ServeEngine, Scheduler,
                           VisionEngine, VisionEngineConfig)
from repro.traffic import (AdmissionController, TraceSpec, TrafficHarness,
                           LMDriver, VisionDriver, bursty_arrivals,
                           diurnal_arrivals, load_trace, make_trace,
                           outputs_digest, percentile, poisson_arrivals,
                           save_trace, trace_fingerprint)


@pytest.fixture(scope="module")
def packed_vit(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)
    return cfg, masked, packed


def _vision_engine(packed_vit, depth=1, quality="strict", slots=2):
    cfg, masked, packed = packed_vit
    return VisionEngine(cfg, masked, packed, VisionEngineConfig(
        max_batch=slots, planner="full", pipeline_depth=depth,
        quality=quality))


def _vision_spec(n=8, rate=60000.0, deadline=0.05):
    # rate far above the uncalibrated model's modeled capacity so the
    # bursty stream actually queues; sizes small to keep compiles cheap
    return TraceSpec(n=n, rate_rps=rate, process="bursty", sizes=(9, 4),
                     r_ts=(None, 0.7), deadlines_ms=(deadline, None))


# ===========================================================================
# workload: arrivals, traces, serialization
# ===========================================================================
def test_arrival_processes_are_seeded_and_monotone():
    for fn in (poisson_arrivals, bursty_arrivals, diurnal_arrivals):
        a = fn(64, 100.0, np.random.default_rng(3))
        b = fn(64, 100.0, np.random.default_rng(3))
        np.testing.assert_array_equal(a, b)
        assert np.all(np.diff(a) > 0) and a[0] > 0


def test_bursty_matches_offered_load_but_overdisperses():
    a = bursty_arrivals(4000, 200.0, np.random.default_rng(0))
    rate = 4000 / (a[-1] * 1e-3)
    assert rate == pytest.approx(200.0, rel=0.1)
    gaps = np.diff(a)
    # MMPP gap CV must exceed the exponential's 1.0 — that's the burst
    assert np.std(gaps) / np.mean(gaps) > 1.05


def test_trace_is_pure_function_of_spec_and_seed():
    spec = _vision_spec()
    fp = trace_fingerprint(make_trace(spec, seed=5))
    assert trace_fingerprint(make_trace(spec, seed=5)) == fp
    assert trace_fingerprint(make_trace(spec, seed=6)) != fp
    assert trace_fingerprint(
        make_trace(_vision_spec(rate=1000.0), seed=5)) != fp


def test_trace_jsonl_roundtrip_preserves_fingerprint(tmp_path):
    trace = make_trace(_vision_spec(), seed=2)
    path = str(tmp_path / "t.jsonl")
    fp = save_trace(path, trace)
    loaded = load_trace(path)
    assert fp == trace_fingerprint(trace) == trace_fingerprint(loaded)
    assert loaded.requests == trace.requests
    assert loaded.meta == trace.meta


def test_trace_schema_version_is_enforced(tmp_path):
    trace = make_trace(_vision_spec(n=2), seed=0)
    path = str(tmp_path / "t.jsonl")
    save_trace(path, trace)
    lines = open(path).read().splitlines()
    import json
    meta = json.loads(lines[0])
    meta["trace_schema"] = 999
    (tmp_path / "bad.jsonl").write_text(
        "\n".join([json.dumps(meta)] + lines[1:]) + "\n")
    with pytest.raises(ValueError, match="trace_schema"):
        load_trace(str(tmp_path / "bad.jsonl"))


def test_trace_validation():
    with pytest.raises(ValueError, match="process"):
        TraceSpec(process="lognormal")
    with pytest.raises(ValueError, match="arrival_ms"):
        from repro.traffic import TraceRequest
        TraceRequest(uid=0, arrival_ms=-1.0)
    with pytest.raises(ValueError, match="sorted"):
        from repro.traffic import Trace, TraceRequest
        Trace(meta={}, requests=(TraceRequest(uid=0, arrival_ms=2.0),
                                 TraceRequest(uid=1, arrival_ms=1.0)))


def test_percentile_nearest_rank():
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 99) == 4.0
    assert np.isnan(percentile([], 50))


# ===========================================================================
# scheduler: admission hook + first-class stats
# ===========================================================================
class _Req:
    def __init__(self, uid):
        self.uid = uid


def test_scheduler_admission_hook_rejects_without_side_effects():
    seen = []

    def hook(req):
        seen.append(req.uid)
        return req.uid % 2 == 0

    sched = Scheduler(2, admission_control=hook)
    sched.submit([_Req(i) for i in range(4)])
    assert seen == [0, 1, 2, 3]
    # rejected uids never entered the queue and never advanced
    # submitted_total (a reject must not trigger engine mid-step replans)
    assert [r.uid for r in sched.waiting] == [0, 2]
    assert sched.submitted_total == 2
    assert sched.rejected_total == 2
    assert [e for e in sched.events if e[0] == "reject"] == [
        ("reject", 1), ("reject", 3)]
    st = sched.stats()
    assert st["queue_depth"] == st["peak_queue_depth"] == 2
    assert st["rejected_total"] == 2 and st["submitted_total"] == 2


def test_scheduler_stats_track_lifecycle():
    sched = Scheduler(2)
    sched.submit([_Req(i) for i in range(3)])
    assert sched.stats()["peak_queue_depth"] == 3
    sched.schedule()
    st = sched.stats()
    assert st["running"] == 2 and st["free_slots"] == 0
    assert st["queue_depth"] == 1 and st["peak_queue_depth"] == 3
    sched.retire(0)
    assert sched.stats()["retired_total"] == 1


# ===========================================================================
# admission controller (stub pricers — engine-free semantics)
# ===========================================================================
def test_admission_degrades_before_rejecting():
    backlog = {"ms": 0.0}
    ctrl = AdmissionController(
        limit_ms=10.0,
        cost_ms=lambda r: 6.0,
        backlog_ms=lambda: backlog["ms"],
        degraded_cost_ms=lambda r: 2.0,
        degrade=lambda r: setattr(r, "quality", "degrade"))
    r0, r1, r2 = _Req(0), _Req(1), _Req(2)
    assert ctrl.gate(r0)                       # 6 <= 10: accept
    backlog["ms"] = 6.0
    assert ctrl.gate(r1)                       # 6 > 4 but 2 <= 4: degrade
    assert getattr(r1, "quality") == "degrade"
    backlog["ms"] = 9.0
    assert not ctrl.gate(r2)                   # even degraded 2 > 1: reject
    assert [d.action for d in ctrl.decisions] == [
        "accept", "degrade", "reject"]
    assert ctrl.counts() == {"accept": 1, "degrade": 1, "reject": 1}
    d = ctrl.decisions[1]
    assert d.cost_ms == 2.0 and d.backlog_ms == 6.0 and d.limit_ms == 10.0


def test_admission_without_degrade_arm_is_accept_or_reject():
    ctrl = AdmissionController(limit_ms=5.0, cost_ms=lambda r: 6.0,
                               backlog_ms=lambda: 0.0)
    assert not ctrl.gate(_Req(0))
    assert ctrl.decisions[0].action == "reject"
    with pytest.raises(ValueError, match="limit_ms"):
        AdmissionController(limit_ms=0.0, cost_ms=lambda r: 1.0,
                            backlog_ms=lambda: 0.0)


# ===========================================================================
# harness: vision engine
# ===========================================================================
def test_vision_harness_timestamps_identical_across_depths(packed_vit):
    trace = make_trace(_vision_spec(), seed=9)
    reports, lifecycles, digests = [], [], []
    for depth in (1, 2):
        h = TrafficHarness(VisionDriver(_vision_engine(packed_vit, depth)))
        rep = h.run(trace)
        reports.append(rep)
        lifecycles.append(h.lifecycle())
        digests.append(rep["outputs_digest"])
        # basic lifecycle sanity: submit at arrival, dispatch after
        # submit, retire after dispatch, all on the virtual clock
        for rec in h.records.values():
            assert rec.submit_ms >= rec.arrival_ms
            assert rec.first_dispatch_ms >= rec.submit_ms
            assert rec.retire_ms > rec.first_dispatch_ms
            assert rec.ttfd_ms >= 0.0
    # the whole point of the virtual clock: pipeline depth changes wall
    # time, never virtual timestamps — byte-identical lifecycles,
    # reports, and served outputs
    assert lifecycles[0] == lifecycles[1]
    assert digests[0] == digests[1]
    assert reports[0] == reports[1]


def test_vision_harness_replay_is_deterministic(packed_vit):
    trace = make_trace(_vision_spec(), seed=4)
    h1 = TrafficHarness(VisionDriver(_vision_engine(packed_vit)))
    h2 = TrafficHarness(VisionDriver(_vision_engine(packed_vit)))
    r1, r2 = h1.run(trace), h2.run(trace)
    assert h1.lifecycle() == h2.lifecycle()
    assert r1 == r2


def test_vision_harness_equals_direct_serve(packed_vit):
    trace = make_trace(_vision_spec(), seed=4)
    h = TrafficHarness(VisionDriver(_vision_engine(packed_vit)))
    rep = h.run(trace)
    assert rep["completed"] == len(trace.requests)
    eng = _vision_engine(packed_vit)
    drv = VisionDriver(eng)
    direct = eng.serve([drv.materialize(t) for t in trace.requests])
    assert outputs_digest(direct) == rep["outputs_digest"]


def test_vision_deadline_accounting(packed_vit):
    # every request gets an impossible SLO, then a generous one: the
    # miss-rate column must see through both
    tight = make_trace(TraceSpec(n=4, rate_rps=1e5, process="poisson",
                                 sizes=(9,), deadlines_ms=(1e-6,)), seed=1)
    h = TrafficHarness(VisionDriver(_vision_engine(packed_vit)))
    rep = h.run(tight)
    assert rep["deadline_total"] == 4
    assert rep["deadline_miss_rate"] == 1.0
    assert rep["goodput_rps"] == 0.0      # completions, but none in SLO
    assert rep["throughput_rps"] > 0.0
    loose = make_trace(TraceSpec(n=4, rate_rps=1e5, process="poisson",
                                 sizes=(9,), deadlines_ms=(1e6,)), seed=1)
    rep2 = TrafficHarness(
        VisionDriver(_vision_engine(packed_vit))).run(loose)
    assert rep2["deadline_miss_rate"] == 0.0
    assert rep2["goodput_rps"] == rep2["throughput_rps"]


def test_vision_admission_decisions_deterministic(packed_vit):
    trace = make_trace(_vision_spec(n=10, rate=2e5), seed=7)
    runs = []
    for _ in range(2):
        h = TrafficHarness(
            VisionDriver(_vision_engine(packed_vit, quality="auto")),
            admission_limit_ms=0.02)
        h.run(trace)
        runs.append([(d.uid, d.action, d.cost_ms, d.backlog_ms)
                     for d in h.controller.decisions])
    assert runs[0] == runs[1]
    assert len(runs[0]) == 10
    actions = {a for _, a, _, _ in runs[0]}
    assert "reject" in actions            # the limit actually binds
    # rejected requests produced no outputs, accepted ones all did
    h3 = TrafficHarness(
        VisionDriver(_vision_engine(packed_vit, quality="auto")),
        admission_limit_ms=0.02)
    rep = h3.run(trace)
    rejected = {d.uid for d in h3.controller.decisions
                if d.action == "reject"}
    assert set(h3.outputs) == set(range(10)) - rejected
    assert rep["rejected"] == len(rejected)
    for uid in rejected:
        rec = h3.records[uid]
        assert rec.rejected and rec.retire_ms is None


def test_vision_admission_bounds_queue_vs_unbounded(packed_vit):
    trace = make_trace(_vision_spec(n=10, rate=2e5), seed=7)
    unb = TrafficHarness(VisionDriver(_vision_engine(packed_vit)))
    unb_rep = unb.run(trace)
    adm = TrafficHarness(
        VisionDriver(_vision_engine(packed_vit, quality="auto")),
        admission_limit_ms=0.02)
    adm_rep = adm.run(trace)
    assert adm_rep["peak_queue_depth"] < unb_rep["peak_queue_depth"]
    assert adm_rep["rejected"] > 0


def test_harness_rejects_mismatched_trace_kind(packed_vit):
    lm_trace = make_trace(TraceSpec(n=2, kind="lm", process="poisson",
                                    rate_rps=10.0), seed=0)
    h = TrafficHarness(VisionDriver(_vision_engine(packed_vit)))
    with pytest.raises(ValueError, match="kind"):
        h.run(lm_trace)


# ===========================================================================
# harness: LM engine
# ===========================================================================
def _lm_engine(depth=1):
    from repro.configs import get_config
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, EngineConfig(max_batch=2, max_len=128,
                                                 pipeline_depth=depth))


def _lm_spec(n=6, deadline=80.0):
    return TraceSpec(n=n, rate_rps=150.0, process="bursty", kind="lm",
                     prompt_sizes=(8, 16), max_new_tokens=4,
                     deadlines_ms=(deadline, None))


def test_lm_harness_timestamps_identical_across_depths():
    trace = make_trace(_lm_spec(), seed=3)
    lifecycles, digests = [], []
    for depth in (1, 2):
        h = TrafficHarness(LMDriver(_lm_engine(depth), per_token_ms=1.0))
        rep = h.run(trace)
        assert rep["completed"] == len(trace.requests)
        lifecycles.append(h.lifecycle())
        digests.append(rep["outputs_digest"])
    assert lifecycles[0] == lifecycles[1]
    assert digests[0] == digests[1]


def test_lm_harness_equals_direct_serve():
    trace = make_trace(_lm_spec(), seed=3)
    eng = _lm_engine()
    drv = LMDriver(eng, per_token_ms=1.0)
    h = TrafficHarness(drv)
    rep = h.run(trace)
    eng2 = _lm_engine()
    drv2 = LMDriver(eng2, per_token_ms=1.0)
    direct = eng2.serve([drv2.materialize(t) for t in trace.requests],
                        continuous=True)
    assert outputs_digest(direct) == rep["outputs_digest"]


def test_lm_admission_rejects_under_token_budget():
    trace = make_trace(_lm_spec(n=8, deadline=None), seed=6)
    drv = LMDriver(_lm_engine(), per_token_ms=1.0)
    h = TrafficHarness(drv, admission_limit_ms=30.0)
    rep = h.run(trace)
    assert rep["rejected"] > 0
    assert rep["completed"] + rep["rejected"] == 8
    # deterministic decisions
    drv2 = LMDriver(_lm_engine(), per_token_ms=1.0)
    h2 = TrafficHarness(drv2, admission_limit_ms=30.0)
    h2.run(trace)
    assert ([(d.uid, d.action) for d in h.controller.decisions]
            == [(d.uid, d.action) for d in h2.controller.decisions])
