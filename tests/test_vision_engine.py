"""VisionEngine: bit-exact ragged-batch serving of the packed ViT.

The acceptance property of the vision serving subsystem: for every request
in a mixed continuous batch (mixed resolutions, mixed per-request keep
rates, staggered arrivals), the served logits are BIT-EXACT against the
single-request offline ``forward_vit_packed`` — and jit recompiles stay
within the ragged batcher's bucket set."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import DEIT_SMALL
from repro.core import packed_runner as PR
from repro.models import model as M
from repro.models import pruning_glue as PG
from repro.serving import (Request, ServeEngine, EngineConfig,
                           VisionEngine, VisionEngineConfig, VisionRequest)


@pytest.fixture(scope="module")
def packed_vit(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    masked = PG.apply_pruning(cfg, params, scores)
    packed = PR.pack_model(cfg, params, scores)
    return cfg, masked, packed


def _mixed_requests(cfg, mixes):
    rng = np.random.default_rng(0)
    pdim = cfg.patch_size ** 2 * 3
    return [VisionRequest(
        uid=i, patches=rng.standard_normal((n, pdim)).astype(np.float32),
        r_t=r_t, arrival_step=arr)
        for i, (n, r_t, arr) in enumerate(mixes)]


def _offline(cfg, masked, packed, req, segments=None):
    c = cfg if req.r_t is None else cfg.replace(
        pruning=dataclasses.replace(cfg.pruning, r_t=req.r_t))
    return np.asarray(PR.forward_vit_packed(
        c, masked, packed, req.patches[None], segments=segments).logits[0])


def test_mixed_batch_bitexact_and_bounded_recompiles(packed_vit):
    """Mixed sizes + keep rates + staggered arrivals through 3 slots: every
    logit vector bit-exact vs the offline path; recompiles <= buckets; one
    unified admit/retire event stream."""
    cfg, masked, packed = packed_vit
    reqs = _mixed_requests(cfg, [(16, None, 0), (9, 0.5, 0), (4, 0.7, 1),
                                 (16, 0.5, 2), (9, None, 3), (4, 0.5, 3)])
    eng = VisionEngine(cfg, masked, packed, VisionEngineConfig(max_batch=3))
    out = eng.serve(reqs)
    assert sorted(out) == [r.uid for r in reqs]

    # recompile discipline: check BEFORE the reference runs below add
    # their own (B=1) shapes to the shared executor's caches
    st = eng.stats()
    assert st["jit_compile_count"] <= st["bucket_count"]
    assert st["batcher_padding_waste"] == 0.0  # token_tile=1: exact tiles

    # unified event stream (same shape as the LM path's)
    admits = [uid for kind, uid in eng.events if kind == "admit"]
    retires = [uid for kind, uid in eng.events if kind == "retire"]
    assert sorted(admits) == sorted(retires) == [r.uid for r in reqs]

    for r in reqs:
        ref = _offline(cfg, masked, packed, r, segments=eng.segments)
        assert np.array_equal(ref, out[r.uid]), (
            f"uid {r.uid}: batched serving changed the logits")
        assert r.done and r.logits is not None


def test_batch_composition_invariance(packed_vit):
    """The same request served alone and in a different mix produces the
    same bits (batch composition independence)."""
    cfg, masked, packed = packed_vit
    probe = _mixed_requests(cfg, [(9, 0.5, 0)])[0]

    def fresh(u):
        return VisionRequest(uid=u, patches=probe.patches.copy(), r_t=0.5)

    solo = VisionEngine(cfg, masked, packed,
                        VisionEngineConfig(max_batch=1))
    out_solo = solo.serve([fresh(0)])
    crowd_reqs = [fresh(7)] + _mixed_requests(
        cfg, [(16, None, 0), (4, 0.7, 0), (9, 0.7, 1)])
    crowd = VisionEngine(cfg, masked, packed,
                         VisionEngineConfig(max_batch=4))
    out_crowd = crowd.serve(crowd_reqs)
    assert np.array_equal(out_solo[0], out_crowd[7])


def test_padded_modes_serve_everyone_close(packed_vit):
    """token_tile > 1 and naive padding run masked kernels: same math,
    different FP reduction order — allclose, all requests served, bound
    still holds."""
    cfg, masked, packed = packed_vit
    mixes = [(16, None, 0), (9, 0.5, 0), (4, 0.7, 1), (13, 0.5, 1)]
    for vc in (VisionEngineConfig(max_batch=2, token_tile=8),
               VisionEngineConfig(max_batch=2, mode="naive")):
        eng = VisionEngine(cfg, masked, packed, vc)
        reqs = _mixed_requests(cfg, mixes)
        out = eng.serve(reqs)
        assert sorted(out) == [r.uid for r in reqs]
        st = eng.stats()
        assert st["jit_compile_count"] <= st["bucket_count"]
        for r in reqs:
            ref = _offline(cfg, masked, packed, r)
            np.testing.assert_allclose(ref, out[r.uid], atol=1e-5,
                                       rtol=1e-5)


@pytest.mark.parametrize("pmode", ["merge", "fuse", "full"])
def test_planner_modes_bitexact_and_bounded_recompiles(packed_vit, pmode):
    """The tentpole acceptance: merged and fused ExecutionPlans produce
    BIT-EXACT head logits vs the unmerged balanced path (planner off) and
    vs the offline single-request oracle, with jit recompiles bounded by
    the bucket ∪ trajectory budget."""
    cfg, masked, packed = packed_vit
    mixes = [(16, None, 0), (9, 0.5, 0), (4, 0.7, 1),
             (16, 0.5, 2), (9, None, 3), (4, 0.5, 3),
             (9, 0.5, 4)]  # uid 6 shares uid 1's bucket -> merge fodder

    base = VisionEngine(cfg, masked, packed,
                        VisionEngineConfig(max_batch=3, planner="off"))
    out_base = base.serve(_mixed_requests(cfg, mixes))

    eng = VisionEngine(cfg, masked, packed,
                       VisionEngineConfig(max_batch=3, planner=pmode))
    reqs = _mixed_requests(cfg, mixes)
    out = eng.serve(reqs)
    assert sorted(out) == [r.uid for r in reqs]

    st = eng.stats()
    assert st["jit_compile_count"] <= st["compile_budget"]
    assert st["compile_budget"] == (st["bucket_count"]
                                    + st["trajectory_count"])
    if pmode in ("fuse", "full"):
        assert st["plan_lanes"] > 0  # express lanes actually ran

    for r in reqs:
        assert np.array_equal(out_base[r.uid], out[r.uid]), (
            f"uid {r.uid}: planner {pmode} changed the logits vs the "
            f"unmerged balanced path")
        ref = _offline(cfg, masked, packed, r, segments=eng.segments)
        assert np.array_equal(ref, out[r.uid])


def test_merge_mode_actually_merges(packed_vit):
    """With fusion disabled and a dispatch-dominated cost model, same-stage
    neighboring buckets must bin-pack into masked tiles."""
    from repro.serving import TileCostModel
    cfg, masked, packed = packed_vit
    cm = TileCostModel(cfg, dispatch_overhead_cycles=1e9)
    eng = VisionEngine(cfg, masked, packed,
                       VisionEngineConfig(max_batch=4, planner="merge"),
                       cost_model=cm)
    reqs = _mixed_requests(cfg, [(16, 0.5, 0), (9, 0.5, 0), (4, 0.5, 0)])
    out = eng.serve(reqs)
    st = eng.stats()
    assert st["plan_merges"] > 0
    assert st["batcher_padding_waste"] > 0.0  # merged tiles are masked
    for r in reqs:
        ref = _offline(cfg, masked, packed, r)
        assert np.array_equal(ref, out[r.uid])


def test_deadline_requests_split_dispatch_first_and_discount_load(
        packed_vit):
    """Deadline-aware tiling: an already-expired SLO makes the planner
    carve the request out of shared tiles (counted in plan stats) while
    results stay bit-exact; the admission annotation shrinks so
    prune_pressure_aware prefers tight-deadline requests."""
    cfg, masked, packed = packed_vit
    # same size + r_t so the deadline request shares every bucket (not
    # fusible -> must go through the split path)
    mixes = [(9, 0.5, 0), (9, 0.5, 0), (9, 0.5, 0)]
    reqs = _mixed_requests(cfg, mixes)
    reqs[0].deadline_ms = 1e-6  # expired by the first plan
    eng = VisionEngine(cfg, masked, packed,
                       VisionEngineConfig(max_batch=3, planner="full"))
    out = eng.serve(reqs)
    st = eng.stats()
    assert st["plan_deadline_urgent"] > 0
    assert st["plan_deadline_splits"] > 0
    for r in reqs:
        ref = _offline(cfg, masked, packed, r)
        assert np.array_equal(ref, out[r.uid])

    # generous deadlines are not urgent
    eng2 = VisionEngine(cfg, masked, packed,
                        VisionEngineConfig(max_batch=3, planner="full"))
    reqs2 = _mixed_requests(cfg, mixes)
    for r in reqs2:
        r.deadline_ms = 1e9
    eng2.serve(reqs2)
    assert eng2.stats()["plan_deadline_urgent"] == 0

    # the prune_pressure_aware annotation: tighter deadline -> smaller load
    tight, loose = _mixed_requests(cfg, [(9, 0.5, 0), (9, 0.5, 0)])
    tight.deadline_ms = 1e-6
    eng3 = VisionEngine(cfg, masked, packed,
                        VisionEngineConfig(max_batch=1, planner="full"))
    eng3.serve([tight, loose])
    assert tight.prune_load < loose.prune_load


def test_admission_policies_order_vision_requests(packed_vit):
    """shortest_prompt_first admits small images first;
    prune_pressure_aware admits by predicted post-prune token load — a
    heavily-pruned large image can overtake a lightly-pruned medium one."""
    cfg, masked, packed = packed_vit
    # uid 0: 16 patches r_t=0.1 (heavy pruning), uid 1: 16 patches r_t=1.0,
    # uid 2: 9 patches r_t=1.0, uid 3: 4 patches r_t=1.0
    mixes = [(16, 0.1, 0), (16, 1.0, 0), (9, 1.0, 0), (4, 1.0, 0)]

    def admit_order(policy):
        eng = VisionEngine(cfg, masked, packed,
                           VisionEngineConfig(max_batch=1), policy=policy)
        eng.serve(_mixed_requests(cfg, mixes))
        return [uid for kind, uid in eng.events if kind == "admit"]

    assert admit_order("fifo") == [0, 1, 2, 3]
    assert admit_order("shortest_prompt_first") == [3, 2, 0, 1]
    loads = {r.uid: r.prune_load
             for r in _annotated(cfg, masked, packed, mixes)}
    expected = sorted(loads, key=lambda u: loads[u])
    assert admit_order("prune_pressure_aware") == expected
    # the heavily-pruned large image must overtake the unpruned one
    assert expected.index(0) < expected.index(1)


def _annotated(cfg, masked, packed, mixes):
    reqs = _mixed_requests(cfg, mixes)
    for r in reqs:
        r.prune_load = float(sum(PR.token_trajectory(
            cfg, r.n_patches, r_t=r.r_t)))
    return reqs


def test_lm_requests_get_prune_load_annotation(rng_key):
    """The LM ServeEngine annotates prune_load (KV-prune-discounted
    footprint) so prune_pressure_aware is meaningful on both paths."""
    from repro.configs import get_config
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, rng_key)
    ec = EngineConfig(max_batch=2, max_len=64, kv_prune_interval=4,
                      kv_prune_keep=0.5)
    eng = ServeEngine(cfg, params, ec)
    reqs = [Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                    max_new_tokens=6)]
    eng._annotate_prune_load(reqs)
    assert reqs[0].prune_load == pytest.approx((10 + 6) * 0.5)
    # disabled pruning -> undiscounted footprint
    eng2 = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_len=64))
    reqs2 = [Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                     max_new_tokens=6)]
    eng2._annotate_prune_load(reqs2)
    assert reqs2[0].prune_load == pytest.approx(16.0)


def test_validation_and_config_errors(packed_vit):
    cfg, masked, packed = packed_vit
    pdim = cfg.patch_size ** 2 * 3
    eng = VisionEngine(cfg, masked, packed)
    with pytest.raises(ValueError, match="patches outside"):
        eng.serve([VisionRequest(uid=0, patches=np.zeros((99, pdim),
                                                         np.float32))])
    with pytest.raises(ValueError, match="patch dim"):
        eng.serve([VisionRequest(uid=0, patches=np.zeros((4, 7),
                                                         np.float32))])
    with pytest.raises(ValueError, match="r_t"):
        eng.serve([VisionRequest(uid=0, patches=np.zeros((4, pdim),
                                                         np.float32),
                                 r_t=1.5)])
    with pytest.raises(ValueError, match="deadline_ms"):
        eng.serve([VisionRequest(uid=0, patches=np.zeros((4, pdim),
                                                         np.float32),
                                 deadline_ms=-5.0)])
    with pytest.raises(ValueError):
        VisionEngineConfig(max_batch=0)
    with pytest.raises(ValueError):
        VisionEngineConfig(token_tile=0)
    with pytest.raises(ValueError):
        VisionEngineConfig(mode="magic")
    with pytest.raises(ValueError):
        VisionEngineConfig(planner="aggressive")
    with pytest.raises(ValueError, match="balanced"):
        VisionEngineConfig(mode="naive", planner="full")
    with pytest.raises(ValueError, match="family"):
        VisionEngine(DEIT_SMALL.reduced().replace(family="dense"),
                     masked, packed)
    with pytest.raises(ValueError, match="unknown policy"):
        VisionEngine(cfg, masked, packed, policy="best_effort")


def test_invalid_request_does_not_leak_siblings(packed_vit):
    """A serve() that raises on one request must not enqueue the others —
    they would silently surface in the next serve()'s results."""
    cfg, masked, packed = packed_vit
    pdim = cfg.patch_size ** 2 * 3
    eng = VisionEngine(cfg, masked, packed, VisionEngineConfig(max_batch=2))
    good = _mixed_requests(cfg, [(4, 0.5, 0)])[0]
    bad = VisionRequest(uid=9, patches=np.zeros((4, 7), np.float32))
    with pytest.raises(ValueError, match="patch dim"):
        eng.serve([good, bad])
    other = _mixed_requests(cfg, [(4, 0.5, 0)])[0]
    other.uid = 5
    out = eng.serve([other])
    assert sorted(out) == [5]  # `good` must NOT ride along


def test_large_token_tile_respects_pos_table(packed_vit):
    """token_tile rounding must clamp at the position-table capacity: a
    full-resolution image under a coarse tile previously crashed the embed
    stage with a broadcast error."""
    cfg, masked, packed = packed_vit
    eng = VisionEngine(cfg, masked, packed,
                       VisionEngineConfig(max_batch=2, token_tile=15))
    reqs = _mixed_requests(cfg, [(16, None, 0), (4, 0.5, 0)])
    out = eng.serve(reqs)
    assert sorted(out) == [0, 1]
    for r in reqs:
        ref = _offline(cfg, masked, packed, r)
        np.testing.assert_allclose(ref, out[r.uid], atol=1e-5, rtol=1e-5)


def test_from_pruned_builds_serving_engine(rng_key):
    cfg = DEIT_SMALL.reduced()
    params = M.init_params(cfg, rng_key)
    scores = PG.init_scores(cfg, params, jax.random.fold_in(rng_key, 7))
    eng = VisionEngine.from_pruned(cfg, params, scores,
                                   vc=VisionEngineConfig(max_batch=2))
    reqs = _mixed_requests(cfg, [(16, None, 0), (9, 0.5, 0)])
    out = eng.serve(reqs)
    assert sorted(out) == [0, 1]
    for lg in out.values():
        assert lg.shape == (cfg.num_classes,)
        assert np.isfinite(lg).all()


def test_validation_rejects_nonfinite_and_bad_quality(packed_vit):
    """NaN fails every range comparison, so it used to slip through the
    ``deadline_ms <= 0`` check; non-finite r_t / deadline / schedules and
    unknown quality preferences must all be rejected at submit."""
    cfg, masked, packed = packed_vit
    pdim = cfg.patch_size ** 2 * 3
    eng = VisionEngine(cfg, masked, packed)

    def rq(**kw):
        return VisionRequest(uid=0, patches=np.zeros((4, pdim), np.float32),
                             **kw)

    for bad in (float("nan"), float("inf"), -float("inf")):
        with pytest.raises(ValueError, match="r_t"):
            eng.serve([rq(r_t=bad)])
        with pytest.raises(ValueError, match="deadline_ms"):
            eng.serve([rq(deadline_ms=bad)])
    with pytest.raises(ValueError, match="keep_schedule"):
        eng.serve([rq(keep_schedule=(float("nan"),))])
    with pytest.raises(ValueError, match="keep_schedule"):
        eng.serve([rq(keep_schedule=(0.5, 0.5))])  # model has 1 TDM
    with pytest.raises(ValueError, match="quality"):
        eng.serve([rq(quality="fastest")])


def test_prune_load_refreshes_while_waiting(packed_vit):
    """The deadline discount is recomputed each admission pass: waiting
    time consumes slack, so a queued deadline request's annotated load
    keeps falling (its admission urgency keeps RISING) — not frozen at
    its submit-time value."""
    import time as _time

    cfg, masked, packed = packed_vit
    pdim = cfg.patch_size ** 2 * 3
    eng = VisionEngine(cfg, masked, packed)
    req = VisionRequest(uid=0, patches=np.zeros((9, pdim), np.float32),
                        deadline_ms=100.0, prune_load_base=100.0,
                        prune_load=100.0, solo_ms=50.0,
                        submit_t=_time.monotonic())
    eng.scheduler.waiting.append(req)
    eng._refresh_prune_loads(req.submit_t)      # full slack at submit
    assert req.prune_load == pytest.approx(100.0)
    eng._refresh_prune_loads(req.submit_t + 0.075)  # 75ms waited
    mid = req.prune_load
    assert mid == pytest.approx(100.0 * (25.0 / 50.0))
    eng._refresh_prune_loads(req.submit_t + 1.0)    # deadline blown
    assert req.prune_load == 0.0 < mid


def test_soft_prune_requests_bitexact_vs_offline(packed_vit):
    """Soft-pruning requests (package token) served in a mixed batch with
    hard-pruning ones: each bit-exact against its own offline path."""
    cfg, masked, packed = packed_vit
    reqs = _mixed_requests(cfg, [(16, None, 0), (9, 0.5, 0), (16, 0.5, 1)])
    reqs[0].soft_prune = True
    reqs[1].soft_prune = True
    eng = VisionEngine(cfg, masked, packed,
                       VisionEngineConfig(max_batch=3, planner="full",
                                          pipeline_depth=2))
    out = eng.serve(reqs)
    for r in reqs:
        c = cfg if r.r_t is None else dataclasses.replace(
            cfg, pruning=dataclasses.replace(cfg.pruning, r_t=r.r_t))
        ref = np.asarray(PR.forward_vit_packed(
            c, masked, packed, r.patches[None],
            soft=r.soft_prune).logits[0])
        assert np.array_equal(ref, out[r.uid]), r.uid
    st = eng.stats()
    assert st["jit_compile_count"] <= st["compile_budget"]
